"""Pallas TPU kernels for the ARCQuant hot path.

  nvfp4_quant      blockwise NVFP4 quantization (codes + E4M3 scales)
  arc_fused_quant  paper §3.3: RMSNorm + reorder + primary + residual quant,
                   interleaved channel layout (Appendix D); the RMSNorm is
                   optional (apply_norm) so pre-normalized projections share
                   the same launch
  nvfp4_gemm       unified-precision GEMM over the augmented K+S dimension;
                   consumes packed serving weights (two codes/byte + E4M3
                   scale codes decoded in-kernel) and switches to a decode
                   fast path (single M tile, f32 scratch accumulator, each
                   weight tile decoded once) at serving decode shapes

Each kernel has a pure-jnp oracle in ref.py; tests run interpret=True.
These are the kernels `QuantConfig.backend="pallas"` routes every deployed
linear through (models/layers._arc_pallas_matmul).
"""
from repro.kernels import common, ops, ref
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import nvfp4_gemm
from repro.kernels.nvfp4_quant import nvfp4_quantize

__all__ = ["common", "ops", "ref", "arc_fused_quantize", "nvfp4_gemm",
           "nvfp4_quantize"]
