"""Pallas TPU kernels for the ARCQuant hot path.

  nvfp4_quant      blockwise NVFP4 quantization (codes + E4M3 scales)
  arc_fused_quant  paper §3.3: RMSNorm + reorder + primary + residual quant,
                   interleaved channel layout (Appendix D); the RMSNorm is
                   optional (apply_norm) so pre-normalized projections share
                   the same launch
  nvfp4_gemm       unified-precision GEMM over the augmented K+S dimension;
                   consumes packed serving weights (two codes/byte + E4M3
                   scale codes decoded in-kernel) and switches to a decode
                   fast path (single M tile, f32 scratch accumulator, each
                   weight tile decoded once) at serving decode shapes; a
                   fused bias epilogue adds b onto the f32 accumulator at
                   the out-tile store, and the decode fast path keeps the
                   decoded activation VMEM-resident across the (j, k)
                   schedule when the buffers fit (plan "residency")
  nvfp4_gemm_swiglu
                   dual-weight variant for gate/up MLP pairs sharing one
                   quantization plan: both packed weights decode against a
                   single activation tile and the epilogue computes
                   silu(g) * u on the VMEM accumulators — one activation
                   read/quantization and no (M, F) intermediate round trip
  paged_attention  vLLM-style paged-attention decode: the per-request
                   block table is a scalar-prefetch operand whose index
                   maps stream K/V pages straight from the pool in HBM,
                   with an online-softmax VMEM accumulator, GQA head
                   grouping, posp-driven masking, and traced valid-row
                   masking for ragged decode batches

Each kernel has a pure-jnp oracle in ref.py (the paged-attention oracle
is the gather + ``chunked_attention`` path it replaces); tests run
interpret=True. The GEMM kernels are what `QuantConfig.backend="pallas"`
routes every deployed linear through (models/layers._arc_pallas_matmul);
the attention kernel is the default paged decode path
(`QuantConfig.attn_kernel`).
"""
from repro.kernels import common, ops, ref
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import nvfp4_gemm, nvfp4_gemm_swiglu
from repro.kernels.nvfp4_quant import nvfp4_quantize
from repro.kernels.paged_attention import paged_attention_decode

__all__ = ["common", "ops", "ref", "arc_fused_quantize", "nvfp4_gemm",
           "nvfp4_gemm_swiglu", "nvfp4_quantize", "paged_attention_decode"]
