"""Pallas TPU kernels for the ARCQuant hot path.

  nvfp4_quant      blockwise NVFP4 quantization (codes + E4M3 scales)
  arc_fused_quant  paper §3.3: RMSNorm + reorder + primary + residual quant,
                   interleaved channel layout (Appendix D)
  nvfp4_gemm       unified-precision GEMM over the augmented K+S dimension

Each kernel has a pure-jnp oracle in ref.py; tests run interpret=True.
"""
from repro.kernels import common, ops, ref
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import nvfp4_gemm
from repro.kernels.nvfp4_quant import nvfp4_quantize

__all__ = ["common", "ops", "ref", "arc_fused_quantize", "nvfp4_gemm",
           "nvfp4_quantize"]
