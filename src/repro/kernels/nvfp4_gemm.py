"""Pallas TPU kernel: unified NVFP4 GEMM over the augmented K+S dimension.

This is the TPU analogue of the paper's single CUTLASS GEMM call: both
operands arrive as 4-bit E2M1 codes + block scales; each (bm, bn, bk) tile
is dequantized in VMEM/VREGs and fed to the MXU with f32 accumulation. The
augmented residual channels (paper §3.2) ride the same K loop — no special
casing, which is exactly the paper's "unified GEMM execution" property.

Weight operands come in two storage modes:
  * unpacked — uint8 holding one 4-bit code per byte + effective f32 scales
    (what the quantization kernels emit for activations)
  * packed (``w_packed=True``) — two codes per byte + 8-bit E4M3 scale codes
    relative to the per-tensor FP32 scale: the serving checkpoint
    representation (``QTensor.to_packed``). Unpack + scale decode happen
    in-kernel, so HBM weight traffic stays at ~4.5 bits/value.

Two schedules:
  * generic (prefill): grid (M/bm, N/bn, Ka/bk), k-innermost accumulation
    into the out tile. Weight tiles are re-decoded once per i.
  * decode fast path — chosen when M (padded) fits one bm tile, the serving
    decode shape (M = active slots): grid (N/bn, Ka/bk) with an f32 VMEM
    scratch accumulator. Every weight tile is decoded exactly once per
    (j, k) — (M/bm)x fewer weight decodes than running the generic schedule
    over the same problem — and the out tile is written once at the last
    k step instead of read-modify-written per step.

Ragged M/N are padded up to the tile grid (zero codes decode to +0 and
contribute nothing) instead of shrinking block sizes below hardware tiles —
the old divisor-shrink loop degenerated for odd M (e.g. 3 active decode
slots).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as C

GROUP = 16
SUBLANE = 8     # minimum second-to-last tile granularity we pad M/N to


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _decode_x(xc_ref, xs_ref):
    bm, bk = xc_ref.shape
    x = C.decode_e2m1(xc_ref[...]).reshape(bm, bk // GROUP, GROUP)
    x = x * xs_ref[...].astype(jnp.float32)[..., None]
    return x.reshape(bm, bk)


def _decode_w(wc_ref, ws_ref, wt_ref, w_packed: bool, bk: int):
    bn = wc_ref.shape[0]
    if w_packed:
        codes = C.unpack_e2m1(wc_ref[...])
        scales = C.decode_e4m3(ws_ref[...]) * wt_ref[0]
    else:
        codes = wc_ref[...]
        scales = ws_ref[...].astype(jnp.float32)
    w = C.decode_e2m1(codes).reshape(bn, bk // GROUP, GROUP)
    return (w * scales[..., None]).reshape(bn, bk)


def _mxu_dot(x, w):
    # MXU matmul in bf16 with f32 accumulation (TPU-native datapath)
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def _gemm_kernel(w_packed, bk, xc_ref, xs_ref, wc_ref, ws_ref, wt_ref,
                 out_ref):
    """Generic schedule: grid (M/bm, N/bn, Ka/bk), k innermost."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = _decode_x(xc_ref, xs_ref)
    w = _decode_w(wc_ref, ws_ref, wt_ref, w_packed, bk)
    out_ref[...] += _mxu_dot(x, w)


def _gemm_kernel_decode(w_packed, bk, nk, xc_ref, xs_ref, wc_ref, ws_ref,
                        wt_ref, out_ref, acc_ref):
    """Decode fast path: grid (N/bn, Ka/bk); single M tile.

    The weight tile for (j, k) is decoded exactly once (there is no i loop
    to re-decode it under); partial sums live in the f32 VMEM scratch and
    the out tile is stored once at the final k step.
    """
    k_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = _decode_x(xc_ref, xs_ref)
    w = _decode_w(wc_ref, ws_ref, wt_ref, w_packed, bk)
    acc_ref[...] += _mxu_dot(x, w)

    @pl.when(k_idx == nk - 1)
    def _store():
        out_ref[...] = acc_ref[...]


def _tile(dim: int, block: int) -> int:
    """Tile size for one padded dimension: the fewest tiles that cover
    ``dim`` under the ``block`` cap, each rounded up to the hardware
    sublane. Padding is bounded below ``tiles * SUBLANE`` rows — the old
    rule padded ``dim`` up to a multiple of ``block`` (m=257 with bm=256
    computed 512 rows, ~2x wasted work; this computes 272)."""
    tiles = max(-(-dim // block), 1)
    return min(_round_up(-(-dim // tiles), SUBLANE), _round_up(block, SUBLANE))


def gemm_plan(m: int, n: int, ka: int, block_m: int = 256,
              block_n: int = 256, block_k: int = 2048) -> dict:
    """Static schedule description for a GEMM shape (no tracing).

    ``weight_tile_decodes`` counts how many (bn, bk) weight tiles the
    schedule dequantizes — the quantity the decode fast path minimizes.
    ``flops`` / ``useful_flops`` account the padded vs requested work so
    callers can see the ragged-tail waste the tile choice bounds
    (benchmarks/deployed_serving.py reports both).
    """
    assert ka % GROUP == 0, ka
    # M/N tiles: minimal tile count first, then the smallest sublane-
    # aligned tile covering the dim — the ragged remainder is padded at
    # SUBLANE granularity instead of up to a full block
    bm = _tile(m, block_m)
    bn = _tile(n, block_n)
    bk = min(block_k, ka)
    while ka % bk:
        bk //= 2
    bk = max(bk, GROUP)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    ni, nj, nk = mp // bm, np_ // bn, ka // bk
    fast = ni == 1
    flops = 2 * mp * np_ * ka
    useful = 2 * m * n * ka
    return {
        "path": "decode_fast" if fast else "generic",
        "bm": bm, "bn": bn, "bk": bk, "mp": mp, "np": np_,
        "grid": (nj, nk) if fast else (ni, nj, nk),
        "weight_tile_decodes": nj * nk if fast else ni * nj * nk,
        "flops": flops,
        "useful_flops": useful,
        "padding_waste": 1.0 - useful / flops,
    }


def gemm_vmem_bytes(plan: dict, w_packed: bool = True) -> int:
    """Estimated VMEM residency of one launch under ``plan``.

    Pipeline in/out blocks are double-buffered (x2); the decode fast
    path adds its f32 accumulator scratch. Mirrors the BlockSpecs in
    :func:`nvfp4_gemm` — update both together.
    """
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    wc = bk // 2 if w_packed else bk
    ws = (bk // GROUP) * (1 if w_packed else 4)
    inputs = (bm * bk                       # x codes (uint8)
              + bm * (bk // GROUP) * 4      # x scales (f32)
              + bn * wc                     # w codes
              + bn * ws                     # w scales
              + 4)                          # tensor scale
    outputs = bm * bn * 4                   # f32 out tile
    scratch = bm * bn * 4 if plan["path"] == "decode_fast" else 0
    return 2 * (inputs + outputs) + scratch


def _pad_rows(a: jax.Array, rows: int) -> jax.Array:
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


@functools.partial(jax.jit,
                   static_argnames=("w_packed", "block_m", "block_n",
                                    "block_k", "interpret"))
def nvfp4_gemm(x_codes: jax.Array, x_scales: jax.Array,
               w_codes: jax.Array, w_scales: jax.Array,
               w_tensor_scale: jax.Array | None = None,
               w_packed: bool = False,
               block_m: int = 256, block_n: int = 256, block_k: int = 2048,
               interpret: bool = False) -> jax.Array:
    """(M, Ka) x (N, Ka) -> (M, N) f32. Ka includes the S augmented channels.

    Unpacked weights: ``w_codes`` (N, Ka) uint8, ``w_scales`` (N, Ka/16) f32
    effective scales. Packed weights (``w_packed=True``): ``w_codes``
    (N, Ka/2) uint8 byte pairs, ``w_scales`` (N, Ka/16) uint8 E4M3 codes,
    ``w_tensor_scale`` the FP32 per-tensor scale they are relative to.
    """
    m, ka = x_codes.shape
    n = w_codes.shape[0]
    ka2 = w_codes.shape[1] * 2 if w_packed else w_codes.shape[1]
    assert ka == ka2 and ka % GROUP == 0, (ka, ka2)
    if w_packed:
        assert w_tensor_scale is not None, "packed weights need tensor scale"
    wt = (jnp.asarray(w_tensor_scale, jnp.float32).reshape(1)
          if w_tensor_scale is not None else jnp.ones((1,), jnp.float32))

    plan = gemm_plan(m, n, ka, block_m, block_n, block_k)
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    mp, np_ = plan["mp"], plan["np"]
    nk = ka // bk

    x_codes = _pad_rows(x_codes, mp)
    x_scales = _pad_rows(x_scales, mp)
    w_codes = _pad_rows(w_codes, np_)
    w_scales = _pad_rows(w_scales, np_)

    wc_cols = bk // 2 if w_packed else bk
    wt_spec = pl.BlockSpec((1,), lambda *_: (0,))

    if plan["path"] == "decode_fast":
        kernel = functools.partial(_gemm_kernel_decode, w_packed, bk, nk)
        out = pl.pallas_call(
            kernel,
            grid=(np_ // bn, nk),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda j, k: (0, k)),
                pl.BlockSpec((bm, bk // GROUP), lambda j, k: (0, k)),
                pl.BlockSpec((bn, wc_cols), lambda j, k: (j, k)),
                pl.BlockSpec((bn, bk // GROUP), lambda j, k: (j, k)),
                wt_spec,
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda j, k: (0, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(x_codes, x_scales, w_codes, w_scales, wt)
    else:
        kernel = functools.partial(_gemm_kernel, w_packed, bk)
        out = pl.pallas_call(
            kernel,
            grid=(mp // bm, np_ // bn, nk),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bm, bk // GROUP), lambda i, j, k: (i, k)),
                pl.BlockSpec((bn, wc_cols), lambda i, j, k: (j, k)),
                pl.BlockSpec((bn, bk // GROUP), lambda i, j, k: (j, k)),
                wt_spec,
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(x_codes, x_scales, w_codes, w_scales, wt)
    return out[:m, :n]
