"""Pallas TPU kernel: unified NVFP4 GEMM over the augmented K+S dimension.

This is the TPU analogue of the paper's single CUTLASS GEMM call: both
operands arrive as 4-bit E2M1 codes + block scales; each (bm, bn, bk) tile
is dequantized in VMEM/VREGs and fed to the MXU with f32 accumulation. The
augmented residual channels (paper §3.2) ride the same K loop — no special
casing, which is exactly the paper's "unified GEMM execution" property.

Weight operands come in two storage modes:
  * unpacked — uint8 holding one 4-bit code per byte + effective f32 scales
    (what the quantization kernels emit for activations)
  * packed (``w_packed=True``) — two codes per byte + 8-bit E4M3 scale codes
    relative to the per-tensor FP32 scale: the serving checkpoint
    representation (``QTensor.to_packed``). Unpack + scale decode happen
    in-kernel, so HBM weight traffic stays at ~4.5 bits/value.

Three schedules:
  * generic (prefill): grid (M/bm, N/bn, Ka/bk), k-innermost accumulation
    into the out tile. Weight tiles are re-decoded once per i.
  * decode fast path — chosen when M (padded) fits one bm tile, the serving
    decode shape (M = active slots): grid (N/bn, Ka/bk) with an f32 VMEM
    scratch accumulator. Every weight tile is decoded exactly once per
    (j, k) and the out tile is written once at the last k step.
  * decode resident — the fast path upgraded when the whole problem fits
    the VMEM budget (``plan["residency"]``): grid (N/bn,). The activation
    operand rides in with a constant index map (fetched from HBM once per
    launch, not once per grid step), is decoded once into an f32 VMEM
    scratch at j == 0, and stays resident across the whole (j, k) schedule;
    packed weight rows stream in as full-Ka blocks, double-buffered across
    the j loop, with the K loop an in-kernel fori_loop over VMEM slices.
    Accumulation order is identical to the streamed fast path, so results
    are bitwise equal.

Epilogues (fused into the out-tile store, saving an HBM round trip):
  * ``bias`` on :func:`nvfp4_gemm` — the f32 bias add happens on the
    accumulator before the single store instead of as a follow-up XLA op.
  * :func:`nvfp4_gemm_swiglu` — dual-weight schedule for gate/up MLP
    pairs: both packed weight tiles are decoded against ONE activation
    tile (one quantized-activation read instead of two) and
    ``silu(g) * u`` is computed in VMEM in ``out_dtype``, so the
    intermediate (M, F) gate/up tensors never touch HBM.

Ragged M/N are padded up to the tile grid (zero codes decode to +0 and
contribute nothing) instead of shrinking block sizes below hardware tiles —
the old divisor-shrink loop degenerated for odd M (e.g. 3 active decode
slots).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as C

GROUP = 16
SUBLANE = 8     # minimum second-to-last tile granularity we pad M/N to

# A decode launch may go VMEM-resident only when the estimator below says
# the whole problem (activations decoded to f32 + double-buffered full-Ka
# weight rows) fits this budget. Matches analysis/vmem.py's R6 default —
# defined here (not imported) because vmem.py imports its estimators from
# this module.
DECODE_RESIDENT_VMEM_LIMIT = 16 * 2**20


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _decode_x(xc_ref, xs_ref):
    bm, bk = xc_ref.shape
    x = C.decode_e2m1(xc_ref[...]).reshape(bm, bk // GROUP, GROUP)
    x = x * xs_ref[...].astype(jnp.float32)[..., None]
    return x.reshape(bm, bk)


def _decode_w(wc_ref, ws_ref, wt_ref, w_packed: bool, bk: int):
    bn = wc_ref.shape[0]
    if w_packed:
        codes = C.unpack_e2m1(wc_ref[...])
        scales = C.decode_e4m3(ws_ref[...]) * wt_ref[0]
    else:
        codes = wc_ref[...]
        scales = ws_ref[...].astype(jnp.float32)
    w = C.decode_e2m1(codes).reshape(bn, bk // GROUP, GROUP)
    return (w * scales[..., None]).reshape(bn, bk)


def _decode_w_chunk(wc_ref, ws_ref, wt_ref, w_packed: bool, bk: int, kidx):
    """Decode the k-th (bn, bk) chunk of a full-Ka VMEM weight block.

    Value-identical to :func:`_decode_w` on the matching streamed block —
    group decode is elementwise per GROUP and bk % GROUP == 0, so slicing
    before or after decoding commutes."""
    bn = wc_ref.shape[0]
    sg = bk // GROUP
    if w_packed:
        codes = C.unpack_e2m1(wc_ref[:, pl.ds(kidx * (bk // 2), bk // 2)])
        scales = C.decode_e4m3(ws_ref[:, pl.ds(kidx * sg, sg)]) * wt_ref[0]
    else:
        codes = wc_ref[:, pl.ds(kidx * bk, bk)]
        scales = ws_ref[:, pl.ds(kidx * sg, sg)].astype(jnp.float32)
    w = C.decode_e2m1(codes).reshape(bn, sg, GROUP)
    return (w * scales[..., None]).reshape(bn, bk)


def _mxu_dot(x, w):
    # MXU matmul in bf16 with f32 accumulation (TPU-native datapath)
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def _swiglu_epilogue(accg, accu, out_dtype):
    """The fused MLP epilogue, replicating the unfused layer chain bitwise:
    f32 accumulators -> round to out_dtype (exactly where the two unfused
    GEMMs round their stores) -> silu computed in f32 -> one final round.
    The f32 silu matches ``models.layers._swiglu``, the single canonical
    epilogue definition — interior low-precision ops would not survive
    XLA's float normalization bit-identically across eager/jit."""
    g = accg.astype(out_dtype).astype(jnp.float32)
    u = accu.astype(out_dtype).astype(jnp.float32)
    return (jax.nn.silu(g) * u).astype(out_dtype)


def _gemm_kernel(w_packed, bk, nk, has_bias, *refs):
    """Generic schedule: grid (M/bm, N/bn, Ka/bk), k innermost."""
    if has_bias:
        xc_ref, xs_ref, wc_ref, ws_ref, wt_ref, b_ref, out_ref = refs
    else:
        (xc_ref, xs_ref, wc_ref, ws_ref, wt_ref, out_ref), b_ref = refs, None
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = _decode_x(xc_ref, xs_ref)
    w = _decode_w(wc_ref, ws_ref, wt_ref, w_packed, bk)
    out_ref[...] += _mxu_dot(x, w)

    if b_ref is not None:
        @pl.when(k_idx == nk - 1)
        def _bias():
            out_ref[...] += b_ref[...][None, :]


def _gemm_kernel_decode(w_packed, bk, nk, has_bias, *refs):
    """Decode fast path: grid (N/bn, Ka/bk); single M tile.

    The weight tile for (j, k) is decoded exactly once (there is no i loop
    to re-decode it under); partial sums live in the f32 VMEM scratch and
    the out tile is stored once at the final k step.
    """
    if has_bias:
        xc_ref, xs_ref, wc_ref, ws_ref, wt_ref, b_ref, out_ref, acc_ref = refs
    else:
        (xc_ref, xs_ref, wc_ref, ws_ref, wt_ref, out_ref, acc_ref), b_ref = \
            refs, None
    k_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = _decode_x(xc_ref, xs_ref)
    w = _decode_w(wc_ref, ws_ref, wt_ref, w_packed, bk)
    acc_ref[...] += _mxu_dot(x, w)

    @pl.when(k_idx == nk - 1)
    def _store():
        acc = acc_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...][None, :]
        out_ref[...] = acc


def _gemm_kernel_decode_resident(w_packed, bk, nk, has_bias, *refs):
    """Decode resident path: grid (N/bn,); activations decoded once.

    The x operand arrives under a constant index map (one HBM fetch per
    launch) and is decoded into the persistent f32 scratch at j == 0; every
    later grid step reuses it. Weight rows arrive as full-Ka blocks (the
    pallas pipeline double-buffers them across j) and the K loop runs
    in-kernel over VMEM slices. The f32 accumulation order matches the
    streamed fast path chunk for chunk, so outputs are bitwise identical.
    """
    if has_bias:
        xc_ref, xs_ref, wc_ref, ws_ref, wt_ref, b_ref, out_ref, xdec_ref = refs
    else:
        (xc_ref, xs_ref, wc_ref, ws_ref, wt_ref, out_ref, xdec_ref), b_ref = \
            refs, None
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _decode_activations():
        xdec_ref[...] = _decode_x(xc_ref, xs_ref)

    bm, bn = out_ref.shape

    def body(kidx, acc):
        x = xdec_ref[:, pl.ds(kidx * bk, bk)]
        w = _decode_w_chunk(wc_ref, ws_ref, wt_ref, w_packed, bk, kidx)
        return acc + _mxu_dot(x, w)

    acc = jax.lax.fori_loop(0, nk, body, jnp.zeros((bm, bn), jnp.float32))
    if b_ref is not None:
        acc = acc + b_ref[...][None, :]
    out_ref[...] = acc


def _swiglu_kernel(w_packed, bk, nk, out_dtype, xc_ref, xs_ref,
                   gc_ref, gs_ref, gt_ref, uc_ref, us_ref, ut_ref,
                   out_ref, accg_ref, accu_ref):
    """Fused gate/up schedule: grid (M/bm, F/bn, Ka/bk), k innermost.

    One activation tile feeds both weight streams; the two f32
    accumulators live in VMEM scratch and ``silu(g) * u`` is computed in
    the out-tile store — the (M, F) gate/up intermediates never hit HBM.
    """
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = _decode_x(xc_ref, xs_ref)
    accg_ref[...] += _mxu_dot(x, _decode_w(gc_ref, gs_ref, gt_ref,
                                           w_packed, bk))
    accu_ref[...] += _mxu_dot(x, _decode_w(uc_ref, us_ref, ut_ref,
                                           w_packed, bk))

    @pl.when(k_idx == nk - 1)
    def _store():
        out_ref[...] = _swiglu_epilogue(accg_ref[...], accu_ref[...],
                                        out_dtype)


def _swiglu_kernel_decode_resident(w_packed, bk, nk, out_dtype, xc_ref,
                                   xs_ref, gc_ref, gs_ref, gt_ref, uc_ref,
                                   us_ref, ut_ref, out_ref, xdec_ref):
    """Fused gate/up decode resident path: grid (F/bn,)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _decode_activations():
        xdec_ref[...] = _decode_x(xc_ref, xs_ref)

    bm, bn = out_ref.shape

    def body(kidx, accs):
        accg, accu = accs
        x = xdec_ref[:, pl.ds(kidx * bk, bk)]
        g = _decode_w_chunk(gc_ref, gs_ref, gt_ref, w_packed, bk, kidx)
        u = _decode_w_chunk(uc_ref, us_ref, ut_ref, w_packed, bk, kidx)
        return accg + _mxu_dot(x, g), accu + _mxu_dot(x, u)

    zeros = jnp.zeros((bm, bn), jnp.float32)
    accg, accu = jax.lax.fori_loop(0, nk, body, (zeros, zeros))
    out_ref[...] = _swiglu_epilogue(accg, accu, out_dtype)


def _tile(dim: int, block: int) -> int:
    """Tile size for one padded dimension: the fewest tiles that cover
    ``dim`` under the ``block`` cap, each rounded up to the hardware
    sublane. Padding is bounded below ``tiles * SUBLANE`` rows — the old
    rule padded ``dim`` up to a multiple of ``block`` (m=257 with bm=256
    computed 512 rows, ~2x wasted work; this computes 272)."""
    tiles = max(-(-dim // block), 1)
    return min(_round_up(-(-dim // tiles), SUBLANE), _round_up(block, SUBLANE))


def _check_blocks(block_m: int, block_n: int, block_k: int) -> None:
    """Reject block sizes the schedule cannot honor, instead of silently
    mis-tiling. The K block must align with the packed byte-pair width —
    2 E2M1 codes per byte x GROUP-code E4M3 scale groups = 2*GROUP
    columns per indivisible packed unit — or a derived bk could split a
    byte pair / scale group mid-tile."""
    for name, val in (("block_m", block_m), ("block_n", block_n)):
        if val < 1:
            raise ValueError(f"{name} must be a positive tile size, "
                             f"got {val}")
    unit = 2 * GROUP
    if block_k < unit or block_k % unit:
        raise ValueError(
            f"block_k={block_k} does not divide into the packed byte-pair "
            f"width: K blocks must be positive multiples of {unit} "
            f"(2 E2M1 codes per packed byte x {GROUP}-code scale groups), "
            f"or the derived k tile would split packed byte pairs / E4M3 "
            f"scale groups and mis-tile the in-kernel decode")


def _resident_vmem_bytes(bm: int, bn: int, ka: int, w_packed: bool,
                         weight_streams: int, out_bytes: int) -> int:
    """VMEM footprint of the decode resident schedule: activations fetched
    once (constant index map, single-buffered) + decoded f32 copy, full-Ka
    weight rows double-buffered across the j loop, per-stream f32
    accumulators, double-buffered out tiles."""
    wc = ka // 2 if w_packed else ka
    ws = (ka // GROUP) * (1 if w_packed else 4)
    x_in = bm * ka + bm * (ka // GROUP) * 4
    w_in = 2 * weight_streams * (bn * wc + bn * ws + 4)
    out = 2 * bm * bn * out_bytes
    scratch = bm * ka * 4 + weight_streams * bm * bn * 4
    return x_in + w_in + out + scratch


def gemm_plan(m: int, n: int, ka: int, block_m: int = 256,
              block_n: int = 256, block_k: int = 2048, *,
              w_packed: bool = True, weight_streams: int = 1,
              out_bytes: int = 4) -> dict:
    """Static schedule description for a GEMM shape (no tracing).

    ``weight_tile_decodes`` counts how many (bn, bk) weight tiles the
    schedule dequantizes — the quantity the decode fast path minimizes —
    and ``x_tile_decodes`` the activation-tile decodes, which the resident
    path collapses to one. ``residency`` marks a decode launch that fits
    :data:`DECODE_RESIDENT_VMEM_LIMIT` and will run the resident schedule.
    ``hbm_read_bytes`` / ``hbm_write_bytes`` model per-launch HBM traffic
    under the schedule (activation + weight fetches; one out-tile store).
    ``flops`` / ``useful_flops`` account the padded vs requested work so
    callers can see the ragged-tail waste the tile choice bounds
    (benchmarks/deployed_serving.py reports both).
    """
    assert ka % GROUP == 0, ka
    _check_blocks(block_m, block_n, block_k)
    # M/N tiles: minimal tile count first, then the smallest sublane-
    # aligned tile covering the dim — the ragged remainder is padded at
    # SUBLANE granularity instead of up to a full block
    bm = _tile(m, block_m)
    bn = _tile(n, block_n)
    bk = min(block_k, ka)
    while ka % bk:
        bk //= 2
    bk = max(bk, GROUP)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    ni, nj, nk = mp // bm, np_ // bn, ka // bk
    fast = ni == 1
    resident = fast and _resident_vmem_bytes(
        bm, bn, ka, w_packed, weight_streams,
        out_bytes) <= DECODE_RESIDENT_VMEM_LIMIT
    flops = 2 * mp * np_ * ka
    useful = 2 * m * n * ka
    wtiles = nj * nk if fast else ni * nj * nk
    wc = bk // 2 if w_packed else bk
    ws = (bk // GROUP) * (1 if w_packed else 4)
    x_fetch = bm * bk + bm * (bk // GROUP) * 4
    x_reads = (mp * ka + mp * (ka // GROUP) * 4 if resident
               else wtiles * x_fetch)
    w_reads = weight_streams * wtiles * (bn * wc + bn * ws)
    return {
        "kernel": "nvfp4_gemm",
        "path": "decode_fast" if fast else "generic",
        "residency": resident,
        "bm": bm, "bn": bn, "bk": bk, "mp": mp, "np": np_,
        "m": m, "n": n, "ka": ka, "k_steps": nk,
        "weight_streams": weight_streams, "out_bytes": out_bytes,
        "grid": (nj, nk) if fast else (ni, nj, nk),
        "weight_tile_decodes": weight_streams * wtiles,
        "x_tile_decodes": 1 if resident else wtiles,
        "hbm_read_bytes": x_reads + w_reads,
        "hbm_write_bytes": mp * np_ * out_bytes,
        "flops": weight_streams * flops,
        "useful_flops": weight_streams * useful,
        "padding_waste": 1.0 - useful / flops,
    }


def swiglu_plan(m: int, n: int, ka: int, block_m: int = 256,
                block_n: int = 256, block_k: int = 2048, *,
                w_packed: bool = True, out_bytes: int = 2) -> dict:
    """Schedule description for :func:`nvfp4_gemm_swiglu`: the same tiling
    as :func:`gemm_plan` with two weight streams sharing each activation
    tile and an ``out_dtype`` (default bf16) fused-epilogue store."""
    p = gemm_plan(m, n, ka, block_m, block_n, block_k, w_packed=w_packed,
                  weight_streams=2, out_bytes=out_bytes)
    p["kernel"] = "nvfp4_gemm_swiglu"
    return p


def gemm_vmem_bytes(plan: dict, w_packed: bool = True) -> int:
    """Estimated VMEM residency of one launch under ``plan``.

    Pipeline in/out blocks are double-buffered (x2); the decode fast
    path adds its per-stream f32 accumulator scratch, and the resident
    path is priced by :func:`_resident_vmem_bytes` (whole-Ka weight rows,
    decoded-activation scratch). Mirrors the BlockSpecs in
    :func:`nvfp4_gemm` / :func:`nvfp4_gemm_swiglu` — update both together.
    """
    streams = plan.get("weight_streams", 1)
    out_b = plan.get("out_bytes", 4)
    if plan.get("residency"):
        return _resident_vmem_bytes(plan["bm"], plan["bn"], plan["ka"],
                                    w_packed, streams, out_b)
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    wc = bk // 2 if w_packed else bk
    ws = (bk // GROUP) * (1 if w_packed else 4)
    inputs = (bm * bk                       # x codes (uint8)
              + bm * (bk // GROUP) * 4      # x scales (f32)
              + streams * (bn * wc          # w codes
                           + bn * ws        # w scales
                           + 4))            # tensor scale
    outputs = bm * bn * out_b
    if plan.get("kernel") == "nvfp4_gemm_swiglu":
        scratch = 2 * bm * bn * 4           # gate + up f32 accumulators
    else:
        scratch = bm * bn * 4 if plan["path"] == "decode_fast" else 0
    return 2 * (inputs + outputs) + scratch


swiglu_vmem_bytes = gemm_vmem_bytes


def _pad_rows(a: jax.Array, rows: int) -> jax.Array:
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


def _resolve_resident(plan: dict, resident: bool | None) -> bool:
    if resident is None:
        return bool(plan["residency"])
    if resident and plan["path"] != "decode_fast":
        raise ValueError(
            f"resident schedule requires the decode fast path (single M "
            f"tile); got path={plan['path']!r} for m={plan['m']}")
    return bool(resident)


@functools.partial(jax.jit,
                   static_argnames=("w_packed", "block_m", "block_n",
                                    "block_k", "interpret", "resident"))
def nvfp4_gemm(x_codes: jax.Array, x_scales: jax.Array,
               w_codes: jax.Array, w_scales: jax.Array,
               w_tensor_scale: jax.Array | None = None,
               w_packed: bool = False,
               block_m: int = 256, block_n: int = 256, block_k: int = 2048,
               interpret: bool = False,
               bias: jax.Array | None = None,
               resident: bool | None = None) -> jax.Array:
    """(M, Ka) x (N, Ka) -> (M, N) f32. Ka includes the S augmented channels.

    Unpacked weights: ``w_codes`` (N, Ka) uint8, ``w_scales`` (N, Ka/16) f32
    effective scales. Packed weights (``w_packed=True``): ``w_codes``
    (N, Ka/2) uint8 byte pairs, ``w_scales`` (N, Ka/16) uint8 E4M3 codes,
    ``w_tensor_scale`` the FP32 per-tensor scale they are relative to.

    ``bias`` (N,) is added to the f32 accumulator inside the out-tile
    store (bitwise equal to ``out + bias`` outside, one HBM round trip
    cheaper). ``resident`` forces the decode resident schedule on/off;
    None defers to ``plan["residency"]`` (fits-in-VMEM auto).
    """
    m, ka = x_codes.shape
    n = w_codes.shape[0]
    ka2 = w_codes.shape[1] * 2 if w_packed else w_codes.shape[1]
    assert ka == ka2 and ka % GROUP == 0, (ka, ka2)
    if w_packed:
        assert w_tensor_scale is not None, "packed weights need tensor scale"
    wt = (jnp.asarray(w_tensor_scale, jnp.float32).reshape(1)
          if w_tensor_scale is not None else jnp.ones((1,), jnp.float32))

    plan = gemm_plan(m, n, ka, block_m, block_n, block_k, w_packed=w_packed)
    use_resident = _resolve_resident(plan, resident)
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    mp, np_ = plan["mp"], plan["np"]
    nk = ka // bk

    x_codes = _pad_rows(x_codes, mp)
    x_scales = _pad_rows(x_scales, mp)
    w_codes = _pad_rows(w_codes, np_)
    w_scales = _pad_rows(w_scales, np_)

    has_bias = bias is not None
    operands = [x_codes, x_scales, w_codes, w_scales, wt]
    if has_bias:
        b = jnp.asarray(bias, jnp.float32).reshape(n)
        operands.append(jnp.pad(b, (0, np_ - n)))

    wc_cols = bk // 2 if w_packed else bk
    wt_spec = pl.BlockSpec((1,), lambda *_: (0,))

    if use_resident:
        kernel = functools.partial(_gemm_kernel_decode_resident, w_packed,
                                   bk, nk, has_bias)
        wc_full = ka // 2 if w_packed else ka
        in_specs = [
            pl.BlockSpec((bm, ka), lambda j: (0, 0)),
            pl.BlockSpec((bm, ka // GROUP), lambda j: (0, 0)),
            pl.BlockSpec((bn, wc_full), lambda j: (j, 0)),
            pl.BlockSpec((bn, ka // GROUP), lambda j: (j, 0)),
            wt_spec,
        ]
        if has_bias:
            in_specs.append(pl.BlockSpec((bn,), lambda j: (j,)))
        out = pl.pallas_call(
            kernel,
            grid=(np_ // bn,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda j: (0, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, ka), jnp.float32)],
            interpret=interpret,
        )(*operands)
    elif plan["path"] == "decode_fast":
        kernel = functools.partial(_gemm_kernel_decode, w_packed, bk, nk,
                                   has_bias)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bm, bk // GROUP), lambda j, k: (0, k)),
            pl.BlockSpec((bn, wc_cols), lambda j, k: (j, k)),
            pl.BlockSpec((bn, bk // GROUP), lambda j, k: (j, k)),
            wt_spec,
        ]
        if has_bias:
            in_specs.append(pl.BlockSpec((bn,), lambda j, k: (j,)))
        out = pl.pallas_call(
            kernel,
            grid=(np_ // bn, nk),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda j, k: (0, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(*operands)
    else:
        kernel = functools.partial(_gemm_kernel, w_packed, bk, nk, has_bias)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, wc_cols), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk // GROUP), lambda i, j, k: (j, k)),
            wt_spec,
        ]
        if has_bias:
            in_specs.append(pl.BlockSpec((bn,), lambda i, j, k: (j,)))
        out = pl.pallas_call(
            kernel,
            grid=(mp // bm, np_ // bn, nk),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(*operands)
    return out[:m, :n]


@functools.partial(jax.jit,
                   static_argnames=("w_packed", "block_m", "block_n",
                                    "block_k", "out_dtype", "interpret",
                                    "resident"))
def nvfp4_gemm_swiglu(x_codes: jax.Array, x_scales: jax.Array,
                      g_codes: jax.Array, g_scales: jax.Array,
                      u_codes: jax.Array, u_scales: jax.Array,
                      g_tensor_scale: jax.Array | None = None,
                      u_tensor_scale: jax.Array | None = None,
                      w_packed: bool = False,
                      block_m: int = 256, block_n: int = 256,
                      block_k: int = 2048,
                      out_dtype=jnp.float32,
                      interpret: bool = False,
                      resident: bool | None = None) -> jax.Array:
    """Fused gate/up MLP GEMM: (M, Ka) x 2x(F, Ka) -> silu(g) * u (M, F).

    Both weight operands are decoded against ONE activation tile per grid
    step (the unfused path reads the quantized activations twice), and the
    ``silu(g) * u`` product is computed on the VMEM accumulators in
    ``out_dtype`` before the single HBM store — the intermediate (M, F)
    gate and up tensors never round-trip through HBM. Bitwise equal to
    ``silu(gemm(x, g).astype(out_dtype)) * gemm(x, u).astype(out_dtype)``
    because the per-tile f32 accumulation order is identical.
    """
    m, ka = x_codes.shape
    n = g_codes.shape[0]
    assert g_codes.shape == u_codes.shape, (g_codes.shape, u_codes.shape)
    assert g_scales.shape == u_scales.shape, (g_scales.shape, u_scales.shape)
    ka2 = g_codes.shape[1] * 2 if w_packed else g_codes.shape[1]
    assert ka == ka2 and ka % GROUP == 0, (ka, ka2)
    if w_packed:
        assert g_tensor_scale is not None and u_tensor_scale is not None, \
            "packed weights need tensor scales"
    gt = (jnp.asarray(g_tensor_scale, jnp.float32).reshape(1)
          if g_tensor_scale is not None else jnp.ones((1,), jnp.float32))
    ut = (jnp.asarray(u_tensor_scale, jnp.float32).reshape(1)
          if u_tensor_scale is not None else jnp.ones((1,), jnp.float32))

    plan = swiglu_plan(m, n, ka, block_m, block_n, block_k,
                       w_packed=w_packed,
                       out_bytes=jnp.dtype(out_dtype).itemsize)
    use_resident = _resolve_resident(plan, resident)
    bm, bn, bk = plan["bm"], plan["bn"], plan["bk"]
    mp, np_ = plan["mp"], plan["np"]
    nk = ka // bk

    x_codes = _pad_rows(x_codes, mp)
    x_scales = _pad_rows(x_scales, mp)
    g_codes = _pad_rows(g_codes, np_)
    g_scales = _pad_rows(g_scales, np_)
    u_codes = _pad_rows(u_codes, np_)
    u_scales = _pad_rows(u_scales, np_)
    operands = [x_codes, x_scales, g_codes, g_scales, gt, u_codes,
                u_scales, ut]

    wc_cols = bk // 2 if w_packed else bk
    wt_spec = pl.BlockSpec((1,), lambda *_: (0,))

    if use_resident:
        kernel = functools.partial(_swiglu_kernel_decode_resident, w_packed,
                                   bk, nk, out_dtype)
        wc_full = ka // 2 if w_packed else ka
        w_specs = [
            pl.BlockSpec((bn, wc_full), lambda j: (j, 0)),
            pl.BlockSpec((bn, ka // GROUP), lambda j: (j, 0)),
            wt_spec,
        ]
        out = pl.pallas_call(
            kernel,
            grid=(np_ // bn,),
            in_specs=[
                pl.BlockSpec((bm, ka), lambda j: (0, 0)),
                pl.BlockSpec((bm, ka // GROUP), lambda j: (0, 0)),
                *w_specs, *w_specs,
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda j: (0, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, ka), jnp.float32)],
            interpret=interpret,
        )(*operands)
    else:
        # the generic dual-acc schedule doubles as the streamed decode
        # fast path when ni == 1 (it already stores once at the last k)
        kernel = functools.partial(_swiglu_kernel, w_packed, bk, nk,
                                   out_dtype)
        w_specs = [
            pl.BlockSpec((bn, wc_cols), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk // GROUP), lambda i, j, k: (j, k)),
            wt_spec,
        ]
        out = pl.pallas_call(
            kernel,
            grid=(mp // bm, np_ // bn, nk),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bm, bk // GROUP), lambda i, j, k: (i, k)),
                *w_specs, *w_specs,
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                            pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(*operands)
    return out[:m, :n]
