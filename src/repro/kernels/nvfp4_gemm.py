"""Pallas TPU kernel: unified NVFP4 GEMM over the augmented K+S dimension.

This is the TPU analogue of the paper's single CUTLASS GEMM call: both
operands arrive as 4-bit E2M1 codes + block scales; each (bm, bn, bk) tile
is dequantized in VMEM/VREGs and fed to the MXU with f32 accumulation. The
augmented residual channels (paper §3.2) ride the same K loop — no special
casing, which is exactly the paper's "unified GEMM execution" property.

Grid: (M/bm, N/bn, Ka/bk), k-innermost accumulation into the out tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common as C

GROUP = 16


def _gemm_kernel(xc_ref, xs_ref, wc_ref, ws_ref, out_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bm, bk = xc_ref.shape
    bn = wc_ref.shape[0]
    x = C.decode_e2m1(xc_ref[...]).reshape(bm, bk // GROUP, GROUP)
    x = (x * xs_ref[...].astype(jnp.float32)[..., None]).reshape(bm, bk)
    w = C.decode_e2m1(wc_ref[...]).reshape(bn, bk // GROUP, GROUP)
    w = (w * ws_ref[...].astype(jnp.float32)[..., None]).reshape(bn, bk)
    # MXU matmul in bf16 with f32 accumulation (TPU-native datapath)
    acc = jax.lax.dot_general(
        x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k",
                                    "interpret"))
def nvfp4_gemm(x_codes: jax.Array, x_scales: jax.Array,
               w_codes: jax.Array, w_scales: jax.Array,
               block_m: int = 256, block_n: int = 256, block_k: int = 2048,
               interpret: bool = False) -> jax.Array:
    """(M, Ka) x (N, Ka) -> (M, N) f32. Ka includes the S augmented channels."""
    m, ka = x_codes.shape
    n, ka2 = w_codes.shape
    assert ka == ka2 and ka % GROUP == 0

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, ka)
    while m % bm:
        bm //= 2
    while n % bn:
        bn //= 2
    while ka % bk:
        bk //= 2
    bk = max(bk, GROUP)
    grid = (m // bm, n // bn, ka // bk)

    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk // GROUP), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x_codes, x_scales, w_codes, w_scales)
