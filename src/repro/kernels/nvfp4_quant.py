"""Pallas TPU kernel: blockwise NVFP4 quantization (paper Eq. 1).

Tiles the activation matrix HBM->VMEM, computes per-16-element-block E4M3
scales against the per-tensor FP32 scale, and emits 4-bit E2M1 codes
(uint8 carrier) plus effective f32 scales. One HBM pass.

Grid: (M/bm, K/bk); blocks (bm, bk) with 16 | bk; scales tile (bm, bk/16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common as C

GROUP = 16


def _quant_kernel(ts_ref, x_ref, codes_ref, scales_ref):
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    t = ts_ref[0]
    xb = x.reshape(bm, bk // GROUP, GROUP)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = C.nvfp4_block_scales(amax, t)              # (bm, bk/16)
    y = xb / scale[..., None]
    codes = C.encode_e2m1(y).reshape(bm, bk)
    codes_ref[...] = codes
    scales_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def nvfp4_quantize(x: jax.Array, tensor_amax: jax.Array | None = None,
                   block_m: int = 256, block_k: int = 2048,
                   interpret: bool = False):
    """x: (M, K) -> (codes uint8 (M, K), scales f32 (M, K/16), tensor_scale).

    K must be a multiple of 16; tiles pad up to (block_m, block_k).
    """
    m, k = x.shape
    assert k % GROUP == 0, k
    if tensor_amax is None:
        tensor_amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    t = tensor_amax / (C.E2M1_MAX * C.E4M3_MAX)
    t = jnp.where(t > 0, t, 1.0).astype(jnp.float32)

    bm = min(block_m, m)
    bk = min(block_k, k)
    # shrink bk to a divisor-friendly tile
    while k % bk:
        bk //= 2
    while m % bm:
        bm //= 2
    bk = max(bk, GROUP)
    grid = (m // bm, k // bk)

    codes, scales = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // GROUP), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.uint8),
            jax.ShapeDtypeStruct((m, k // GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(t.reshape(1), x)
    return codes, scales, t
