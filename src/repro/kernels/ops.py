"""Jit'd public wrappers around the Pallas kernels.

``arc_linear`` composes the full paper pipeline: fused quantization of the
activations (RMSNorm + reorder + primary + residual, interleaved layout)
followed by the unified NVFP4 GEMM over K+S — one fused quant pass and one
stock GEMM call, exactly the deployment dataflow of Figure 4.

``quantize_weight_interleaved`` is the single source of truth for the
offline augmented-weight layout: every producer (the Pallas path here, the
QTensor carrier path in ``quant/apply.py``) interleaves through the same
``core.arc.interleaved_permutation``, so kernel and emulated consumers
agree bit-for-bit on where each primary/residual block lives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import quant as Q
from repro.core.arc import interleaved_permutation
from repro.kernels import ref
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import nvfp4_gemm
from repro.kernels.nvfp4_quant import nvfp4_quantize
from repro.kernels.paged_attention import paged_attention_decode

GROUP = 16


def paged_attention(q, kp, vp, posp, block_table, q_pos, active=None, *,
                    window=None, interpret=None):
    """Paged-attention decode step over a K/V page pool.

    The serving entry point ``models.layers.attention_layer`` dispatches
    here on the paged decode branch: the block table is walked inside
    the kernel (scalar-prefetch page indexing), so no ``(B, max_blocks *
    block_size)`` K/V view is ever materialized. ``interpret=None``
    auto-resolves (compiled on TPU, interpreter elsewhere) via
    ``common.resolve_interpret``. See ``kernels.paged_attention`` for
    the full contract.
    """
    return paged_attention_decode(q, kp, vp, posp, block_table, q_pos,
                                  active, window=window,
                                  interpret=interpret)


def quantize_weight_interleaved(w: jax.Array, order: jax.Array, s: int,
                                interpret: bool = False):
    """Offline weight path: reorder, quantize, duplicate outlier columns,
    emit the interleaved layout matching arc_fused_quantize's output."""
    wr = jnp.take(w, order, axis=-1)
    codes, scales, t = nvfp4_quantize(wr, interpret=interpret)
    if s == 0:
        return codes, scales
    k = w.shape[-1]
    perm = jnp.asarray(interleaved_permutation(k, s, GROUP))
    aug_c = jnp.concatenate([codes, codes[:, :s]], axis=-1)
    aug_s = jnp.concatenate([scales, scales[:, : s // GROUP]], axis=-1)
    inter_c = jnp.take(aug_c, perm, axis=-1)
    inter_s = jnp.take(aug_s, perm[::GROUP] // GROUP, axis=-1)
    return inter_c, inter_s


def qtensor_gemm_operands(w: Q.QTensor):
    """Map an offline-quantized weight QTensor (canonical interleaved
    layout for ARC) to ``nvfp4_gemm`` operands.

    Packed NVFP4 tensors feed the kernel directly (byte-pair codes + E4M3
    scale codes + FP32 tensor scale: decode happens in-kernel, HBM traffic
    stays at ~4.5 bits/value). Other storage re-derives unpacked codes and
    effective f32 scales on the fly.

    Returns (w_codes, w_scales, w_tensor_scale, w_packed).
    """
    if w.packed and w.fmt_name == "nvfp4":
        return w.elements, w.scales, w.tensor_scale, True
    if w.packed:
        return F.unpack_e2m1(w.elements), w.scale_values(), None, False
    return F.encode_e2m1(w.elements), w.scales, None, False


def arc_linear(x: jax.Array, gamma: jax.Array, order: jax.Array,
               w_codes: jax.Array, w_scales: jax.Array,
               tensor_scales: jax.Array, s: int,
               w_tensor_scale: jax.Array | None = None,
               w_packed: bool = False, apply_norm: bool = True,
               interpret: bool = False) -> jax.Array:
    """Full ARCQuant linear: fused-quant(x) -> unified GEMM. Returns f32.

    x: (M, K); w_codes/w_scales: interleaved offline weights (N, K+S...),
    unpacked or packed (see ``nvfp4_gemm``).
    """
    x_codes, x_scales = arc_fused_quantize(x, gamma, order, tensor_scales,
                                           s, apply_norm=apply_norm,
                                           interpret=interpret)
    return nvfp4_gemm(x_codes, x_scales, w_codes, w_scales,
                      w_tensor_scale=w_tensor_scale, w_packed=w_packed,
                      interpret=interpret)


def rtn_linear(x: jax.Array, w_codes: jax.Array, w_scales: jax.Array,
               interpret: bool = False) -> jax.Array:
    """Baseline: plain NVFP4 quantize + GEMM (no residual compensation)."""
    x_codes, x_scales, _ = nvfp4_quantize(x, interpret=interpret)
    return nvfp4_gemm(x_codes, x_scales, w_codes, w_scales,
                      interpret=interpret)
