"""Pure-jnp oracles for the Pallas kernels (bit-exact references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common as C

GROUP = 16


def ref_tensor_scale(tensor_amax):
    t = tensor_amax / (C.E2M1_MAX * C.E4M3_MAX)
    return jnp.where(t > 0, t, 1.0).astype(jnp.float32)


def ref_nvfp4_quantize(x: jax.Array, tensor_amax=None):
    """Oracle for nvfp4_quant: (codes, scales, tensor_scale)."""
    x = x.astype(jnp.float32)
    m, k = x.shape
    if tensor_amax is None:
        tensor_amax = jnp.max(jnp.abs(x))
    t = ref_tensor_scale(tensor_amax)
    xb = x.reshape(m, k // GROUP, GROUP)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scales = C.nvfp4_block_scales(amax, t)
    codes = C.encode_e2m1(xb / scales[..., None]).reshape(m, k)
    return codes, scales, t


def ref_dequantize(codes: jax.Array, scales: jax.Array) -> jax.Array:
    m, k = codes.shape
    v = C.decode_e2m1(codes).reshape(m, k // GROUP, GROUP)
    return (v * scales[..., None]).reshape(m, k)


def ref_nvfp4_gemm(x_codes, x_scales, w_codes, w_scales) -> jax.Array:
    """Oracle for nvfp4_gemm: dequantize then bf16 matmul, f32 accumulate."""
    x = ref_dequantize(x_codes, x_scales).astype(jnp.bfloat16)
    w = ref_dequantize(w_codes, w_scales).astype(jnp.bfloat16)
    return jnp.matmul(x, w.T, preferred_element_type=jnp.float32)


def ref_arc_fused(x, gamma, order, tensor_scales, s: int, eps: float = 1e-6,
                  apply_norm: bool = True):
    """Oracle for arc_fused_quantize (interleaved layout)."""
    x = x.astype(jnp.float32)
    m, k = x.shape
    if apply_norm:
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        xn = x * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    else:
        xn = x
    xr = jnp.take(xn, order, axis=1)
    t1, t2 = tensor_scales[0], tensor_scales[1]

    xb = xr.reshape(m, k // GROUP, GROUP)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scales = C.nvfp4_block_scales(amax, t1)
    codes = C.encode_e2m1(xb / scales[..., None]).reshape(m, k)
    if s == 0:
        return codes, scales

    deq = ref_dequantize(codes[:, :s], scales[:, : s // GROUP])
    r = xr[:, :s] - deq
    rb = r.reshape(m, s // GROUP, GROUP)
    ramax = jnp.max(jnp.abs(rb), axis=-1)
    rscales = C.nvfp4_block_scales(ramax, t2)
    rcodes = C.encode_e2m1(rb / rscales[..., None]).reshape(m, s)

    nb = s // GROUP
    inter_c = jnp.stack([codes[:, :s].reshape(m, nb, GROUP),
                         rcodes.reshape(m, nb, GROUP)], axis=2).reshape(m, 2 * s)
    inter_s = jnp.stack([scales[:, :nb], rscales], axis=2).reshape(m, 2 * nb)
    out_c = jnp.concatenate([inter_c, codes[:, s:]], axis=1)
    out_s = jnp.concatenate([inter_s, scales[:, nb:]], axis=1)
    return out_c, out_s
