"""Shared in-kernel NVFP4 arithmetic (E2M1 encode/decode, E4M3 scales).

Everything here is branch-free vector arithmetic (VPU-friendly): encode is
a comparison ladder with RNE tie handling, decode is exponent/mantissa
reconstruction — no table gathers, so the same code runs inside Pallas
kernel bodies and in the pure-jnp references.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# In-kernel 8-bit scale decode / byte-pair unpack for packed weights. Both
# core.formats implementations are gather-free (shifts, ldexp, where), so
# they run inside Pallas kernel bodies directly — imported rather than
# re-implemented, because packed-GEMM correctness depends on the decode
# being the exact inverse of the encoder in core.formats.
from repro.core.formats import decode_e4m3  # noqa: F401  (re-export)
from repro.core.formats import unpack_e2m1  # noqa: F401  (re-export)

E2M1_MAX = 6.0
E4M3_MAX = 448.0


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a kernel's interpret flag: ``None`` means *auto*.

    Auto compiles on TPU backends and falls back to interpreter mode (a
    bit-faithful, still-jittable jnp emulation) everywhere else, so a
    kernel that is always on the hot path — like the paged-attention
    decode kernel — runs under CPU CI without every caller having to
    thread an explicit flag. An explicit True/False always wins (the
    quantization kernels keep their opt-in ``interpret=True`` contract).
    """
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() != "tpu"

# decision thresholds between consecutive E2M1 magnitudes, and which ties
# round UP (to the even code): values 0/.5/1/1.5/2/3/4/6 -> midpoints
_THRESH = (0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0)
_TIE_UP = (0.75, 1.75, 3.5)   # ties landing on even codes 2, 4, 6


def encode_e2m1_mag(y):
    """|y| (clipped to [0, 6]) -> magnitude code 0..7, RNE at midpoints."""
    idx = jnp.zeros(y.shape, jnp.uint8)
    for t in _THRESH:
        idx = idx + (y > t).astype(jnp.uint8)
    for t in _TIE_UP:
        idx = idx + (y == t).astype(jnp.uint8)
    return idx


def encode_e2m1(x):
    """Signed value -> 4-bit code (sign<<3 | mag) as uint8."""
    y = jnp.clip(jnp.abs(x), 0.0, E2M1_MAX)
    mag = encode_e2m1_mag(y)
    sign = (x < 0).astype(jnp.uint8)
    return (sign << 3) | mag


def decode_e2m1(codes):
    """4-bit code -> f32 value, arithmetic reconstruction (no gathers)."""
    c = codes.astype(jnp.int32)
    mag = (c & 7).astype(jnp.float32)
    sign = 1.0 - 2.0 * ((c >> 3) & 1).astype(jnp.float32)
    e = jnp.floor(mag / 2.0)                       # 0..3
    m = mag - 2.0 * e                              # 0 or 1
    sub = mag * 0.5                                # codes 0,1 -> 0, 0.5
    val = jnp.where(mag < 2.0, sub,
                    (1.0 + 0.5 * m) * jnp.ldexp(jnp.float32(1.0),
                                                e.astype(jnp.int32) - 1))
    return sign * val


def round_e4m3(v):
    """Round positive scale values to E4M3 (RNE, saturating, subnormals)."""
    v = jnp.asarray(v, jnp.float32)
    _, ef = jnp.frexp(jnp.where(v > 0, v, 1.0))   # bit-exact exponent
    e = jnp.maximum((ef - 1).astype(jnp.float32), -6.0)
    step = jnp.ldexp(jnp.float32(1.0), (e - 3.0).astype(jnp.int32))
    q = jnp.round(v / step) * step
    q = jnp.minimum(q, E4M3_MAX)
    return jnp.where(v > 0, jnp.maximum(q, jnp.float32(2.0 ** -9)), 0.0)


def nvfp4_block_scales(amax, tensor_scale):
    """Effective per-block scale = e4m3(amax / 6 / t) * t, clamped to the
    smallest E4M3 subnormal (matches core.quant.compute_scales)."""
    raw = amax / E2M1_MAX / tensor_scale
    q = round_e4m3(raw)
    q = jnp.maximum(q, jnp.float32(2.0 ** -9))
    return q * tensor_scale
