"""Pallas TPU kernel: the paper's Fused Quantization Kernel (§3.3, App. D).

Fuses, in one HBM pass over the activation rows:
    RMSNorm -> channel reorder -> primary NVFP4 quantization
            -> residual quantization of the top-S outlier channels
            -> write-out in the Interleaved Channel Layout
       [P0 | R0 | P1 | R1 | ... | P_{S/16-1} | R_{S/16-1} | P_{S/16} ...]
so the downstream GEMM consumes a strictly-NVFP4 augmented tensor with
16-block-aligned scales (the TPU analogue of the CUDA kernel's
coalesced interleaved write-back).

Per-tensor scales (primary + residual) are calibration-time constants, as
in the deployed paper configuration — computing them online would need a
second pass over X.

Grid: (M/bm,); x block (bm, K) resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common as C

GROUP = 16


def _quant_block(xr, t):
    """(bm, K) -> codes (bm, K) uint8, scales (bm, K/16) f32."""
    bm, k = xr.shape
    xb = xr.reshape(bm, k // GROUP, GROUP)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = C.nvfp4_block_scales(amax, t)
    codes = C.encode_e2m1(xb / scale[..., None]).reshape(bm, k)
    return codes, scale


def _fused_kernel(s, eps, apply_norm, order_ref, ts_ref, x_ref, gamma_ref,
                  codes_ref, scales_ref):
    x = x_ref[...].astype(jnp.float32)
    bm, k = x.shape
    if apply_norm:
        # RMSNorm fused into the quantization pass (one HBM read of x)
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        xn = x * jax.lax.rsqrt(var + eps) * gamma_ref[...].astype(jnp.float32)
    else:
        # pre-normalized input (e.g. wo / w_down projections)
        xn = x
    # channel reorder (outliers first)
    xr = jnp.take(xn, order_ref[...], axis=1)
    t1, t2 = ts_ref[0], ts_ref[1]
    codes, scales = _quant_block(xr, t1)

    if s == 0:
        codes_ref[...] = codes
        scales_ref[...] = scales
        return

    # residual of the first S channels: r = x_o - dq(Q(x_o))
    deq = (C.decode_e2m1(codes[:, :s]).reshape(bm, s // GROUP, GROUP)
           * scales[:, : s // GROUP, None]).reshape(bm, s)
    r = xr[:, :s] - deq
    rcodes, rscales = _quant_block(r, t2)

    # interleaved layout: [P0 R0 P1 R1 ... | P_{S/16}...]
    nb = s // GROUP
    pc = codes[:, :s].reshape(bm, nb, GROUP)
    rc = rcodes.reshape(bm, nb, GROUP)
    inter_c = jnp.stack([pc, rc], axis=2).reshape(bm, 2 * s)
    ps = scales[:, :nb]
    rs = rscales
    inter_s = jnp.stack([ps, rs], axis=2).reshape(bm, 2 * nb)

    codes_ref[...] = jnp.concatenate([inter_c, codes[:, s:]], axis=1)
    scales_ref[...] = jnp.concatenate([inter_s, scales[:, nb:]], axis=1)


def fused_quant_plan(m: int, k: int, s: int, block_m: int = 128,
                     x_bytes: int = 4) -> dict:
    """Static schedule + VMEM estimate for one fused-quantize launch
    (no tracing). Mirrors the BlockSpecs in :func:`arc_fused_quantize` —
    update both together. In/out blocks are double-buffered (x2);
    ``x_bytes`` is the activation element width (4 for the f32 datapath).
    """
    bm = max(min(block_m, -(-m // 8) * 8), 8)
    mp = -(-m // bm) * bm
    ka = k + s
    inputs = (k * 4                         # channel order (i32)
              + 2 * 4                       # tensor scales (f32)
              + bm * k * x_bytes            # x block
              + k * x_bytes)                # gamma
    outputs = (bm * ka                      # codes (uint8)
               + bm * (ka // GROUP) * 4)    # scales (f32)
    return {
        "bm": bm, "mp": mp, "ka": ka, "grid": (mp // bm,),
        "vmem_bytes": 2 * (inputs + outputs),
    }


@functools.partial(jax.jit, static_argnames=("s", "eps", "block_m",
                                             "apply_norm", "interpret"))
def arc_fused_quantize(x: jax.Array, gamma: jax.Array, order: jax.Array,
                       tensor_scales: jax.Array, s: int,
                       eps: float = 1e-6, block_m: int = 128,
                       apply_norm: bool = True,
                       interpret: bool = False):
    """x: (M, K); order: (K,) i32; tensor_scales: (2,) f32 = (primary, residual).

    Returns (codes uint8 (M, K+S), scales f32 (M, (K+S)/16)) in the
    interleaved channel layout. ``apply_norm=False`` skips the fused
    RMSNorm (for linears whose input is not the residual-stream norm, e.g.
    attention-output and down projections); ``gamma`` is then ignored.

    One launch covers every row of ``x`` — the serving engine flattens all
    active decode slots into M so a decode tick quantizes the whole batch
    in a single fused pass. Ragged M pads up to the sublane tile (padded
    rows quantize zeros and are sliced away) instead of shrinking the block
    below hardware granularity.
    """
    m, k = x.shape
    assert k % GROUP == 0 and s % GROUP == 0 and s <= k
    bm = max(min(block_m, -(-m // 8) * 8), 8)
    mp = -(-m // bm) * bm
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    ka = k + s
    grid = (mp // bm,)

    kernel = functools.partial(_fused_kernel, s, eps, apply_norm)
    codes, scales = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, ka), lambda i: (i, 0)),
            pl.BlockSpec((bm, ka // GROUP), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, ka), jnp.uint8),
            jax.ShapeDtypeStruct((mp, ka // GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(order, tensor_scales, x, gamma)
    return codes[:m], scales[:m]
