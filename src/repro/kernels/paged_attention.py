"""Pallas paged-attention decode kernel (vLLM-style block-table walk).

One decode step of paged attention reads, per batch row, the K/V the
row's block table points at. The jnp fallback materializes that logical
view with a host-side gather — ``kp[block_table]`` builds a
``(B, max_blocks * block_size)`` copy of every resident token before a
single score is computed, undoing the paged pool's memory win on the
hot path. This kernel never builds that view: the grid walks
``(batch row, logical block)`` cells, the block table rides in as a
*scalar-prefetch* operand so each cell's ``BlockSpec`` index map selects
the physical page to stream from HBM into VMEM, and an online-softmax
accumulator in VMEM scratch carries the running ``(max, sum, weighted
V)`` across a row's pages. Per tick the kernel therefore moves exactly
the pages the tables name — HBM traffic is O(resident tokens), with no
``(B, nblocks*bs)`` intermediate in the HLO.

Masking matches ``models.layers.chunked_attention`` (the gather-path
oracle) exactly:

  * position masking is driven by the pool's ``posp`` leaf: a slot is
    attended iff ``0 <= kv_pos <= q_pos`` (and inside ``window`` when
    set), so null-page entries (pos stays -1) and recycled pages'
    unwritten tails contribute nothing;
  * rows with ``q_pos < 0`` (inactive slots) and rows at or beyond the
    traced ``active`` count (ragged padding under dynamic valid-row
    masking) skip all compute and emit zeros — ``active`` is a traced
    scalar, so any active-request count reuses one trace;
  * GQA folds query heads as ``(Hkv, rep)`` groups against shared K/V
    heads, the same head grouping as the oracle.

Probabilities are masked multiplicatively (``p = where(valid, p, 0)``)
rather than relying on ``exp(NEG_INF - m)`` underflow, so a fully
masked page is an exact no-op on the accumulator regardless of the
running max. Rows that attend nothing finish with ``l == 0`` and emit
zeros, mirroring the oracle's ``where(l > 0, acc / l, 0)``.

``interpret=None`` resolves via :func:`repro.kernels.common.
resolve_interpret`: compiled on TPU, interpreter (bit-faithful jnp
emulation, still jittable) everywhere else — the CI configuration.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret

NEG_INF = -1e30     # matches models.layers.chunked_attention


def _decode_kernel(table_ref, qpos_ref, active_ref,   # scalar prefetch
                   q_ref, k_ref, v_ref, pos_ref,       # VMEM blocks
                   o_ref, acc_ref, m_ref, l_ref,       # output + scratch
                   *, rep: int, nblocks: int, scale: float,
                   window: Optional[int]):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qpos_ref[b]

    # dynamic valid-row masking: padding rows (beyond the traced active
    # count) and inactive rows (q_pos < 0) never touch the accumulator
    @pl.when((qp >= 0) & (b < active_ref[0]))
    def _attend_page():
        q = q_ref[0].astype(jnp.float32)                 # (Hq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bs, Hkv, D)
        v = v_ref[0].astype(jnp.float32)                 # (bs, Hkv, D)
        pos = pos_ref[0]                                 # (bs,)
        hq, hd = q.shape
        hkv = k.shape[1]
        q3 = q.reshape(hkv, rep, hd)                     # GQA head groups
        # s[h, r, t] = q[h, r, :] . k[t, h, :]  (f32 accumulation)
        s = jax.lax.dot_general(
            q3, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale  # (Hkv, rep, bs)
        valid = (pos >= 0) & (pos <= qp)
        if window is not None:
            valid &= (qp - pos) < window
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # multiplicative masking: a fully masked page contributes exactly
        # nothing even while the running max is still NEG_INF
        p = jnp.where(valid[None, None, :],
                      jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
        # pv[h, r, d] = sum_t p[h, r, t] * v[t, h, d]
        pv = jax.lax.dot_general(
            p, v.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv

    @pl.when(j == nblocks - 1)
    def _finish():
        l = l_ref[...]
        out = jnp.where(l[..., None] > 0,
                        acc_ref[...] / jnp.maximum(l, 1e-30)[..., None], 0.0)
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def paged_attention_plan(batch: int, hq: int, hd: int, hkv: int,
                         block_size: int, nblocks: int,
                         dtype_bytes: int = 2) -> dict:
    """Static schedule + VMEM estimate for one decode launch (no
    tracing). Mirrors the grid spec in :func:`paged_attention_decode` —
    update both together. In/out blocks are double-buffered (x2); the
    online-softmax accumulator scratch is single-buffered; the scalar-
    prefetch operands (block table, positions, active) live in SMEM and
    are reported separately.
    """
    rep = hq // hkv
    inputs = (hq * hd * dtype_bytes                 # q block
              + 2 * block_size * hkv * hd * dtype_bytes   # k + v page
              + block_size * 4)                     # page positions (i32)
    outputs = hq * hd * dtype_bytes
    scratch = (hkv * rep * hd + 2 * hkv * rep) * 4  # acc + max + sum (f32)
    return {
        "grid": (batch, nblocks),
        "vmem_bytes": 2 * (inputs + outputs) + scratch,
        "smem_bytes": batch * nblocks * 4 + batch * 4 + 4,
    }


def paged_attention_decode(q: jax.Array, kp: jax.Array, vp: jax.Array,
                           posp: jax.Array, block_table: jax.Array,
                           q_pos: jax.Array,
                           active: Optional[jax.Array] = None, *,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """One paged-attention decode step; pages streamed via the table.

    q: (B, Hq, D) this step's query (one token per row); kp/vp:
    (num_pages, block_size, Hkv, D) page-pool K/V; posp: (num_pages,
    block_size) absolute positions (-1 = unwritten); block_table:
    (B, max_blocks) physical page ids (unallocated entries must name the
    null page 0); q_pos: (B,) absolute query positions (-1 = inactive
    row); active: traced scalar — rows at index >= active are padding
    and emit zeros (defaults to B, i.e. every row live). Returns
    (B, Hq, D) in q's dtype.
    """
    B, hq, hd = q.shape
    _, bs, hkv, _ = kp.shape
    nblocks = block_table.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    if active is None:
        active = jnp.int32(B)
    active = jnp.asarray(active, jnp.int32).reshape(1)
    table = block_table.astype(jnp.int32)
    qpos = q_pos.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, rep=rep, nblocks=nblocks,
                               scale=1.0 / math.sqrt(hd), window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, nblocks),
        in_specs=[
            pl.BlockSpec((1, hq, hd), lambda b, j, t, qp, a: (b, 0, 0)),
            pl.BlockSpec((1, bs, hkv, hd),
                         lambda b, j, t, qp, a: (t[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, hd),
                         lambda b, j, t, qp, a: (t[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs), lambda b, j, t, qp, a: (t[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, hd), lambda b, j, t, qp, a: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, rep, hd), jnp.float32),    # acc
            pltpu.VMEM((hkv, rep), jnp.float32),        # running max
            pltpu.VMEM((hkv, rep), jnp.float32),        # running sum
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, hq, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(table, qpos, active, q, kp, vp, posp)
