"""Data pipeline: deterministic synthetic LM streams + calibration sets.

No external datasets ship in this container, so the corpus is a synthetic
Zipf-Markov language: Zipfian unigram marginals (vocab ranks follow real
text) with a low-rank Markov kernel so sequences carry learnable structure
(a trained model reaches materially lower PPL than the unigram entropy
floor, giving the PTQ accuracy benchmarks a meaningful signal to degrade).

The stream is stateful and checkpointable: ``state_dict``/``load_state``
round-trips through the training checkpoint so restarts are bit-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np


@dataclasses.dataclass
class TokenStream:
    """Deterministic, seekable synthetic token stream."""

    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    n_states: int = 64          # latent Markov states
    step: int = 0               # batches served (checkpoint state)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        base = ranks ** (-self.zipf_a)
        base /= base.sum()
        # per-latent-state emission: permute ranks only within windows of 16,
        # so states differ while the corpus marginal stays Zipf-shaped
        def windowed_perm():
            p = np.arange(v)
            for i in range(0, v - 16 + 1, 16):
                p[i:i + 16] = rng.permutation(p[i:i + 16])
            return p
        self._emit_perm = np.stack(
            [windowed_perm() for _ in range(self.n_states)])
        self._base = base
        # sticky latent transitions
        trans = rng.dirichlet(np.full(self.n_states, 0.3), self.n_states)
        self._trans = 0.7 * np.eye(self.n_states) + 0.3 * trans
        self._cum_emit = np.cumsum(base)

    def _sample_batch(self, rng: np.random.Generator, batch: int,
                      seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int64)
        state = rng.integers(0, self.n_states, batch)
        for t in range(seq):
            u = rng.random(batch)
            tok_rank = np.searchsorted(self._cum_emit, u)
            tok_rank = np.minimum(tok_rank, self.vocab_size - 1)
            out[:, t] = self._emit_perm[state, tok_rank]
            nxt = rng.random(batch)
            cum = np.cumsum(self._trans[state], axis=1)
            state = (cum < nxt[:, None]).sum(axis=1)
            state = np.minimum(state, self.n_states - 1)
        return out

    def batches(self, batch: int, seq: int) -> Iterator[np.ndarray]:
        while True:
            rng = np.random.default_rng((self.seed, self.step))
            # advance the cursor *before* yielding so state_dict() taken
            # after consuming N batches resumes at batch N (exactly-once)
            self.step += 1
            yield self._sample_batch(rng, batch, seq).astype(np.int32)

    # -- checkpointable state ------------------------------------------
    def state_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    def load_state(self, state: Dict) -> None:
        assert state["seed"] == self.seed, "stream seed mismatch"
        self.step = int(state["step"])


@dataclasses.dataclass
class SyntheticLM:
    """Convenience bundle: train/eval/calibration splits with separate seeds."""

    vocab_size: int
    seed: int = 0

    def train_stream(self) -> TokenStream:
        return TokenStream(self.vocab_size, seed=self.seed)

    def eval_batches(self, batch: int, seq: int, n: int) -> List[np.ndarray]:
        ts = TokenStream(self.vocab_size, seed=self.seed + 10_000)
        it = ts.batches(batch, seq)
        return [next(it) for _ in range(n)]

    def calibration_batches(self, batch: int, seq: int, n: int,
                            seed_offset: int = 20_000) -> List[np.ndarray]:
        """Paper App. B: 128 x 2048-token calibration segments (scaled down).
        Different seed_offset values emulate different calibration corpora
        (WikiText2 / C4 / HumanEval) for the robustness ablation."""
        ts = TokenStream(self.vocab_size, seed=self.seed + seed_offset)
        it = ts.batches(batch, seq)
        return [next(it) for _ in range(n)]


@dataclasses.dataclass
class CalibrationSet:
    batches: List[np.ndarray]
    name: str = "synthetic-wikitext2"


def make_calibration_set(vocab_size: int, n_samples: int = 16,
                         seq_len: int = 256, seed: int = 0,
                         corpus: str = "wikitext2") -> CalibrationSet:
    offsets = {"wikitext2": 20_000, "c4": 30_000, "humaneval": 40_000}
    data = SyntheticLM(vocab_size, seed)
    batches = data.calibration_batches(4, seq_len, max(1, n_samples // 4),
                                       seed_offset=offsets[corpus])
    return CalibrationSet(batches=batches, name=f"synthetic-{corpus}")
