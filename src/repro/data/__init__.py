from repro.data.pipeline import (CalibrationSet, SyntheticLM, TokenStream,
                                 make_calibration_set)

__all__ = ["CalibrationSet", "SyntheticLM", "TokenStream",
           "make_calibration_set"]
