from repro.serving.backend import PagedBackend, SlotBackend
from repro.serving.cache_manager import PagedCacheManager, SlotCacheManager
from repro.serving.core import EngineCore, EngineFns, EngineStats
from repro.serving.engine import (PagedServingEngine, ServingEngine,
                                  StaticBatchEngine)
from repro.serving.faults import FaultInjectedError, FaultInjector
from repro.serving.request import (FINISH_EOS, FINISH_LENGTH, CapacityError,
                                   FinishReason, GenerationRequest,
                                   QueueFullError, Request, RequestOutput,
                                   RequestState, SamplingParams, StepOutput)
from repro.serving.scheduler import (DECODE, DONE, FREE, PREFILL, Scheduler,
                                     Slot)

__all__ = ["CapacityError", "DECODE", "DONE", "EngineCore", "EngineFns",
           "EngineStats", "FINISH_EOS", "FINISH_LENGTH", "FREE",
           "FaultInjectedError", "FaultInjector", "FinishReason",
           "GenerationRequest", "PREFILL", "PagedBackend",
           "PagedCacheManager", "PagedServingEngine", "QueueFullError",
           "Request", "RequestOutput", "RequestState", "SamplingParams",
           "Scheduler", "ServingEngine", "SlotCacheManager", "Slot",
           "StaticBatchEngine", "StepOutput"]
