from repro.serving.cache_manager import SlotCacheManager
from repro.serving.engine import (EngineStats, Request, ServingEngine,
                                  StaticBatchEngine)
from repro.serving.scheduler import (DECODE, DONE, FREE, PREFILL, Scheduler,
                                     Slot)

__all__ = ["DECODE", "DONE", "EngineStats", "FREE", "PREFILL", "Request",
           "Scheduler", "ServingEngine", "SlotCacheManager", "Slot",
           "StaticBatchEngine"]
