from repro.serving.cache_manager import PagedCacheManager, SlotCacheManager
from repro.serving.engine import (EngineStats, PagedServingEngine, Request,
                                  ServingEngine, StaticBatchEngine)
from repro.serving.scheduler import (DECODE, DONE, FREE, PREFILL, Scheduler,
                                     Slot)

__all__ = ["DECODE", "DONE", "EngineStats", "FREE", "PREFILL",
           "PagedCacheManager", "PagedServingEngine", "Request", "Scheduler",
           "ServingEngine", "SlotCacheManager", "Slot", "StaticBatchEngine"]
