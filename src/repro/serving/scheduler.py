"""Continuous-batching request scheduler (Python-side, shape-free).

The scheduler owns the dynamic state the jitted model functions must not
see: the FIFO admission queue and the per-slot lifecycle

    FREE -> PREFILL -> DECODE -> DONE -> FREE
       ^         |        |
       +---------+--------+   (preempt: back to the queue front)

Between decode steps the engine asks for ``admissions()`` — queued
requests paired with FREE slots — prefills each one into its cache row,
then runs one batched decode step over every DECODE slot. With chunked
prefill a slot *stays* in PREFILL across ticks while its prompt is fed
in ``prefill_chunk``-token slices (``Slot.prefill_pos`` is the prompt
cursor, ``Slot.prefill_cache`` the partial batch-1 cache the engine
threads through the chunk passes), so a long prompt no longer serializes
its whole prefill in front of one tick's decode. Finished requests (EOS
or per-request ``max_new_tokens``) move their slot through DONE back to
FREE, so the next queued request takes the row over without waiting for
the rest of the batch.

All bookkeeping here is plain Python over numpy token ids; nothing is
traced, so scheduling decisions never trigger recompilation. The
scheduler operates on engine-owned :class:`~repro.serving.request.
RequestState` objects; immutable inputs live on ``state.request``.
"""
from __future__ import annotations

from collections import deque
import dataclasses
from typing import Any, Deque, List, Optional, Tuple

from repro.serving.request import (CapacityError, FINISH_EOS, FINISH_LENGTH,
                                   FinishReason, RequestState)

# slot lifecycle states
FREE = "FREE"
PREFILL = "PREFILL"
DECODE = "DECODE"
DONE = "DONE"


@dataclasses.dataclass
class Slot:
    """One cache row's lifecycle state."""

    index: int
    state: str = FREE
    req: Optional[RequestState] = None
    next_pos: int = 0               # absolute position of next decode write
    last_token: int = 0             # token fed at the next decode step
    # chunked prefill: prompt tokens already fed, and the partial batch-1
    # cache the engine threads through the chunk passes (None once the
    # prefill is installed into the pool)
    prefill_pos: int = 0
    prefill_cache: Any = None

    def clear(self) -> None:
        self.req = None
        self.state = FREE
        self.next_pos = 0
        self.last_token = 0
        self.prefill_pos = 0
        self.prefill_cache = None


class Scheduler:
    """FIFO admission of queued requests into free cache slots."""

    def __init__(self, num_slots: int, max_len: int):
        self.slots = [Slot(i) for i in range(num_slots)]
        self.queue: Deque[RequestState] = deque()
        self.max_len = max_len
        self.step = 0               # engine tick clock

    # -- queue -------------------------------------------------------------

    def submit(self, state: RequestState) -> None:
        sp = state.sampling
        if sp.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "samples the first token)")
        if state.prompt_len + sp.max_new_tokens > self.max_len:
            raise CapacityError(
                f"request needs {state.prompt_len + sp.max_new_tokens}"
                f" cache positions but slots hold {self.max_len}")
        state.submit_step = self.step
        self.queue.append(state)

    def remove_queued(self, state: RequestState) -> None:
        """Drop a queued (or preempted-and-requeued) request from the
        admission queue — the abort/expiry path for not-resident requests."""
        try:
            self.queue.remove(state)
        except ValueError:
            raise KeyError(f"request {state.rid} is not queued") from None

    def admissions(self, can_admit=None) -> List[Tuple[Slot, RequestState]]:
        """Pair queued requests with FREE slots; marks them PREFILL.

        ``can_admit(state) -> bool`` gates each admission on resource
        availability — the paged backend's gate computes the request's
        cached-prefix length and checks free pages against only the
        *uncached* suffix (shared-prefix pages are reused ref-counted,
        not allocated), reserving as it approves. The queue stays
        strictly FIFO: when the head request cannot be admitted, nothing
        behind it jumps ahead.
        """
        out = []
        for slot in self.slots:
            if not self.queue:
                break
            if slot.state == FREE:
                if can_admit is not None and not can_admit(self.queue[0]):
                    break
                st = self.queue.popleft()
                st.admit_step = self.step
                slot.req = st
                slot.state = PREFILL
                slot.prefill_pos = 0
                slot.prefill_cache = None
                out.append((slot, st))
        return out

    # -- lifecycle ---------------------------------------------------------

    def record_token(self, slot: Slot, token: int) -> bool:
        """Append one generated token; returns True when the request ends.

        Called once after prefill (the token sampled from the last-prompt
        logits) and once per decode step. On completion the slot moves to
        DONE; the engine releases the cache row and calls ``free()``.
        """
        st = slot.req
        st.out_tokens.append(token)
        if st.first_token_step < 0:
            st.first_token_step = self.step
        sp = st.sampling
        hit_eos = sp.eos_token is not None and token == sp.eos_token
        if hit_eos or len(st.out_tokens) >= sp.max_new_tokens:
            st.done = True
            st.finish_reason = FINISH_EOS if hit_eos else FINISH_LENGTH
            st.finish_step = self.step
            slot.state = DONE
            return True
        if slot.state == PREFILL:       # first token -> start decoding
            slot.next_pos = st.prompt_len
        else:
            slot.next_pos += 1
        slot.last_token = token
        slot.state = DECODE
        return False

    def free(self, slot: Slot) -> None:
        """Return a DONE slot to FREE. Freeing a slot in any other state
        would silently corrupt bookkeeping (an in-flight request losing
        its row, a double free) — raise loudly instead."""
        if slot.state != DONE:
            raise RuntimeError(
                f"cannot free slot {slot.index} in state {slot.state}: only "
                f"DONE slots (finished requests) may be freed")
        slot.clear()

    def finish(self, slot: Slot, reason: FinishReason,
               error: Optional[str] = None) -> RequestState:
        """Terminate a resident request out-of-band (abort, deadline,
        capacity, poisoned row): stamp the state, move the slot to DONE.
        The engine releases the cache row/pages and calls ``free``."""
        if slot.state not in (PREFILL, DECODE):
            raise RuntimeError(
                f"cannot finish slot {slot.index} in state {slot.state}")
        st = slot.req
        st.done = True
        st.finish_reason = reason
        st.error = error
        st.finish_step = self.step
        slot.state = DONE
        return st

    def preempt(self, slot: Slot) -> RequestState:
        """Evict a request to reclaim its cache pages.

        The request returns to the *front* of the queue (FIFO order is
        preserved) keeping its generated tokens; re-admission prefills
        ``prompt + out_tokens[:-1]`` to rebuild the K/V it lost and then
        resumes decoding (``resume``) without re-sampling anything. With
        a ref-counted pool, eviction only *decrefs* the victim's pages —
        pages other sharers still reference (or that stay content-
        registered in the prefix cache) remain resident, so the resume
        prefill usually re-shares most of what was "lost" instead of
        recomputing it. A PREFILL-state victim (mid chunked prefill)
        simply discards its partial cache and re-prefills from the
        re-matched prefix boundary on re-admission.
        """
        assert slot.state in (DECODE, PREFILL), slot.state
        st = slot.req
        st.preemptions += 1
        self.queue.appendleft(st)
        slot.clear()
        return st

    def resume(self, slot: Slot) -> None:
        """Move a re-admitted (previously preempted) slot straight to
        DECODE: its next token was already sampled before eviction."""
        st = slot.req
        assert slot.state == PREFILL and st.out_tokens
        slot.next_pos = st.prompt_len + len(st.out_tokens) - 1
        slot.last_token = st.out_tokens[-1]
        slot.state = DECODE
        slot.prefill_pos = 0
        slot.prefill_cache = None

    # -- queries -----------------------------------------------------------

    def slot_of(self, rid: int) -> Optional[Slot]:
        """The slot currently holding request ``rid`` (None if the
        request is queued, finished, or unknown)."""
        for s in self.slots:
            if s.req is not None and s.req.rid == rid:
                return s
        return None

    def active(self) -> List[Slot]:
        return [s for s in self.slots if s.state == DECODE]

    def prefilling(self) -> List[Slot]:
        """Slots mid chunked prefill (PREFILL persisting across ticks)."""
        return [s for s in self.slots if s.state == PREFILL]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s.state != FREE for s in self.slots)

    def all_idle(self) -> bool:
        return all(s.state == FREE for s in self.slots)
