"""Continuous-batching request scheduler (Python-side, shape-free).

The scheduler owns the dynamic state the jitted model functions must not
see: the FIFO admission queue and the per-slot lifecycle

    FREE -> PREFILL -> DECODE -> DONE -> FREE
                 ^        |
                 +--------+   (preempt: back to the queue front)

Between decode steps the engine asks for ``admissions()`` — queued
requests paired with FREE slots — prefills each one into its cache row,
then runs one batched decode step over every DECODE slot. Finished
requests (EOS or per-request ``max_new_tokens``) move their slot through
DONE back to FREE, so the next queued request takes the row over without
waiting for the rest of the batch: no decode step is spent padding a
short request to its batch's slowest member.

All bookkeeping here is plain Python over numpy token ids; nothing is
traced, so scheduling decisions never trigger recompilation.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

# slot lifecycle states
FREE = "FREE"
PREFILL = "PREFILL"
DECODE = "DECODE"
DONE = "DONE"


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    temperature: float = 0.0            # 0 -> greedy
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # per-request metrics, in decode-step ticks of the engine clock
    submit_step: int = -1
    admit_step: int = -1
    finish_step: int = -1
    preemptions: int = 0                # times evicted to free cache pages

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def resume_prefill_len(self) -> int:
        """Tokens a (re-)admission must prefill: the prompt plus every
        generated token except the last, which is fed at the next decode
        step (fresh requests: just the prompt)."""
        return self.prompt_len + max(len(self.out_tokens) - 1, 0)

    @property
    def queue_wait_steps(self) -> int:
        return self.admit_step - self.submit_step

    @property
    def latency_steps(self) -> int:
        return self.finish_step - self.submit_step


@dataclasses.dataclass
class Slot:
    """One cache row's lifecycle state."""

    index: int
    state: str = FREE
    request: Optional[Request] = None
    next_pos: int = 0                   # absolute position of next decode write
    last_token: int = 0                 # token fed at the next decode step


class Scheduler:
    """FIFO admission of queued requests into free cache slots."""

    def __init__(self, num_slots: int, max_len: int):
        self.slots = [Slot(i) for i in range(num_slots)]
        self.queue: Deque[Request] = deque()
        self.max_len = max_len
        self.step = 0                   # decode-step clock

    # -- queue -------------------------------------------------------------

    def submit(self, request: Request) -> None:
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "samples the first token)")
        if request.prompt_len + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request needs {request.prompt_len + request.max_new_tokens}"
                f" cache positions but slots hold {self.max_len}")
        request.submit_step = self.step
        self.queue.append(request)

    def admissions(self, can_admit=None) -> List[Tuple[Slot, Request]]:
        """Pair queued requests with FREE slots; marks them PREFILL.

        ``can_admit(request) -> bool`` gates each admission on resource
        availability (the paged engine passes the free-page check). The
        queue stays strictly FIFO: when the head request cannot be
        admitted, nothing behind it jumps ahead.
        """
        out = []
        for slot in self.slots:
            if not self.queue:
                break
            if slot.state == FREE:
                if can_admit is not None and not can_admit(self.queue[0]):
                    break
                req = self.queue.popleft()
                req.admit_step = self.step
                slot.request = req
                slot.state = PREFILL
                out.append((slot, req))
        return out

    # -- lifecycle ---------------------------------------------------------

    def record_token(self, slot: Slot, token: int) -> bool:
        """Append one generated token; returns True when the request ends.

        Called once after prefill (the token sampled from the last-prompt
        logits) and once per decode step. On completion the slot moves to
        DONE; the engine releases the cache row and calls ``free()``.
        """
        req = slot.request
        req.out_tokens.append(token)
        hit_eos = req.eos_token is not None and token == req.eos_token
        if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
            req.done = True
            req.finish_step = self.step
            slot.state = DONE
            return True
        if slot.state == PREFILL:       # first token -> start decoding
            slot.next_pos = req.prompt_len
        else:
            slot.next_pos += 1
        slot.last_token = token
        slot.state = DECODE
        return False

    def free(self, slot: Slot) -> None:
        assert slot.state == DONE, slot.state
        slot.request = None
        slot.state = FREE
        slot.next_pos = 0
        slot.last_token = 0

    def preempt(self, slot: Slot) -> Request:
        """Evict a decoding request to reclaim its cache pages.

        The request returns to the *front* of the queue (FIFO order is
        preserved) keeping its generated tokens; re-admission prefills
        ``prompt + out_tokens[:-1]`` to rebuild the K/V it lost and then
        resumes decoding (``resume``) without re-sampling anything.
        """
        assert slot.state == DECODE, slot.state
        req = slot.request
        req.preemptions += 1
        self.queue.appendleft(req)
        slot.request = None
        slot.state = FREE
        slot.next_pos = 0
        slot.last_token = 0
        return req

    def resume(self, slot: Slot) -> None:
        """Move a re-admitted (previously preempted) slot straight to
        DECODE: its next token was already sampled before eviction."""
        req = slot.request
        assert slot.state == PREFILL and req.out_tokens
        slot.next_pos = req.prompt_len + len(req.out_tokens) - 1
        slot.last_token = req.out_tokens[-1]
        slot.state = DECODE

    # -- queries -----------------------------------------------------------

    def active(self) -> List[Slot]:
        return [s for s in self.slots if s.state == DECODE]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s.state != FREE for s in self.slots)

    def all_idle(self) -> bool:
        return all(s.state == FREE for s in self.slots)
