"""Batched serving engine over the ARCQuant quantized model.

Flow (paper Figure 5, deployment side):
  1. offline: calibrate -> plans -> quantize weights (packed NVFP4, ARC-
     augmented along K)
  2. prefill: batched prompt pass through the quantized model, fills the
     KV / recurrent-state caches
  3. decode: step loop — each step is ONE ``serve_step`` (fused online
     activation quantization + unified GEMMs), greedy or temperature
     sampling

The engine pads requests to a fixed batch (static shapes for jit) and
tracks per-request completion. Continuous batching at cluster scale slots
new requests into finished cache rows between steps — the cache layout
(batch-major, position-indexed) is chosen so that's a pure row overwrite.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import lm
from repro.models.lm import PlanBundle


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, qparams, cfg: ModelConfig, quant: QuantConfig,
                 plans: Optional[PlanBundle], batch_size: int = 4,
                 max_len: int = 512):
        self.qparams = qparams
        self.cfg = cfg
        self.quant = quant
        self.plans = plans
        self.batch_size = batch_size
        self.max_len = max_len

        def prefill(qp, cache, tokens, positions):
            logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                          positions=positions, cache=cache,
                                          quant=quant, plans=plans)
            return logits[:, -1], cache

        def decode(qp, cache, tokens, positions):
            logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                          positions=positions, cache=cache,
                                          quant=quant, plans=plans)
            nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
            return nxt.astype(jnp.int32), cache

        self._prefill = jax.jit(prefill, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in fixed-size batches."""
        for i in range(0, len(requests), self.batch_size):
            self._run_batch(requests[i:i + self.batch_size])
        return requests

    def _run_batch(self, batch: List[Request]) -> None:
        b = self.batch_size
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(batch):
            toks[j, plen - len(r.prompt):] = r.prompt     # left-pad
        cache = lm.init_cache(self.cfg, b, self.max_len)
        pos = np.broadcast_to(np.arange(plen), (b, plen)).astype(np.int32)
        _, cache = self._prefill(self.qparams, cache, jnp.asarray(toks),
                                 jnp.asarray(pos))
        last = jnp.asarray(toks[:, -1:])
        max_new = max(r.max_new_tokens for r in batch)
        for t in range(max_new):
            p = jnp.full((b, 1), plen + t, jnp.int32)
            nxt, cache = self._decode(self.qparams, cache, last, p)
            nxt_np = np.asarray(nxt)
            for j, r in enumerate(batch):
                if r.done or t >= r.max_new_tokens:
                    continue
                tok = int(nxt_np[j])
                r.out_tokens.append(tok)
                if r.eos_token is not None and tok == r.eos_token:
                    r.done = True
            last = nxt[:, None]
            if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                   for r in batch):
                break
        for r in batch:
            r.done = True
