"""Continuous-batching serving engine over the ARCQuant quantized model.

Flow (paper Figure 5, deployment side):
  1. offline: calibrate -> plans -> quantize weights (packed NVFP4, ARC-
     augmented along K)
  2. admission: each queued request is prefilled alone (exact prompt
     length, or a power-of-two bucket for pure-attention models) into a
     batch-1 cache whose row is then scattered into a free slot of the
     pooled cache (``SlotCacheManager``) — a pure row overwrite thanks to
     the batch-major, position-indexed cache layout
  3. decode: one batched ``_decode`` step per tick over every DECODE slot
     (fused online activation quantization + unified GEMMs), greedy or
     per-request temperature sampling at per-slot positions

The jitted functions are static-shaped — batch is always the full slot
count and scheduling state never enters a trace. The Python-side
``Scheduler`` swaps finished rows for queued requests *between* decode
steps (slot lifecycle FREE -> PREFILL -> DECODE -> DONE -> FREE), so a
short request's slot is reused immediately instead of idling as padding
until the batch's slowest member finishes. ``StaticBatchEngine`` keeps
the old gang-scheduled behavior (admission only when every slot is idle)
as the baseline that ``benchmarks/continuous_batching.py`` measures
padding waste against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FULL_ATTN, ModelConfig, QuantConfig
from repro.models import lm
from repro.models.lm import PlanBundle
from repro.serving.cache_manager import SlotCacheManager
from repro.serving.scheduler import Request, Scheduler

__all__ = ["EngineStats", "Request", "ServingEngine", "StaticBatchEngine"]


@dataclasses.dataclass
class EngineStats:
    """Aggregate serving metrics for one ``run`` call.

    ``slot_steps`` counts slot-rows swept by decode steps (steps x slots);
    ``useful_slot_steps`` counts the ones that emitted a token for a live
    request. Their gap is the padding waste continuous batching removes.
    """

    num_slots: int = 0
    decode_steps: int = 0
    slot_steps: int = 0
    useful_slot_steps: int = 0
    prefill_tokens: int = 0
    generated_tokens: int = 0
    wall_seconds: float = 0.0

    @property
    def padding_waste(self) -> float:
        if not self.slot_steps:
            return 0.0
        return 1.0 - self.useful_slot_steps / self.slot_steps

    @property
    def tokens_per_step(self) -> float:
        """Simulated throughput: generated tokens per batched decode step."""
        if not self.decode_steps:
            return 0.0
        return self.generated_tokens / self.decode_steps

    def summary(self) -> Dict[str, float]:
        return {
            "decode_steps": self.decode_steps,
            "generated_tokens": self.generated_tokens,
            "prefill_tokens": self.prefill_tokens,
            "padding_waste": round(self.padding_waste, 4),
            "tokens_per_step": round(self.tokens_per_step, 4),
            "wall_seconds": round(self.wall_seconds, 3),
            "wall_tokens_per_s": round(
                self.generated_tokens / self.wall_seconds, 2)
            if self.wall_seconds else 0.0,
        }


class ServingEngine:
    """Continuous-batching engine: ``batch_size`` slots over one cache pool."""

    continuous = True

    def __init__(self, qparams, cfg: ModelConfig, quant: QuantConfig,
                 plans: PlanBundle | None, batch_size: int = 4,
                 max_len: int = 512, seed: int = 0,
                 act_scale: str = "calibrated", backend: str | None = None,
                 interpret: bool | None = None):
        # activation FP32 scales must not see a request's batch company, or
        # swapping a finished slot for a new request would perturb every
        # other in-flight generation. "calibrated" (static per-layer scales
        # from the plan — the paper's App. D deployed config, and what the
        # fused Pallas kernel consumes) is batch-invariant by construction;
        # linears without calibrated scales fall back to per-token scales.
        quant = dataclasses.replace(quant, act_scale=act_scale)
        # kernel backend for deployed linears: "reference" (emulated GEMM)
        # or "pallas" (fused quant + packed NVFP4 GEMM); interpret=True
        # runs the Pallas kernels bit-faithfully on CPU.
        if backend is not None:
            quant = dataclasses.replace(quant, backend=backend)
        if interpret is not None:
            quant = dataclasses.replace(quant, interpret=interpret)
        self.qparams = qparams
        self.cfg = cfg
        self.quant = quant
        self.plans = plans
        self.batch_size = batch_size
        self.max_len = max_len
        self.seed = seed
        self.last_stats = EngineStats()
        # prompt-length bucketing pads prefill up to a power of two, which
        # bounds compile count. Right-padding is exact for full attention
        # (pad writes land at positions the causal mask hides and decode
        # later overwrites) but would pollute ring buffers and recurrent
        # state, so windowed/SSM/hybrid models prefill at exact length.
        self._bucket_prompts = all(m == FULL_ATTN for m in cfg.mixer_pattern)

        def prefill(qp, cache, tokens, positions, last_idx):
            logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                          positions=positions, cache=cache,
                                          quant=quant, plans=plans)
            return logits[0, last_idx], cache

        def decode(qp, cache, tokens, positions, temps, key):
            logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                          positions=positions, cache=cache,
                                          quant=quant, plans=plans)
            lg = logits[:, -1, : cfg.vocab_size].astype(jnp.float32)
            nxt = _sample_batch(lg, temps, key)
            return nxt, cache

        def sample(logits, temp, key):
            lg = logits[: cfg.vocab_size].astype(jnp.float32)
            return _sample_batch(lg[None], temp[None], key)[0]

        self._prefill = jax.jit(prefill, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._sample = jax.jit(sample)

    # -- public API --------------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests`` to completion; fills per-request metrics."""
        t0 = time.time()
        sched = Scheduler(self.batch_size, self.max_len)
        pool = SlotCacheManager(self.cfg, self.batch_size, self.max_len)
        stats = EngineStats(num_slots=self.batch_size)
        key = jax.random.PRNGKey(self.seed)
        for r in requests:
            sched.submit(r)

        B = self.batch_size
        while sched.has_work():
            # admission: continuous mode refills any free slot every tick;
            # the static baseline waits for the whole gang to drain
            if self.continuous or sched.all_idle():
                for slot, req in sched.admissions():
                    key, kp = jax.random.split(key)
                    logits, src = self._prefill_request(req, pool)
                    pool.write(slot.index, src)
                    tok = int(self._sample(
                        logits, jnp.float32(req.temperature), kp))
                    stats.prefill_tokens += req.prompt_len
                    if sched.record_token(slot, tok):
                        pool.release(slot.index)
                        sched.free(slot)

            active = sched.active()
            if not active:
                continue    # everything admitted finished at prefill

            last = np.zeros((B, 1), np.int32)
            pos = np.zeros((B, 1), np.int32)
            temps = np.zeros((B,), np.float32)
            for s in active:
                last[s.index, 0] = s.last_token
                pos[s.index, 0] = s.next_pos
                temps[s.index] = s.request.temperature
            key, kd = jax.random.split(key)
            nxt, pool.cache = self._decode(
                self.qparams, pool.cache, jnp.asarray(last), jnp.asarray(pos),
                jnp.asarray(temps), kd)
            nxt = np.asarray(nxt)

            sched.step += 1
            stats.decode_steps += 1
            stats.slot_steps += B
            stats.useful_slot_steps += len(active)
            for s in active:
                if sched.record_token(s, int(nxt[s.index])):
                    pool.release(s.index)
                    sched.free(s)

        stats.generated_tokens = sum(len(r.out_tokens) for r in requests)
        stats.wall_seconds = time.time() - t0
        self.last_stats = stats
        return requests

    # -- internals ---------------------------------------------------------

    def _prefill_request(self, req: Request, pool: SlotCacheManager):
        """Prefill one request alone; returns (last-prompt logits, cache)."""
        p = req.prompt_len
        plen = self._bucket_len(p) if self._bucket_prompts else p
        toks = np.zeros((1, plen), np.int32)
        toks[0, :p] = np.asarray(req.prompt, np.int32)
        positions = np.arange(plen, dtype=np.int32)[None]
        cache = pool.fresh_prefill_cache()
        return self._prefill(self.qparams, cache, jnp.asarray(toks),
                             jnp.asarray(positions), jnp.int32(p - 1))

    def _bucket_len(self, p: int) -> int:
        b = 16
        while b < p:
            b *= 2
        return min(b, self.max_len)


class StaticBatchEngine(ServingEngine):
    """Gang-scheduled baseline: a batch holds its slots until the slowest
    request finishes (the fixed-batch behavior this engine replaced)."""

    continuous = False


def _sample_batch(logits: jax.Array, temps: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Per-row greedy/temperature sampling. logits (B, V), temps (B,)."""
    greedy = jnp.argmax(logits, axis=-1)
    keys = jax.random.split(key, logits.shape[0])
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
