"""Continuous-batching serving engine over the ARCQuant quantized model.

Flow (paper Figure 5, deployment side):
  1. offline: calibrate -> plans -> quantize weights (packed NVFP4, ARC-
     augmented along K)
  2. admission: each queued request is prefilled alone (exact prompt
     length, or a power-of-two bucket for pure-attention models) into a
     batch-1 cache that is then installed into the pool — a slot-row
     overwrite (``SlotCacheManager``) or a page scatter through the
     request's block table (``PagedCacheManager``)
  3. decode: one batched ``_decode`` step per tick over every DECODE slot
     (fused online activation quantization + unified GEMMs), greedy or
     per-request temperature sampling at per-slot positions

The jitted functions are static-shaped and scheduling state never enters
a trace. The Python-side ``Scheduler`` swaps finished rows for queued
requests *between* decode steps (slot lifecycle FREE -> PREFILL ->
DECODE -> DONE -> FREE), so a short request's slot is reused immediately
instead of idling as padding until the batch's slowest member finishes.

Engines:
  * ``ServingEngine`` — continuous batching over the slot-row pool (every
    slot reserves ``max_len`` positions).
  * ``StaticBatchEngine`` — gang-scheduled baseline (admission only when
    every slot is idle); what ``benchmarks/continuous_batching.py``
    measures padding waste against.
  * ``PagedServingEngine`` — continuous batching over the paged K/V pool:
    admission is gated on free pages (FIFO head-of-line), the tail page
    is allocated on demand as decode crosses block boundaries, and when
    the pool runs dry the latest-admitted request is preempted (pages
    reclaimed, request re-queued at the front and later re-prefilled from
    its own tokens). Block tables ride into the jitted decode as a
    ``(batch, max_blocks)`` int32 input. With ``decode_buckets=True`` the
    decode batch is the active-request count rounded up to a power of two
    instead of the full slot count (ragged decode: compute scales with
    load; one retrace per bucket size).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FULL_ATTN, ModelConfig, QuantConfig
from repro.models import lm
from repro.models.lm import PlanBundle
from repro.serving.cache_manager import PagedCacheManager, SlotCacheManager
from repro.serving.scheduler import DECODE, Request, Scheduler, Slot

__all__ = ["EngineStats", "PagedServingEngine", "Request", "ServingEngine",
           "StaticBatchEngine"]


@dataclasses.dataclass
class EngineStats:
    """Aggregate serving metrics for one ``run`` call.

    ``slot_steps`` counts slot-rows swept by decode steps (steps x slots);
    ``useful_slot_steps`` counts the ones that emitted a token for a live
    request. Their gap is the padding waste continuous batching removes.
    ``generated_tokens`` splits into ``prefill_sampled_tokens`` (the token
    sampled from each admission's last-prompt logits — no decode step
    spent) and ``decode_tokens`` (one decode step each), so per-step
    throughput is not inflated by prefill-time samples.
    """

    num_slots: int = 0
    decode_steps: int = 0
    slot_steps: int = 0
    useful_slot_steps: int = 0
    prefill_tokens: int = 0
    generated_tokens: int = 0
    prefill_sampled_tokens: int = 0
    decode_tokens: int = 0
    wall_seconds: float = 0.0
    # paged-pool metrics (zero on the slot pool)
    num_pages: int = 0
    page_step_sum: int = 0              # sum over decode steps of pages in use
    peak_pages: int = 0
    preemptions: int = 0

    @property
    def padding_waste(self) -> float:
        if not self.slot_steps:
            return 0.0
        return 1.0 - self.useful_slot_steps / self.slot_steps

    @property
    def tokens_per_step(self) -> float:
        """Decode throughput: decode-generated tokens per batched decode
        step (prefill-sampled tokens cost no decode step and are excluded
        — counting them overstated throughput)."""
        if not self.decode_steps:
            return 0.0
        return self.decode_tokens / self.decode_steps

    @property
    def page_utilization(self) -> float:
        """Mean fraction of the page pool in use across decode steps."""
        if not (self.decode_steps and self.num_pages):
            return 0.0
        return self.page_step_sum / (self.decode_steps * self.num_pages)

    def summary(self) -> Dict[str, float]:
        out = {
            "decode_steps": self.decode_steps,
            "generated_tokens": self.generated_tokens,
            "prefill_sampled_tokens": self.prefill_sampled_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "padding_waste": round(self.padding_waste, 4),
            "tokens_per_step": round(self.tokens_per_step, 4),
            "wall_seconds": round(self.wall_seconds, 3),
            "wall_tokens_per_s": round(
                self.generated_tokens / self.wall_seconds, 2)
            if self.wall_seconds else 0.0,
        }
        if self.num_pages:
            out.update({
                "num_pages": self.num_pages,
                "page_utilization": round(self.page_utilization, 4),
                "peak_pages": self.peak_pages,
                "preemptions": self.preemptions,
            })
        return out


class ServingEngine:
    """Continuous-batching engine: ``batch_size`` slots over one cache pool."""

    continuous = True
    paged = False

    def __init__(self, qparams, cfg: ModelConfig, quant: QuantConfig,
                 plans: PlanBundle | None, batch_size: int = 4,
                 max_len: int = 512, seed: int = 0,
                 act_scale: str = "calibrated", backend: str | None = None,
                 interpret: bool | None = None):
        # activation FP32 scales must not see a request's batch company, or
        # swapping a finished slot for a new request would perturb every
        # other in-flight generation. "calibrated" (static per-layer scales
        # from the plan — the paper's App. D deployed config, and what the
        # fused Pallas kernel consumes) is batch-invariant by construction;
        # linears without calibrated scales fall back to per-token scales.
        quant = dataclasses.replace(quant, act_scale=act_scale)
        # kernel backend for deployed linears: "reference" (emulated GEMM)
        # or "pallas" (fused quant + packed NVFP4 GEMM); interpret=True
        # runs the Pallas kernels bit-faithfully on CPU.
        if backend is not None:
            quant = dataclasses.replace(quant, backend=backend)
        if interpret is not None:
            quant = dataclasses.replace(quant, interpret=interpret)
        self.qparams = qparams
        self.cfg = cfg
        self.quant = quant
        self.plans = plans
        self.batch_size = batch_size
        self.max_len = max_len
        self.seed = seed
        self.last_stats = EngineStats()
        # prompt-length bucketing pads prefill up to a power of two, which
        # bounds compile count. Right-padding is exact for full attention
        # (pad writes land at positions the causal mask hides and decode
        # later overwrites) but would pollute ring buffers and recurrent
        # state, so windowed/SSM/hybrid models prefill at exact length.
        self._bucket_prompts = all(m == FULL_ATTN for m in cfg.mixer_pattern)

        def prefill(qp, cache, tokens, positions, last_idx):
            logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                          positions=positions, cache=cache,
                                          quant=quant, plans=plans)
            return logits[0, last_idx], cache

        def decode(qp, cache, tokens, positions, temps, key):
            logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                          positions=positions, cache=cache,
                                          quant=quant, plans=plans)
            lg = logits[:, -1, : cfg.vocab_size].astype(jnp.float32)
            nxt = _sample_batch(lg, temps, key)
            return nxt, cache

        def decode_paged(qp, cache, tokens, positions, tables, slot_ids,
                         temps, key):
            logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                          positions=positions, cache=cache,
                                          quant=quant, plans=plans,
                                          block_tables=tables,
                                          slot_ids=slot_ids)
            lg = logits[:, -1, : cfg.vocab_size].astype(jnp.float32)
            nxt = _sample_batch(lg, temps, key)
            return nxt, cache

        def sample(logits, temp, key):
            lg = logits[: cfg.vocab_size].astype(jnp.float32)
            return _sample_batch(lg[None], temp[None], key)[0]

        self._prefill = jax.jit(prefill, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._decode_paged = jax.jit(decode_paged, donate_argnums=(1,))
        self._sample = jax.jit(sample)

    # -- public API --------------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests`` to completion; fills per-request metrics."""
        t0 = time.time()
        sched = Scheduler(self.batch_size, self.max_len)
        pool = self._make_pool()
        stats = EngineStats(num_slots=self.batch_size,
                            num_pages=getattr(pool, "usable_pages", 0))
        key = jax.random.PRNGKey(self.seed)
        for r in requests:
            self._check_capacity(pool, r)
            sched.submit(r)

        while sched.has_work():
            # admission: continuous mode refills any free slot every tick;
            # the static baseline waits for the whole gang to drain
            if self.continuous or sched.all_idle():
                key = self._admit(sched, pool, stats, key)
            active = sched.active()
            if not active:
                continue    # everything admitted finished at prefill
            key = self._decode_tick(sched, pool, stats, active, key)

        stats.generated_tokens = sum(len(r.out_tokens) for r in requests)
        stats.wall_seconds = time.time() - t0
        self.last_stats = stats
        return requests

    # -- admission ---------------------------------------------------------

    def _admit(self, sched: Scheduler, pool, stats: EngineStats, key):
        for slot, req in sched.admissions(self._admission_gate(pool)):
            resumed = bool(req.out_tokens)
            toks = (np.concatenate([np.asarray(req.prompt, np.int32),
                                    np.asarray(req.out_tokens[:-1],
                                               np.int32)])
                    if resumed else np.asarray(req.prompt, np.int32))
            self._pool_admit(pool, slot, len(toks))
            logits, src = self._prefill_tokens(toks, pool)
            pool.write(slot.index, src)
            stats.prefill_tokens += len(toks)
            if resumed:
                # the preempted request's next token was sampled before
                # eviction; rebuild its K/V and keep decoding
                sched.resume(slot)
                continue
            key, kp = jax.random.split(key)
            tok = int(self._sample(logits, jnp.float32(req.temperature), kp))
            stats.prefill_sampled_tokens += 1
            if sched.record_token(slot, tok):
                pool.release(slot.index)
                sched.free(slot)
        return key

    def _admission_gate(self, pool):
        return None                     # slot pool: a FREE slot suffices

    def _pool_admit(self, pool, slot: Slot, prefill_len: int) -> None:
        pass                            # slot pool: the row already exists

    def _check_capacity(self, pool, req: Request) -> None:
        pass                            # Scheduler.submit enforces max_len

    # -- decode ------------------------------------------------------------

    def _decode_tick(self, sched: Scheduler, pool, stats: EngineStats,
                     active: List[Slot], key):
        B = self.batch_size
        last = np.zeros((B, 1), np.int32)
        pos = np.zeros((B, 1), np.int32)
        temps = np.zeros((B,), np.float32)
        for s in active:
            last[s.index, 0] = s.last_token
            pos[s.index, 0] = s.next_pos
            temps[s.index] = s.request.temperature
        key, kd = jax.random.split(key)
        nxt, pool.cache = self._decode(
            self.qparams, pool.cache, jnp.asarray(last), jnp.asarray(pos),
            jnp.asarray(temps), kd)
        nxt = np.asarray(nxt)
        self._finish_tick(sched, pool, stats, active,
                          {s.index: int(nxt[s.index]) for s in active})
        return key

    def _finish_tick(self, sched: Scheduler, pool, stats: EngineStats,
                     active: List[Slot], tokens: Dict[int, int],
                     swept: int | None = None) -> None:
        sched.step += 1
        stats.decode_steps += 1
        # rows the decode launch actually swept: the full slot count, or
        # the bucket width when ragged decode shrank the launch
        stats.slot_steps += self.batch_size if swept is None else swept
        stats.useful_slot_steps += len(active)
        stats.decode_tokens += len(active)
        for s in active:
            if sched.record_token(s, tokens[s.index]):
                pool.release(s.index)
                sched.free(s)

    # -- internals ---------------------------------------------------------

    def _make_pool(self):
        return SlotCacheManager(self.cfg, self.batch_size, self.max_len)

    def _prefill_tokens(self, toks: np.ndarray, pool):
        """Prefill one token sequence alone; returns (last logits, cache)."""
        p = len(toks)
        plen = self._bucket_len(p) if self._bucket_prompts else p
        buf = np.zeros((1, plen), np.int32)
        buf[0, :p] = toks
        positions = np.arange(plen, dtype=np.int32)[None]
        cache = pool.fresh_prefill_cache()
        return self._prefill(self.qparams, cache, jnp.asarray(buf),
                             jnp.asarray(positions), jnp.int32(p - 1))

    def _bucket_len(self, p: int) -> int:
        b = 16
        while b < p:
            b *= 2
        return min(b, self.max_len)


class StaticBatchEngine(ServingEngine):
    """Gang-scheduled baseline: a batch holds its slots until the slowest
    request finishes (the fixed-batch behavior this engine replaced)."""

    continuous = False


class PagedServingEngine(ServingEngine):
    """Continuous batching over the paged K/V pool.

    ``num_pages=None`` sizes the pool for slot parity (``batch_size *
    max_blocks`` usable pages — the correctness-anchor configuration,
    greedy-token-identical to ``ServingEngine``); pass fewer pages to
    oversubscribe memory, more slots to raise concurrency in the same
    bytes. ``decode_buckets=True`` shrinks each decode launch to the
    active-request count rounded up to a power of two (ragged decode).
    """

    paged = True

    def __init__(self, *args, num_pages: int | None = None,
                 block_size: int = 16, decode_buckets: bool = False,
                 **kwargs):
        super().__init__(*args, **kwargs)
        self.num_pages = num_pages
        self.block_size = block_size
        self.decode_buckets = decode_buckets

    def _make_pool(self):
        return PagedCacheManager(self.cfg, self.batch_size, self.max_len,
                                 num_pages=self.num_pages,
                                 block_size=self.block_size)

    def _admission_gate(self, pool):
        # admissions() gates the whole batch before the engine allocates
        # any pages, so the gate must reserve as it approves: otherwise
        # two requests could both pass against the same free pages
        reserved = 0

        def gate(req):
            nonlocal reserved
            if not pool.can_admit(req.resume_prefill_len, reserved):
                return False
            # reserve the first decode write's block too (what can_admit
            # checked) or a same-tick admission could take it and force an
            # immediate preemption
            reserved += pool.blocks_for(req.resume_prefill_len + 1)
            return True

        return gate

    def _pool_admit(self, pool, slot: Slot, prefill_len: int) -> None:
        pool.allocate_prefill(slot.index, prefill_len)

    def _check_capacity(self, pool, req: Request) -> None:
        pool.check_capacity(req.prompt_len + req.max_new_tokens)

    def _decode_tick(self, sched: Scheduler, pool, stats: EngineStats,
                     active: List[Slot], key):
        active = self._ensure_pages(sched, pool, stats, active)
        if not active:
            return key
        m = (_bucket_pow2(len(active), self.batch_size)
             if self.decode_buckets else self.batch_size)
        last = np.zeros((m, 1), np.int32)
        pos = np.full((m, 1), -1, np.int32)    # -1 rows are inert
        temps = np.zeros((m,), np.float32)
        tables = np.zeros((m, pool.max_blocks), np.int32)
        slot_ids = np.full((m,), self.batch_size, np.int32)  # OOB = padding
        read_tables = pool.read_tables()
        rows = ({i: s for i, s in enumerate(active)} if self.decode_buckets
                else {s.index: s for s in active})
        for i, s in rows.items():
            last[i, 0] = s.last_token
            pos[i, 0] = s.next_pos
            temps[i] = s.request.temperature
            tables[i] = read_tables[s.index]
            slot_ids[i] = s.index
        key, kd = jax.random.split(key)
        nxt, pool.cache = self._decode_paged(
            self.qparams, pool.cache, jnp.asarray(last), jnp.asarray(pos),
            jnp.asarray(tables), jnp.asarray(slot_ids), jnp.asarray(temps),
            kd)
        nxt = np.asarray(nxt)
        stats.page_step_sum += pool.pages_in_use
        stats.peak_pages = max(stats.peak_pages, pool.pages_in_use)
        self._finish_tick(sched, pool, stats, active,
                          {s.index: int(nxt[i]) for i, s in rows.items()},
                          swept=m)
        return key

    def _ensure_pages(self, sched: Scheduler, pool, stats: EngineStats,
                      active: List[Slot]) -> List[Slot]:
        """Allocate each active slot's tail page, preempting the latest-
        admitted request when the pool is exhausted."""
        for s in active:
            if s.state != DECODE:       # already preempted this tick
                continue
            block = s.next_pos // pool.block_size
            while not pool.ensure(s.index, block):
                victims = [v for v in active
                           if v.state == DECODE and v is not s]
                victim = (max(victims, key=lambda v: v.request.admit_step)
                          if victims else s)
                pool.release(victim.index)
                sched.preempt(victim)
                stats.preemptions += 1
                if victim is s:
                    break
        return [s for s in active if s.state == DECODE]


def _bucket_pow2(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


def _sample_batch(logits: jax.Array, temps: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Per-row greedy/temperature sampling. logits (B, V), temps (B,)."""
    greedy = jnp.argmax(logits, axis=-1)
    keys = jax.random.split(key, logits.shape[0])
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
