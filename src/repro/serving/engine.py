"""Serving facades over the step-driven ``EngineCore``.

Flow (paper Figure 5, deployment side):
  1. offline: calibrate -> plans -> quantize weights (packed NVFP4, ARC-
     augmented along K)
  2. admission: each queued request is prefilled — in one shot, or in
     ``prefill_chunk``-token slices spread across ticks — into a batch-1
     cache that is then installed into the pool: a slot-row overwrite
     (``SlotCacheManager``) or a page scatter through the request's
     block table (``PagedCacheManager``)
  3. decode: one batched decode step per tick over every DECODE slot
     (fused online activation quantization + unified GEMMs), greedy or
     per-request temperature sampling at per-slot positions

The jitted functions are static-shaped and scheduling state never enters
a trace; all dynamic bookkeeping lives in the Python-side ``EngineCore``
(see ``core.py``). The facades here differ only in admission policy and
cache backend:

  * ``ServingEngine`` — continuous batching over the slot-row pool (every
    slot reserves ``max_len`` positions).
  * ``StaticBatchEngine`` — gang-scheduled baseline (admission only when
    every slot is idle); what ``benchmarks/continuous_batching.py``
    measures padding waste against.
  * ``PagedServingEngine`` — continuous batching over the paged K/V pool
    (block tables, on-demand page allocation, preemption + exact-
    recompute resume, optional ragged ``decode_buckets``).

Each facade offers three entry points:

  * ``make_core()`` — a fresh :class:`EngineCore` for step-driven use
    (``add_request`` at any tick, ``step()`` per tick).
  * ``stream(requests)`` — generator yielding per-request
    :class:`RequestOutput` token deltas as each tick produces them.
  * ``run(requests)`` — batch-blocking compatibility wrapper: drives
    ``step()`` to completion and returns the legacy ``Request`` records
    with results filled in, exactly as before the redesign.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FULL_ATTN, ModelConfig, QuantConfig
from repro.models import lm
from repro.models.lm import PlanBundle
from repro.serving.backend import PagedBackend, SlotBackend
from repro.serving.core import (EngineCore, EngineFns, EngineStats,
                                sample_rows)
from repro.serving.request import GenerationRequest, Request, RequestOutput

__all__ = ["EngineStats", "PagedServingEngine", "Request", "ServingEngine",
           "StaticBatchEngine"]


def _build_fns(cfg: ModelConfig, quant: QuantConfig,
               plans: Optional[PlanBundle],
               nan_guard: bool = True) -> EngineFns:
    """Jit the model entry points one engine's cores share.

    ``nan_guard`` adds the poisoned-request guard to each entry point: a
    per-row (decode) / scalar (prefill) bool that is False where the
    logits a token is sampled from contain NaN/Inf. The reduction runs
    inside the jit and only ``B`` bools cross to the host, so the decode
    hot path pays one fused ``isfinite``+``all`` per row; ``False``
    replaces the flags with constant True (the A/B overhead baseline in
    ``benchmarks/robustness.py``)."""

    def _ok_rows(lg):               # (B, V) -> (B,) finite-row flags
        if not nan_guard:
            return jnp.ones((lg.shape[0],), bool)
        return jnp.all(jnp.isfinite(lg), axis=-1)

    def prefill(qp, cache, tokens, positions, last_idx):
        logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                      positions=positions, cache=cache,
                                      quant=quant, plans=plans)
        lg = logits[0, last_idx]
        return lg, _ok_rows(lg[None, : cfg.vocab_size])[0], cache

    def prefill_chunk(qp, cache, tokens, positions):
        return lm.prefill_chunk(qp, cfg, tokens=tokens, positions=positions,
                                cache=cache, quant=quant, plans=plans)

    def decode(qp, cache, tokens, positions, temps, rids, tok_idx, seed):
        logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                      positions=positions, cache=cache,
                                      quant=quant, plans=plans)
        lg = logits[:, -1, : cfg.vocab_size].astype(jnp.float32)
        nxt = sample_rows(lg, temps, rids, tok_idx, seed)
        return nxt, _ok_rows(lg), cache

    def decode_paged(qp, cache, tokens, positions, tables, slot_ids,
                     active, temps, rids, tok_idx, seed):
        logits, cache, _ = lm.forward(qp, cfg, tokens=tokens,
                                      positions=positions, cache=cache,
                                      quant=quant, plans=plans,
                                      block_tables=tables, slot_ids=slot_ids,
                                      active_rows=active)
        lg = logits[:, -1, : cfg.vocab_size].astype(jnp.float32)
        nxt = sample_rows(lg, temps, rids, tok_idx, seed)
        return nxt, _ok_rows(lg), cache

    def sample(logits, temp, rid, tok_idx, seed):
        lg = logits[: cfg.vocab_size].astype(jnp.float32)
        return sample_rows(lg[None], temp[None], rid[None], tok_idx[None],
                           seed)[0]

    return EngineFns(
        prefill=jax.jit(prefill, donate_argnums=(1,)),
        prefill_chunk=jax.jit(prefill_chunk, donate_argnums=(1,)),
        decode=jax.jit(decode, donate_argnums=(1,)),
        decode_paged=jax.jit(decode_paged, donate_argnums=(1,)),
        sample=jax.jit(sample),
    )


class ServingEngine:
    """Continuous-batching engine: ``batch_size`` slots over one cache
    pool, served by a step-driven :class:`EngineCore` per call.

    ``prefill_chunk`` bounds the admission stall: prompts longer than the
    chunk prefill in ``prefill_chunk``-token slices across ticks instead
    of serializing their whole prefill in front of one tick's decode
    (``None`` keeps one-shot prefill).
    """

    continuous = True
    paged = False

    def __init__(self, qparams, cfg: ModelConfig, quant: QuantConfig,
                 plans: PlanBundle | None, batch_size: int = 4,
                 max_len: int = 512, seed: int = 0,
                 act_scale: str = "calibrated", backend: str | None = None,
                 interpret: bool | None = None,
                 attn_kernel: bool | None = None,
                 prefill_chunk: int | None = None,
                 prefill_budget: int | None = None,
                 nan_guard: bool = True,
                 max_queue: int | None = None,
                 max_preemptions: int | None = 64):
        # activation FP32 scales must not see a request's batch company, or
        # swapping a finished slot for a new request would perturb every
        # other in-flight generation. "calibrated" (static per-layer scales
        # from the plan — the paper's App. D deployed config, and what the
        # fused Pallas kernel consumes) is batch-invariant by construction;
        # linears without calibrated scales fall back to per-token scales.
        quant = dataclasses.replace(quant, act_scale=act_scale)
        # kernel backend for deployed linears: "reference" (emulated GEMM)
        # or "pallas" (fused quant + packed NVFP4 GEMM); interpret=True
        # runs the Pallas kernels bit-faithfully on CPU.
        if backend is not None:
            quant = dataclasses.replace(quant, backend=backend)
        if interpret is not None:
            quant = dataclasses.replace(quant, interpret=interpret)
        # paged decode attention: True (the QuantConfig default) streams
        # K/V pages through the Pallas paged-attention kernel; False pins
        # the jnp gather fallback — the A/B parity baseline.
        if attn_kernel is not None:
            quant = dataclasses.replace(quant, attn_kernel=attn_kernel)
        self.qparams = qparams
        self.cfg = cfg
        self.quant = quant
        self.plans = plans
        self.batch_size = batch_size
        self.max_len = max_len
        self.seed = seed
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget
        # robustness knobs (see core.py): the in-jit NaN/Inf logit guard,
        # the bounded submit queue, and the per-request preemption budget.
        self.nan_guard = nan_guard
        self.max_queue = max_queue
        self.max_preemptions = max_preemptions
        self.last_stats = EngineStats()
        # prompt-length bucketing pads one-shot prefill up to a power of
        # two, which bounds compile count. Right-padding is exact for full
        # attention (pad writes land at positions the causal mask hides and
        # decode later overwrites) but would pollute ring buffers and
        # recurrent state, so windowed/SSM/hybrid models prefill at exact
        # length. Chunked prefill always runs exact-length chunks.
        self._bucket_prompts = all(m == FULL_ATTN for m in cfg.mixer_pattern)
        self.fns = _build_fns(cfg, quant, plans, nan_guard=nan_guard)
        self.cache_backend = self._make_backend()

    def _make_backend(self) -> SlotBackend:
        return SlotBackend()

    # -- public API --------------------------------------------------------

    def make_core(self, prefill_chunk: int | None = None,
                  prefill_budget: int | None = None,
                  faults=None, trace_guard=None) -> EngineCore:
        """A fresh step-driven core over a new cache pool. Jit trace
        caches are shared across cores of the same engine.
        ``prefill_chunk`` / ``prefill_budget`` override the engine
        defaults for this core (``0`` forces one-shot / unbudgeted
        prefill, as in the CLIs). ``faults`` threads a
        :class:`~repro.serving.faults.FaultInjector` through the core
        and backend for deterministic failure testing; ``trace_guard``
        threads an :class:`~repro.analysis.retrace.TraceGuard` that
        counts jit traces per entry point (rule R5)."""
        if prefill_chunk is None:
            chunk = self.prefill_chunk
        else:
            chunk = prefill_chunk or None   # 0 -> one-shot
        if prefill_budget is None:
            budget = self.prefill_budget
        else:
            budget = prefill_budget or None  # 0 -> unbudgeted
        return EngineCore(self.fns, self.qparams, self.cfg,
                          cache_backend=self.cache_backend,
                          num_slots=self.batch_size, max_len=self.max_len,
                          seed=self.seed, continuous=self.continuous,
                          prefill_chunk=chunk, prefill_budget=budget,
                          bucket_prompts=self._bucket_prompts,
                          max_queue=self.max_queue,
                          max_preemptions=self.max_preemptions,
                          faults=faults, trace_guard=trace_guard)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests`` to completion (compatibility wrapper).

        Drives a core's ``step()`` until every request finishes and
        copies results back into the legacy records, reconstituting the
        pre-redesign return shape.
        """
        core = self.make_core()
        self.last_stats = core.stats        # mutated in place per tick
        rids = [core.add_request(r.to_generation_request()) for r in requests]
        while core.has_unfinished():
            core.step()
        for rid, r in zip(rids, requests):
            r.absorb(core.states[rid])
        return requests

    def stream(self, requests: Iterable[Request | GenerationRequest]
               ) -> Iterator[RequestOutput]:
        """Serve ``requests``, yielding per-request token deltas as each
        tick emits them (``RequestOutput.new_tokens``). Legacy ``Request``
        records get their results absorbed as they finish. For mid-flight
        submission drive a ``make_core()`` directly."""
        core = self.make_core()
        self.last_stats = core.stats        # mutated in place per tick, so
        # stats stay truthful even when the consumer breaks out early
        legacy = {}
        for r in requests:
            rid = core.add_request(r)
            if isinstance(r, Request):
                legacy[rid] = r
        while core.has_unfinished():
            for ro in core.step().outputs:
                if ro.finished and ro.request_id in legacy:
                    legacy[ro.request_id].absorb(core.states[ro.request_id])
                yield ro


class StaticBatchEngine(ServingEngine):
    """Gang-scheduled baseline: a batch holds its slots until the slowest
    request finishes (the fixed-batch behavior this engine replaced)."""

    continuous = False


class PagedServingEngine(ServingEngine):
    """Continuous batching over the paged K/V pool.

    ``num_pages=None`` sizes the pool for slot parity (``batch_size *
    max_blocks`` usable pages — the correctness-anchor configuration,
    greedy-token-identical to ``ServingEngine``); pass fewer pages to
    oversubscribe memory, more slots to raise concurrency in the same
    bytes. Decode ticks run the Pallas paged-attention kernel by default
    (``attn_kernel=False`` pins the jnp gather fallback for A/B parity):
    active requests are packed into the low batch rows and the packed
    count is a traced scalar, so ragged batches skip padding rows
    in-kernel without retracing. ``decode_buckets=True`` additionally
    shrinks each decode launch to the active-request count rounded up to
    a power of two — a legacy knob now that padding rows cost nothing.
    Chunked prefill allocates each chunk's pages as the prompt cursor
    advances.

    ``prefix_cache=True`` turns the pool content-addressed: full pages
    are registered under a chained block hash, admissions whose token
    sequence starts with a registered chain share those pages ref-counted
    instead of recomputing them (only the uncached suffix is prefilled
    and charged against the pool), and a shared tail page a request must
    write into is duplicated copy-on-write. Greedy tokens are identical
    to ``prefix_cache=False``; configs with slot-resident mixer state
    (sliding windows, SSM/RWKV) silently serve unshared because their
    state cannot be skipped.
    """

    paged = True

    def __init__(self, *args, num_pages: int | None = None,
                 block_size: int = 16, decode_buckets: bool = False,
                 prefix_cache: bool = False, **kwargs):
        self.num_pages = num_pages
        self.block_size = block_size
        self.decode_buckets = decode_buckets
        self.prefix_cache = prefix_cache
        super().__init__(*args, **kwargs)

    def _make_backend(self) -> PagedBackend:
        return PagedBackend(num_pages=self.num_pages,
                            block_size=self.block_size,
                            decode_buckets=self.decode_buckets,
                            prefix_cache=self.prefix_cache)
