"""Step-driven serving core: add_request / step / has_unfinished.

``EngineCore`` is the single engine underneath every serving facade
(slot, paged, static). One ``step()`` call is one engine *tick*:

  1. **admission** — queued requests are paired with FREE slots (gated
     by the cache backend). The backend first claims whatever *cached
     prefix* the pool already holds for the request's token sequence
     (``begin_prefill``: shared pages enter the block table ref-counted,
     and the prompt cursor starts at the shared-prefix boundary, so only
     the uncached suffix is ever computed). Short suffixes prefill in
     one shot, exactly as before; suffixes longer than ``prefill_chunk``
     enter the chunked PREFILL phase instead.
  2. **chunked prefill** — every PREFILL slot advances by at most
     ``prefill_chunk`` prompt tokens (the paged backend allocates that
     chunk's pages as the cursor moves). With ``prefill_budget`` set,
     one shared per-tick token budget caps the *total* prefill work a
     tick performs across every admission (vLLM-style
     ``max_num_batched_tokens``), so N simultaneous admissions cannot
     stack N chunks into one tick — the admission stall is bounded by
     the budget, not ``slots x chunk``. The final chunk samples the
     first token and installs the built cache into the pool.
  3. **decode** — one batched decode step over every DECODE slot.

Every tick returns a :class:`StepOutput` carrying the per-request token
deltas it produced, so callers can stream tokens as they are emitted and
``add_request`` at any tick. The batch-blocking ``run()`` of the engine
facades is a thin wrapper that drives ``step()`` to completion.

Sampling is *slot-invariant*: each request draws from a PRNG stream
derived from ``(engine seed, request id, token index)`` via ``fold_in``,
never from a per-tick batch key, so temperature>0 outputs are identical
across slot assignments, preemption/resume, and streaming-vs-``run()``.

The core is also the request-lifecycle robustness layer — the pieces a
network front end needs before untrusted traffic can reach the engine:
:meth:`EngineCore.abort_request` cancels a request in any phase
(releasing slot state and ref-counted pages without corrupting shared
COW pages), a step watchdog expires requests past their per-request
deadline / queue timeout / preemption-retry budget with distinct finish
reasons, ``max_queue`` bounds the admission queue with explicit
``QueueFullError`` rejection (``CapacityError`` fails impossible
requests fast instead of head-of-line-blocking FIFO), a per-row
NaN/Inf logit guard finishes only the offending request while the rest
of the batch continues bit-identically, and a failed decode launch is
contained to the batch it poisoned. ``faults.FaultInjector`` drives
every one of these paths deterministically in tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.backend import SlotBackend
from repro.serving.faults import FaultInjector
from repro.serving.request import (CapacityError, FinishReason, QueueFullError,
                                   Request, RequestOutput, RequestState,
                                   StepOutput)
from repro.serving.scheduler import DECODE, PREFILL, Scheduler, Slot

__all__ = ["EngineCore", "EngineFns", "EngineStats", "request_key",
           "sample_rows"]


@dataclasses.dataclass
class EngineStats:
    """Aggregate serving metrics for one core lifetime (one ``run`` /
    ``stream`` call of a facade engine).

    ``slot_steps`` counts slot-rows swept by decode steps (steps x slots);
    ``useful_slot_steps`` counts the ones that emitted a token for a live
    request. Their gap is the padding waste continuous batching removes.
    ``generated_tokens`` splits into ``prefill_sampled_tokens`` (the token
    sampled from each admission's last-prompt logits — no decode step
    spent) and ``decode_tokens`` (one decode step each), so per-step
    throughput is not inflated by prefill-time samples.
    ``max_prefill_tokens_per_step`` is the admission-stall bound: the
    most prefill tokens a single tick had to compute before its decode
    could run (chunked prefill caps it near ``prefill_chunk``; a
    ``prefill_budget`` caps it at the budget across all admissions).
    ``cached_prefix_tokens`` counts prefill tokens *skipped* because
    their pages were found in the prefix cache — ``prefill_tokens``
    counts only what was actually computed.
    """

    num_slots: int = 0
    decode_steps: int = 0
    slot_steps: int = 0
    useful_slot_steps: int = 0
    prefill_tokens: int = 0
    cached_prefix_tokens: int = 0
    generated_tokens: int = 0
    prefill_sampled_tokens: int = 0
    decode_tokens: int = 0
    max_prefill_tokens_per_step: int = 0
    wall_seconds: float = 0.0
    # paged-pool metrics (zero on the slot pool)
    num_pages: int = 0
    page_step_sum: int = 0              # sum over decode steps of pages in use
    peak_pages: int = 0
    preemptions: int = 0
    # robustness counters (the fields a future /metrics endpoint exports):
    # aborted = caller cancellations; expired = watchdog terminations
    # (deadline, queue timeout, preemption budget); rejected = add_request
    # refusals (bounded queue, capacity fail-fast); nan_isolated = rows
    # finished ERROR by the non-finite-logit guard; preemption_retries =
    # re-admissions of previously preempted requests; step_failures =
    # decode launches that raised (their whole batch finished ERROR)
    aborted: int = 0
    expired: int = 0
    rejected: int = 0
    nan_isolated: int = 0
    preemption_retries: int = 0
    step_failures: int = 0
    # per-request tick-clock observations, appended at each finish (any
    # reason). Averages hide tail latency entirely, so the /metrics
    # endpoint and summary() export p50/p95 over these. One int per
    # finished request — a long-lived server trims via trim_histograms()
    # if it ever cares (at 8 bytes/request this is years of traffic).
    ttft_hist: List[int] = dataclasses.field(default_factory=list)
    latency_hist: List[int] = dataclasses.field(default_factory=list)

    def observe_finish(self, state) -> None:
        """Record one finished request's TTFT/latency (engine ticks).
        A request that never emitted (queue timeout, prefill poison)
        has no TTFT; one that never got submit-stamped has neither."""
        ttft = state.ttft_steps
        if ttft is not None:
            self.ttft_hist.append(int(ttft))
        lat = state.latency_steps
        if lat is not None:
            self.latency_hist.append(int(lat))

    def trim_histograms(self, keep: int = 10000) -> None:
        """Drop all but the most recent ``keep`` observations."""
        del self.ttft_hist[:-keep]
        del self.latency_hist[:-keep]

    @staticmethod
    def _pct(hist: List[int], q: float) -> float:
        if not hist:
            return 0.0
        return float(np.percentile(np.asarray(hist), q))

    @property
    def ttft_p50(self) -> float:
        return self._pct(self.ttft_hist, 50)

    @property
    def ttft_p95(self) -> float:
        return self._pct(self.ttft_hist, 95)

    @property
    def latency_p50(self) -> float:
        return self._pct(self.latency_hist, 50)

    @property
    def latency_p95(self) -> float:
        return self._pct(self.latency_hist, 95)

    @property
    def padding_waste(self) -> float:
        if not self.slot_steps:
            return 0.0
        return 1.0 - self.useful_slot_steps / self.slot_steps

    @property
    def tokens_per_step(self) -> float:
        """Decode throughput: decode-generated tokens per batched decode
        step (prefill-sampled tokens cost no decode step and are excluded
        — counting them overstated throughput)."""
        if not self.decode_steps:
            return 0.0
        return self.decode_tokens / self.decode_steps

    @property
    def page_utilization(self) -> float:
        """Mean fraction of the page pool in use across decode steps."""
        if not (self.decode_steps and self.num_pages):
            return 0.0
        return self.page_step_sum / (self.decode_steps * self.num_pages)

    def summary(self) -> Dict[str, float]:
        out = {
            "decode_steps": self.decode_steps,
            "generated_tokens": self.generated_tokens,
            "prefill_sampled_tokens": self.prefill_sampled_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "cached_prefix_tokens": self.cached_prefix_tokens,
            "max_prefill_tokens_per_step": self.max_prefill_tokens_per_step,
            "padding_waste": round(self.padding_waste, 4),
            "tokens_per_step": round(self.tokens_per_step, 4),
            "wall_seconds": round(self.wall_seconds, 3),
            "wall_tokens_per_s": round(
                self.generated_tokens / self.wall_seconds, 2)
            if self.wall_seconds else 0.0,
            "ttft_p50": round(self.ttft_p50, 1),
            "ttft_p95": round(self.ttft_p95, 1),
            "latency_p50": round(self.latency_p50, 1),
            "latency_p95": round(self.latency_p95, 1),
            "aborted": self.aborted,
            "expired": self.expired,
            "rejected": self.rejected,
            "nan_isolated": self.nan_isolated,
            "preemption_retries": self.preemption_retries,
            "step_failures": self.step_failures,
        }
        if self.num_pages:
            out.update({
                "num_pages": self.num_pages,
                "page_utilization": round(self.page_utilization, 4),
                "peak_pages": self.peak_pages,
                "preemptions": self.preemptions,
            })
        return out


@dataclasses.dataclass
class EngineFns:
    """The jitted model entry points one core drives (built once per
    facade engine; trace caches are shared across its cores).

    prefill(qp, cache, tokens, positions, last_idx)
        -> (logits, ok, cache)
    prefill_chunk(qp, cache, tokens, positions) -> cache
    decode(qp, cache, tokens, positions, temps, rids, tok_idx, seed)
        -> (next_tokens, ok_rows, cache)
    decode_paged(..., tables, slot_ids, active, temps, rids, tok_idx,
        seed) — ``active`` is the traced packed-row count driving the
        kernel's dynamic valid-row masking
    sample(logits, temp, rid, tok_idx, seed) -> token

    ``ok`` / ``ok_rows`` are the poisoned-request guard: a scalar (resp.
    per-row ``(B,)``) bool, False where the sampled-over logits contain a
    NaN/Inf. Computed inside the jit (one ``isfinite`` all-reduce per
    row, no extra host transfer beyond ``B`` bools) so the engine can
    finish only the offending request while the batch survives; engines
    built with ``nan_guard=False`` return constant-True flags.
    """

    prefill: callable
    prefill_chunk: callable
    decode: callable
    decode_paged: callable
    sample: callable


def request_key(seed_key: jax.Array, rid: jax.Array,
                tok_idx: jax.Array) -> jax.Array:
    """Per-token PRNG key from (engine seed, request id, token index).

    Independent of slot assignment, batch composition, and tick count, so
    sampled outputs are reproducible across scheduling decisions."""
    return jax.random.fold_in(jax.random.fold_in(seed_key, rid), tok_idx)


def sample_rows(logits: jax.Array, temps: jax.Array, rids: jax.Array,
                tok_idx: jax.Array, seed_key: jax.Array) -> jax.Array:
    """Per-row greedy/temperature sampling. logits (B, V), temps (B,)."""
    greedy = jnp.argmax(logits, axis=-1)
    keys = jax.vmap(request_key, in_axes=(None, 0, 0))(seed_key, rids,
                                                       tok_idx)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)


class EngineCore:
    """Step-driven request engine over one cache pool.

    Requests arrive at any tick via :meth:`add_request`; every
    :meth:`step` surfaces the tokens it produced. Construction is cheap —
    the jitted functions are built (and their traces cached) by the
    facade engine and shared across cores.
    """

    def __init__(self, fns: EngineFns, qparams, cfg: ModelConfig,
                 cache_backend: Optional[SlotBackend] = None,
                 num_slots: int = 4, max_len: int = 512, seed: int = 0,
                 continuous: bool = True,
                 prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 bucket_prompts: bool = False,
                 max_queue: Optional[int] = None,
                 max_preemptions: Optional[int] = 64,
                 faults: Optional[FaultInjector] = None,
                 trace_guard=None):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if max_preemptions is not None and max_preemptions < 0:
            raise ValueError("max_preemptions must be >= 0")
        # an analysis.retrace.TraceGuard (or anything with wrap_fns)
        # interposes counting shims on this core's entry points without
        # touching the engine-shared fns or their trace caches
        self.fns = fns if trace_guard is None else trace_guard.wrap_fns(fns)
        self.qparams = qparams
        self.cfg = cfg
        self.backend = cache_backend or SlotBackend()
        self.num_slots = num_slots
        self.max_len = max_len
        self.continuous = continuous
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget
        self.bucket_prompts = bucket_prompts
        self.max_queue = max_queue
        self.max_preemptions = max_preemptions
        self.faults = faults
        self.sched = Scheduler(num_slots, max_len)
        self.pool = self.backend.make_pool(cfg, num_slots, max_len)
        self.stats = EngineStats(num_slots=num_slots,
                                 num_pages=getattr(self.pool, "usable_pages",
                                                   0))
        self.states: Dict[int, RequestState] = {}
        self._seed_key = jax.random.PRNGKey(seed)
        self._next_id = 0
        self._tick_prefill = 0
        self._t0: Optional[float] = None    # starts at the first tick, so
        # a step-driven core's idle time never dilutes its throughput
        # terminations between ticks (abort_request) surface as finished
        # RequestOutputs on the *next* StepOutput, so streaming consumers
        # always observe the finish
        self._pending: List[RequestOutput] = []
        # the thread-safe submission seam the async server front end
        # relies on: add_request/abort_request may be called from the
        # event-loop thread while step() runs on an executor thread —
        # every mutation of scheduler/pool/stats state is serialized
        # under this lock (step's injected-fault stall sits *outside*
        # it, so a deliberately held tick never blocks admissions, and
        # backpressure 429s stay responsive while the engine stalls)
        self._lock = threading.Lock()

    # -- public API --------------------------------------------------------

    def add_request(self, request) -> int:
        """Queue a request (any tick); returns its resolved request id.

        Accepts a :class:`GenerationRequest` or a legacy :class:`Request`
        (converted). An explicit ``request_id`` pins the PRNG stream;
        otherwise the next monotonic id is assigned. Thread-safe: may be
        called from any thread, including concurrently with a ``step()``
        running on another (the server's submission path).
        """
        if isinstance(request, Request):
            request = request.to_generation_request()
        with self._lock:
            return self._add_request_locked(request)

    def _add_request_locked(self, request) -> int:
        rid = request.request_id
        if rid is None:
            rid = self._next_id
        if rid in self.states:
            raise ValueError(f"duplicate request_id {rid}")
        if (self.max_queue is not None
                and len(self.sched.queue) >= self.max_queue):
            # admission backpressure: explicit rejection instead of an
            # unbounded queue. Preempted residents awaiting re-admission
            # count against the bound — they hold queue positions too.
            self.stats.rejected += 1
            raise QueueFullError(
                f"admission queue is full ({len(self.sched.queue)} of "
                f"{self.max_queue}); shed load or retry later")
        self._next_id = max(self._next_id, rid + 1)
        state = RequestState(request=request, rid=rid)
        try:
            # fail fast on requests that could never run even in an idle
            # pool — admitting one would head-of-line-block FIFO forever
            self.backend.check_capacity(
                self.pool, state.prompt_len + state.sampling.max_new_tokens)
            self.sched.submit(state)    # validates lengths, stamps submit
        except CapacityError:
            self.stats.rejected += 1
            raise
        self.states[rid] = state
        return rid

    def abort_request(self, rid: int) -> bool:
        """Cancel request ``rid`` in whatever phase it is — QUEUED,
        chunked-PREFILL mid-flight, DECODE, or PREEMPTED (requeued).

        Slot state and cache rows/pages are released exactly as on a
        normal finish (ref-counted pages decref; shared/COW pages other
        requests reference stay resident), the request finishes with
        ``FinishReason.ABORTED`` keeping whatever tokens it produced,
        and the next ``step()``'s output carries its finished
        ``RequestOutput``. Returns False when the request had already
        finished (abort raced completion — a no-op), True otherwise.
        Raises ``KeyError`` for an unknown (or already popped) rid.
        Thread-safe: serialized against ``step()``'s mutation phase, so
        a client-disconnect abort may land from the event-loop thread
        while a tick runs on the executor. Never call from *inside* a
        ``step()`` (same thread re-entry would deadlock).
        """
        with self._lock:
            st = self.states.get(rid)
            if st is None:
                raise KeyError(f"unknown request id {rid}")
            if st.done:
                return False
            self._terminate(st, FinishReason.ABORTED)
            self.stats.aborted += 1
            return True

    def pop_request(self, rid: int) -> RequestState:
        """Remove and return a *finished* request's state.

        ``states`` retains every request so ``run()``/``stream()`` can
        read results back; a long-lived core serving an open-ended stream
        should pop each request once its results are consumed, or the
        map grows without bound."""
        with self._lock:
            state = self.states.get(rid)
            if state is None:
                raise KeyError(
                    f"unknown request id {rid}: never added or already popped")
            if not state.done:
                raise ValueError(
                    f"request {rid} is still in flight "
                    f"(finish it, abort_request({rid}), or wait)")
            return self.states.pop(rid)

    def has_unfinished(self) -> bool:
        return self.sched.has_work()

    def has_pending_outputs(self) -> bool:
        """True when between-tick terminations (aborts) are waiting to
        surface on the next ``step()`` — the server pump ticks once more
        to flush them even when nothing else is unfinished."""
        return bool(self._pending)

    def step(self) -> StepOutput:
        """Advance the engine by one tick; returns the tokens it emitted.

        An *idle* tick — nothing queued, nothing resident, no pending
        between-tick finishes — returns an empty :class:`StepOutput`
        without launching any jitted function, advancing the tick clock,
        or starting the wall clock: the server's pump loop may call
        ``step()`` continuously, and idle ticks must cost nothing.
        """
        tick = self.sched.step
        if not self._pending and not self.sched.has_work():
            return StepOutput(step=tick, outputs=[])
        if self._t0 is None:
            self._t0 = time.time()
        if self.faults is not None:
            self.faults.sleep(tick)         # injected straggler/held tick
        with self._lock:
            return self._step_locked(tick)

    def _step_locked(self, tick: int) -> StepOutput:
        self._tick_prefill = 0
        deltas: Dict[int, RequestOutput] = {}
        for ro in self._pending:            # between-tick aborts
            deltas[ro.request_id] = ro
        self._pending.clear()
        # watchdog: expire requests past their deadline / queue timeout /
        # preemption budget before any work is scheduled for them
        self._expire(deltas)
        # admission: continuous mode refills any free slot every tick;
        # the static baseline waits for the whole gang to drain
        if self.continuous or self.sched.all_idle():
            self._admit(deltas)
        self._advance_chunked_prefills(deltas)
        active = self.sched.active()
        if active:
            self._decode_tick(deltas, active)
        self.sched.step += 1
        self.stats.max_prefill_tokens_per_step = max(
            self.stats.max_prefill_tokens_per_step, self._tick_prefill)
        self.stats.wall_seconds = time.time() - self._t0
        return StepOutput(step=tick, outputs=list(deltas.values()))

    # -- termination (abort / watchdog / fault isolation) ------------------

    def _terminate(self, st: RequestState, reason: FinishReason,
                   error: Optional[str] = None,
                   deltas: Optional[Dict[int, RequestOutput]] = None) -> None:
        """Finish a not-done request out-of-band in whatever phase it is.

        Resident requests (PREFILL mid-chunk or DECODE) release their
        cache row/pages through the same path as a normal finish — pages
        decref, shared/registered pages stay resident — and their slot
        returns to FREE; queued or preempted requests just leave the
        admission queue. The finished ``RequestOutput`` lands in this
        tick's ``deltas`` (watchdog/fault paths) or on the next tick's
        StepOutput (between-tick aborts).
        """
        slot = self.sched.slot_of(st.rid)
        if slot is not None and slot.state in (PREFILL, DECODE):
            self.sched.finish(slot, reason, error)
            self.pool.release(slot.index)
            self.sched.free(slot)
        else:                               # QUEUED or PREEMPTED
            self.sched.remove_queued(st)
            st.done = True
            st.finish_reason = reason
            st.error = error
            st.finish_step = self.sched.step
        self.stats.observe_finish(st)
        ro = RequestOutput(request_id=st.rid, new_tokens=[],
                           num_generated=len(st.out_tokens), finished=True,
                           finish_reason=reason, error=error)
        if deltas is None:
            self._pending.append(ro)
        else:
            deltas[st.rid] = ro

    def _expire(self, deltas: Dict[int, RequestOutput]) -> None:
        """Step watchdog: terminate requests whose elapsed ticks exceed
        their deadline, whose first admission never came within the
        queue timeout, or whose preemption-retry budget is spent —
        instead of letting them run (or thrash evict/resume) forever."""
        tick = self.sched.step
        for st in list(self.sched.queue):
            sp = st.sampling
            if (sp.queue_timeout_steps is not None and st.admit_step < 0
                    and tick - st.submit_step > sp.queue_timeout_steps):
                self._terminate(st, FinishReason.QUEUE_TIMEOUT, deltas=deltas)
                self.stats.expired += 1
            elif (sp.deadline_steps is not None
                    and tick - st.submit_step > sp.deadline_steps):
                self._terminate(st, FinishReason.DEADLINE, deltas=deltas)
                self.stats.expired += 1
            elif (self.max_preemptions is not None
                    and st.preemptions > self.max_preemptions):
                # livelock breaker: two requests too large to coexist can
                # thrash evict/resume cycles forever; after the budget,
                # the thrashing request fails fast with CAPACITY
                self._terminate(
                    st, FinishReason.CAPACITY, deltas=deltas,
                    error=f"preempted {st.preemptions}x "
                          f"(budget {self.max_preemptions}): the pool "
                          f"cannot hold this request alongside its peers")
                self.stats.expired += 1
        for slot in self.sched.slots:
            if slot.state not in (PREFILL, DECODE):
                continue
            st = slot.req
            sp = st.sampling
            if (sp.deadline_steps is not None
                    and tick - st.submit_step > sp.deadline_steps):
                self._terminate(st, FinishReason.DEADLINE, deltas=deltas)
                self.stats.expired += 1

    # -- admission ---------------------------------------------------------

    def _admit(self, deltas: Dict[int, RequestOutput]) -> None:
        gate = self.backend.admission_gate(self.pool)
        admitted = self.sched.admissions(gate)
        if (not admitted and self.sched.queue and self.sched.all_idle()
                and self.backend.pool_idle(self.pool)):
            # the queue head was refused with every slot free and nothing
            # resident: no amount of waiting can admit it (defense in
            # depth behind add_request's fail-fast — charge-accounting
            # drift must not head-of-line-block FIFO forever)
            st = self.sched.queue[0]
            self._terminate(
                st, FinishReason.CAPACITY, deltas=deltas,
                error="refused admission by an idle pool: the request "
                      "cannot fit even running alone")
            self.stats.expired += 1
            return
        for slot, st in admitted:
            if st.preemptions:
                self.stats.preemption_retries += 1
            toks = st.prefill_token_seq()
            # claim the cached prefix first: the prompt cursor starts at
            # the shared-prefix boundary and only the suffix is computed
            cached = self.backend.begin_prefill(self.pool, slot, st, toks)
            slot.prefill_pos = cached
            self.stats.cached_prefix_tokens += cached
            suffix = len(toks) - cached
            if (self.prefill_budget is not None
                    or (self.prefill_chunk is not None
                        and suffix > self.prefill_chunk)):
                # enter the chunked PREFILL phase: the partial batch-1
                # cache (seeded with the gathered shared prefix) rides on
                # the slot; chunks advance each tick (starting this one)
                # in _advance_chunked_prefills. A prefill budget routes
                # *every* admission here so one tick's total prefill work
                # is capped across admissions, not per slot.
                slot.prefill_cache = self._fresh_prefill_cache(slot, cached)
                continue
            cache = self._fresh_prefill_cache(slot, cached)
            if not self.backend.alloc_prefill_chunk(
                    self.pool, self.sched, self.stats, slot, len(toks),
                    faults=self.faults):
                continue                # the slot preempted itself
            logits, ok, src = self._prefill_suffix(toks, cached, cache)
            self.backend.install(self.pool, slot, st, src, toks)
            self._count_prefill(suffix)
            self._finish_prefill(slot, st, logits, ok, deltas)

    def _fresh_prefill_cache(self, slot: Slot, cached: int) -> list:
        """Batch-1 prefill cache, seeded from shared-prefix pages when
        the admission had a prefix-cache hit."""
        cache = self.pool.fresh_prefill_cache()
        return self.backend.gather_prefill_cache(self.pool, slot, cached,
                                                 cache)

    def _budget_left(self) -> Optional[int]:
        if self.prefill_budget is None:
            return None
        return max(self.prefill_budget - self._tick_prefill, 0)

    def _advance_chunked_prefills(self, deltas: Dict[int, RequestOutput]
                                  ) -> None:
        """Feed each PREFILL slot one prefill slice.

        The slice is bounded per slot by ``prefill_chunk`` and across the
        whole tick by ``prefill_budget``; a slot whose turn finds the
        budget exhausted simply waits for the next tick (its cursor and
        partial cache persist), so total tick prefill work never exceeds
        the budget no matter how many admissions landed together.
        """
        for slot in self.sched.prefilling():
            if slot.state != PREFILL:   # preempted by an earlier reclaim
                continue
            st = slot.req
            toks = st.prefill_token_seq()
            start = slot.prefill_pos
            cap = len(toks) - start
            if self.prefill_chunk is not None:
                cap = min(cap, self.prefill_chunk)
            budget = self._budget_left()
            if budget is not None:
                cap = min(cap, budget)
            if cap <= 0:
                continue                # tick budget spent: wait
            end = start + cap
            if not self.backend.alloc_prefill_chunk(
                    self.pool, self.sched, self.stats, slot, end,
                    faults=self.faults):
                continue                # the slot preempted itself
            self._count_prefill(end - start)
            if end < len(toks):
                chunk = np.asarray(toks[start:end], np.int32)[None]
                positions = np.arange(start, end, dtype=np.int32)[None]
                slot.prefill_cache = self.fns.prefill_chunk(
                    self.qparams, slot.prefill_cache, jnp.asarray(chunk),
                    jnp.asarray(positions))
                slot.prefill_pos = end
                continue
            # final chunk: on full-attention models, pad it to the chunk
            # size so mixed tail lengths share one trace (the same
            # argument as one-shot bucketing: pad writes land beyond the
            # prompt, where the causal mask hides them until decode
            # overwrites). Recurrent/windowed models stay exact-length;
            # budget-only mode (no per-slot chunk) has no fixed slice
            # size to pad to and stays exact as well.
            pad_hi = (self.prefill_chunk if self.prefill_chunk is not None
                      else cap)
            pad_end = (min(start + pad_hi, self.max_len)
                       if self.bucket_prompts else end)
            buf = np.zeros((1, pad_end - start), np.int32)
            buf[0, : end - start] = toks[start:end]
            positions = np.arange(start, pad_end, dtype=np.int32)[None]
            logits, ok, src = self.fns.prefill(
                self.qparams, slot.prefill_cache, jnp.asarray(buf),
                jnp.asarray(positions), jnp.int32(end - start - 1))
            slot.prefill_cache = None
            self.backend.install(self.pool, slot, st, src, toks)
            self._finish_prefill(slot, st, logits, ok, deltas)

    def _finish_prefill(self, slot: Slot, st: RequestState, logits, ok,
                        deltas: Dict[int, RequestOutput]) -> None:
        if st.out_tokens:
            # the preempted request's next token was sampled before
            # eviction; rebuild its K/V and keep decoding (a poisoned
            # resume surfaces at the next decode tick's row guard)
            self.sched.resume(slot)
            return
        if self._poisoned(st.rid, ok):
            self.stats.nan_isolated += 1
            self._terminate(st, FinishReason.ERROR,
                            error="non-finite logits at prefill",
                            deltas=deltas)
            return
        tok = int(self.fns.sample(
            logits, jnp.float32(st.sampling.temperature), jnp.int32(st.rid),
            jnp.int32(0), self._seed_key))
        self.stats.prefill_sampled_tokens += 1
        self._record(slot, tok, deltas)

    def _poisoned(self, rid: int, ok) -> bool:
        """The per-row non-finite-logit guard verdict for one request:
        the in-jit ``isfinite`` flag, or a scheduled injection standing
        in for a real NaN (same downstream path either way)."""
        if self.faults is not None and self.faults.poisoned(self.sched.step,
                                                            rid):
            return True
        return not bool(np.asarray(ok))

    def _prefill_suffix(self, toks: np.ndarray, cached: int, cache: list):
        """Prefill ``toks[cached:]`` into ``cache`` (which already holds
        the gathered shared prefix when ``cached > 0``); returns (last
        logits, finite-row flag, cache)."""
        p = len(toks) - cached
        plen = p
        if self.bucket_prompts:
            plen = min(self._bucket_len(p), self.max_len - cached)
        buf = np.zeros((1, plen), np.int32)
        buf[0, :p] = toks[cached:]
        positions = np.arange(cached, cached + plen, dtype=np.int32)[None]
        return self.fns.prefill(self.qparams, cache, jnp.asarray(buf),
                                jnp.asarray(positions), jnp.int32(p - 1))

    def _bucket_len(self, p: int) -> int:
        b = 16
        while b < p:
            b *= 2
        return min(b, self.max_len)

    def _count_prefill(self, n: int) -> None:
        self.stats.prefill_tokens += n
        self._tick_prefill += n

    # -- decode ------------------------------------------------------------

    def _decode_tick(self, deltas: Dict[int, RequestOutput],
                     active: List[Slot]) -> None:
        active = self.backend.pre_decode(self.pool, self.sched, self.stats,
                                         active, faults=self.faults)
        if not active:
            return
        m, rows, extra = self.backend.decode_rows(self.pool, active,
                                                  self.num_slots)
        last = np.zeros((m, 1), np.int32)
        # inert rows: the paged write drops pos < 0; the slot pool's
        # harmless pos-0 write is fully overwritten at the next admission
        pos = np.full((m, 1), -1 if self.backend.paged else 0, np.int32)
        temps = np.zeros((m,), np.float32)
        rids = np.zeros((m,), np.int32)
        tok_idx = np.zeros((m,), np.int32)
        for i, s in rows.items():
            last[i, 0] = s.last_token
            pos[i, 0] = s.next_pos
            temps[i] = s.req.sampling.temperature
            rids[i] = s.req.rid
            tok_idx[i] = len(s.req.out_tokens)
        args = [self.qparams, self.pool.cache, jnp.asarray(last),
                jnp.asarray(pos)]
        if extra:
            args += [jnp.asarray(extra["tables"]),
                     jnp.asarray(extra["slot_ids"]),
                     jnp.asarray(extra["active"])]
        fn = getattr(self.fns, self.backend.decode_fn)
        try:
            # injected step errors fire *before* the launch, so the pool
            # buffers (donated into the call) are still intact and the
            # containment below is exact. A real mid-launch failure is
            # contained best-effort: the batch is isolated either way.
            if self.faults is not None:
                self.faults.raise_step_error(self.sched.step)
            nxt, ok, self.pool.cache = fn(*args, jnp.asarray(temps),
                                          jnp.asarray(rids),
                                          jnp.asarray(tok_idx),
                                          self._seed_key)
        except Exception as e:              # noqa: BLE001 — containment seam
            self._fail_step(active, e, deltas)
            return
        nxt = np.asarray(nxt)
        okh = np.asarray(ok)
        self.stats.decode_steps += 1
        # rows the decode launch actually swept: the full slot count, or
        # the bucket width when ragged decode shrank the launch
        self.stats.slot_steps += m
        self.stats.useful_slot_steps += len(active)
        in_use = getattr(self.pool, "pages_in_use", 0)
        self.stats.page_step_sum += in_use
        self.stats.peak_pages = max(self.stats.peak_pages, in_use)
        for i, s in rows.items():
            if self._poisoned(s.req.rid, okh[i]):
                # poisoned-request isolation: only the offending row
                # finishes (ERROR); every other row of this very launch
                # keeps its token, bit-identical to a fault-free tick
                self.stats.nan_isolated += 1
                self._terminate(s.req, FinishReason.ERROR,
                                error="non-finite logits at decode",
                                deltas=deltas)
                continue
            self.stats.decode_tokens += 1
            self._record(s, int(nxt[i]), deltas)

    def _fail_step(self, active: List[Slot], exc: Exception,
                   deltas: Dict[int, RequestOutput]) -> None:
        """A decode launch raised: the K/V of every request in the failed
        batch can no longer be trusted, so each finishes with ERROR and
        releases its resources — but the engine itself stays up, and
        queued/prefilling requests continue unharmed."""
        self.stats.step_failures += 1
        msg = f"decode step failed: {type(exc).__name__}: {exc}"
        for s in active:
            if s.state == DECODE:           # not already terminated
                self._terminate(s.req, FinishReason.ERROR, error=msg,
                                deltas=deltas)

    # -- bookkeeping -------------------------------------------------------

    def _record(self, slot: Slot, token: int,
                deltas: Dict[int, RequestOutput]) -> bool:
        """Append one emitted token to the request and this tick's delta;
        on completion, release the cache row/pages and free the slot."""
        st = slot.req
        finished = self.sched.record_token(slot, token)
        ro = deltas.get(st.rid)
        if ro is None:
            ro = deltas[st.rid] = RequestOutput(request_id=st.rid,
                                                new_tokens=[],
                                                num_generated=0)
        ro.new_tokens.append(token)
        ro.num_generated = len(st.out_tokens)
        self.stats.generated_tokens += 1
        if finished:
            ro.finished = True
            ro.finish_reason = st.finish_reason
            self.stats.observe_finish(st)
            self.pool.release(slot.index)
            self.sched.free(slot)
        return finished
