"""Cache pools for continuous batching: slot rows or paged blocks.

``SlotCacheManager`` is the PR-1 layout: one batched cache pytree
(``lm.init_cache(cfg, num_slots, max_len)``) whose batch rows are
*slots* — every slot permanently reserves ``max_len`` positions, so pool
memory scales with the worst case even when traffic is short.

``PagedCacheManager`` replaces the full-attention rows with a pool of
fixed-size pages plus per-slot block tables (vLLM-style). Pages are
allocated on demand (prefill blocks at admission, the tail block as
decode crosses a page boundary) and returned when the request finishes
or is preempted, so concurrency is bounded by *tokens actually
resident*, not ``num_slots * max_len``. Sliding-window rings and
SSM/RWKV recurrent state stay slot-resident (O(window)/O(1) per request —
nothing to reclaim).

With ``prefix_cache=True`` the pool is additionally **content-addressed
and ref-counted**: every page carries a reference count (one per block
table naming it), full pages are registered in a prefix-hash table keyed
by ``hash(parent-block hash, page's token ids)``, and a new admission
whose token sequence starts with a registered chain *shares* those pages
(ref count incremented, no recompute) instead of prefilling them. Pages
whose ref count drops to zero but that remain registered stay resident
("cached-free") and are only evicted — positions invalidated, hash entry
dropped — when an allocation finds the free list empty; eviction picks
the page with the fewest prefix hits (LRU among ties), so a hot system
prompt outlives a burst of one-off prompts. Concurrent admissions of
the same uncached prefix deduplicate at registration: a block hash that
collides with an existing entry repoints the table at the registered
page and frees the private duplicate. The capped tail
block of a fully-cached sequence is duplicated copy-on-write: its
content is gathered into the new request's prefill cache and installed
into a fresh private page, so the shared original is never written.
Sharing is only enabled on configs whose every layer stores its state in
pages (all-full-attention mixers); anything slot-resident (rings,
recurrent state) cannot be skipped, so those configs silently run with
sharing off and are bit-identical to the plain pool.

All device ops are jitted once with slot/table/page indices traced, so
serving any number of requests compiles a fixed handful of cache ops;
the pool buffers are donated through every call (no per-step
reallocation).

Which pool an ``EngineCore`` drives — and when pages are claimed — is
decided by the cache backends in ``backend.py``: prefill (one-shot or
chunk-by-chunk via ``fresh_prefill_cache``) always builds a batch-1
contiguous cache that ``write`` installs into the pool in one scatter;
with chunked prefill the paged backend claims each chunk's blocks as the
prompt cursor advances (``ensure_writable``), so pool accounting tracks
the K/V actually resident before the install.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FULL_ATTN, MOE_FFN, ModelConfig
from repro.models import lm
from repro.serving.request import CapacityError

# module-level jits: the trace cache survives across pool instances, so
# repeated engine runs reuse the compiled cache ops instead of re-tracing
# them per manager instance
_WRITE_SLOT = jax.jit(lm.write_cache_slot, donate_argnums=(0,))
_RESET_SLOT = jax.jit(lm.reset_cache_slot, donate_argnums=(0,))
_WRITE_PAGES = jax.jit(lm.write_cache_pages, donate_argnums=(0,))
_RELEASE_PAGES = jax.jit(lm.release_cache_pages, donate_argnums=(0,))
_GATHER_PAGES = jax.jit(lm.gather_cache_pages, donate_argnums=(0,))
_COPY_PAGE = jax.jit(lm.copy_cache_page, donate_argnums=(0,))
_INVALIDATE_PAGES = jax.jit(lm.invalidate_cache_pages, donate_argnums=(0,))

# root of every prefix-hash chain (an arbitrary constant: block hashes
# mix it with the parent hash so chains starting differently never alias)
_HASH_ROOT = 0x9E3779B9


class SlotCacheManager:
    """Fixed pool of ``num_slots`` cache rows.

    Which slot is free belongs to the ``Scheduler`` (the slot lifecycle is
    scheduling state); this class owns the device arrays and the row-level
    operations on them.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.dtype = dtype
        self.cache = lm.init_cache(cfg, num_slots, max_len, dtype)
        self._write = _WRITE_SLOT
        self._reset = _RESET_SLOT

    # -- row writes --------------------------------------------------------

    def release(self, slot: int) -> None:
        """Clear a freed slot row (pos -> -1, states -> 0).

        Isolation is already guaranteed by ``write`` fully overwriting the
        row at the next admission; the reset keeps freed rows inert and
        makes pool state inspectable between requests.
        """
        self.cache = self._reset(self.cache, jnp.int32(slot))

    def write(self, slot: int, src_cache: list) -> None:
        """Install a prefilled batch-1 cache into ``slot``'s row."""
        self.cache = self._write(self.cache, src_cache, jnp.int32(slot))

    def fresh_prefill_cache(self) -> list:
        """Batch-1 cache matching the pool's row shapes, for one prefill."""
        return lm.init_cache(self.cfg, 1, self.max_len, self.dtype)


class PagedCacheManager:
    """Paged K/V pool: ``num_pages`` fixed-size pages + per-slot block
    tables, with optional content-addressed prefix sharing.

    The Python side owns the page lifecycle — free list, per-page ref
    counts, prefix-hash registry, cached-free eviction queue — and the
    ``(num_slots, max_blocks)`` block tables (-1 = unallocated); the
    device side holds the page arrays. Physical page 0 is reserved as the
    null page (read target of unallocated table entries), so
    ``usable_pages = num_pages - 1``. ``num_pages=None`` sizes the pool
    to full slot-cache parity (every slot can hold ``max_len`` tokens) —
    pass something smaller to actually share memory.

    Page lifecycle with ``prefix_cache=True``::

        FREE --alloc--> ACTIVE(ref>=1) --last decref--> CACHED(ref=0,
             registered; content+positions intact) --evict--> FREE
                                       \\--decref (unregistered)--> FREE

    Sharing an admission's prefix moves CACHED (or still-ACTIVE) pages
    straight back into a block table with ``ref += 1``; only truly FREE
    or evicted pages ever lose their contents.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 num_pages: int | None = None, block_size: int = 16,
                 prefix_cache: bool = False, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks = -(-max_len // block_size)
        self.padded_len = self.max_blocks * block_size
        if num_pages is None:
            num_pages = num_slots * self.max_blocks + 1
        assert num_pages >= 2, "need at least the null page + one real page"
        self.num_pages = num_pages
        self.usable_pages = num_pages - 1
        self.dtype = dtype
        # prefix sharing needs (a) every layer's state in pages — a
        # skipped prefill would silently lose sliding-window rings and
        # SSM/RWKV recurrent state (slot-resident) — and (b) per-token
        # prefill numerics. Dropless MoE dispatch (cfg.moe_dropless,
        # cap = S*K) gives every routed assignment a slot, so no token's
        # expert output depends on what the (padded) sequence around it
        # routed and MoE prefixes are shareable; the legacy
        # capacity-dropping dispatch couples tokens across the sequence
        # (which tokens an expert drops depends on prefill shape) and
        # runs unshared. Configs failing either condition run unshared.
        moe_ok = (getattr(cfg, "moe_dropless", False)
                  or all(f != MOE_FFN for f in cfg.ffn_pattern))
        self.prefix_enabled = (bool(prefix_cache)
                               and all(m == FULL_ATTN
                                       for m in cfg.mixer_pattern)
                               and moe_ok
                               and cfg.family != "ssm")
        self.cache = lm.init_paged_cache(cfg, num_slots, num_pages,
                                         block_size, self.padded_len, dtype)
        self._free = list(range(num_pages - 1, 0, -1))   # page 0 = null
        self.tables = np.full((num_slots, self.max_blocks), -1, np.int32)
        # content addressing (all empty when prefix_enabled is False)
        self.ref = np.zeros((num_pages,), np.int32)      # tables naming page
        self._hash_to_page: Dict[int, int] = {}
        self._page_hash: Dict[int, int] = {}             # registered pages
        # ref==0 registered pages, insertion-ordered; eviction picks the
        # least-hit page (LRU among ties), see _take_page
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self._hits: Dict[int, int] = {}      # prefix reuses per registered page
        self._shared_blocks = np.zeros((num_slots,), np.int32)  # per slot
        self._gather_tables: Dict[int, np.ndarray] = {}
        self._pinned: Dict[int, List[int]] = {}          # gather-pinned refs
        # per-slot registration cursor: (blocks published, parent hash) —
        # the slot's sequence is append-only, so publishes resume here
        self._chain_pos: Dict[int, tuple] = {}

    # -- accounting --------------------------------------------------------

    def blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.block_size)

    @property
    def free_page_count(self) -> int:
        """Pages allocatable right now: truly free plus evictable
        cached-free pages (content-cached, ref count zero)."""
        return len(self._free) + len(self._cached)

    @property
    def cached_page_count(self) -> int:
        """Resident zero-ref pages retained for future prefix hits."""
        return len(self._cached)

    @property
    def pages_in_use(self) -> int:
        """Pages named by at least one block table (ref count > 0)."""
        return self.usable_pages - self.free_page_count

    def pages_needed(self, prefill_len: int, cached_tokens: int = 0) -> int:
        """New pages one admission claims: blocks for the prefill plus
        the first decode write, minus the full shared-prefix blocks."""
        return (self.blocks_for(prefill_len + 1)
                - cached_tokens // self.block_size)

    def check_capacity(self, total_tokens: int) -> None:
        """Liveness bound: a request must fit the pool when running alone
        (otherwise preemption could cycle forever) and its block table.
        Prefix hits only ever reduce the pages actually claimed, so the
        unshared worst case is the bound."""
        if self.blocks_for(total_tokens) > self.usable_pages:
            raise CapacityError(
                f"request needs {self.blocks_for(total_tokens)} pages but "
                f"the pool holds {self.usable_pages}")
        if total_tokens > self.padded_len:
            raise CapacityError(
                f"request needs {total_tokens} positions but block tables "
                f"address {self.padded_len}")

    # -- prefix hashing ----------------------------------------------------

    def _block_keys(self, seq: np.ndarray,
                    parent: int = _HASH_ROOT) -> List[tuple]:
        """Chained content keys of ``seq``'s *full* blocks: block b's key
        is ``(hash of block b-1's key, block b's token bytes)``. The
        registry is a dict keyed by these tuples, so a lookup hit
        compares the block's actual token ids (dict equality), never
        just a hash — a hash collision degrades to a near-miss probe,
        not to silently serving another request's K/V. ``seq`` must
        start at a block boundary (``parent`` = the preceding block's
        key hash)."""
        seq = np.ascontiguousarray(np.asarray(seq, np.int32))
        out = []
        for off in range(len(seq) // self.block_size):
            key = (parent, seq[off * self.block_size:
                               (off + 1) * self.block_size].tobytes())
            out.append(key)
            parent = hash(key)
        return out

    def _match_chain(self, seq: np.ndarray):
        """(full-block keys of ``seq``, count matched in the registry)."""
        keys = self._block_keys(seq)
        matched = 0
        for k in keys:
            if k not in self._hash_to_page:
                break
            matched += 1
        return keys, matched

    def _cap_matched(self, matched: int, seq_len: int) -> int:
        """Cap the matched-token count at ``seq_len - 1``: the last token
        is always recomputed so the admission has logits to sample from
        (the capped tail block is duplicated copy-on-write at install)."""
        return min(matched * self.block_size, max(seq_len - 1, 0))

    def match_prefix(self, seq: np.ndarray) -> int:
        """Cached-prefix length (tokens) an admission prefilling ``seq``
        could skip. Pure lookup — claims nothing."""
        if not self.prefix_enabled:
            return 0
        _, matched = self._match_chain(seq)
        return self._cap_matched(matched, len(seq))

    def admission_charge(self, seq: np.ndarray):
        """(cached_tokens, allocatable pages this admission consumes).

        The charge counts the fresh pages the uncached suffix (plus the
        first decode write) needs *and* every matched page currently
        cached-free: retaining those removes them from the free/evictable
        supply just as surely as allocating does, so a same-tick gate
        that didn't charge them could over-admit against pages a hit is
        about to pin down.
        """
        if not self.prefix_enabled:
            return 0, self.pages_needed(len(seq), 0)
        keys, matched = self._match_chain(seq)
        cached = self._cap_matched(matched, len(seq))
        charge = self.pages_needed(len(seq), cached)
        for b in range(self.blocks_for(cached) if cached else 0):
            if self.ref[self._hash_to_page[keys[b]]] == 0:
                charge += 1
        return cached, charge

    def share_prefix(self, slot: int, seq: np.ndarray) -> int:
        """Claim ``seq``'s cached prefix for ``slot``; returns its length.

        Full shared blocks enter the slot's block table with ``ref += 1``
        (they are never written again — ``write`` masks them out of the
        install scatter). When the cap leaves a partial tail inside the
        last matched block, that block's page is *pinned* (ref held, not
        in the table) until ``gather_prefix`` copies its contents into
        the admission's prefill cache — the copy-on-write read side; the
        write side lands in a fresh private page at install.
        """
        assert (self.tables[slot] < 0).all(), "slot still owns pages"
        if not self.prefix_enabled:
            return 0
        keys, matched = self._match_chain(seq)
        cached = self._cap_matched(matched, len(seq))
        self._shared_blocks[slot] = full = cached // self.block_size
        if cached == 0:
            return 0
        # seed the slot's registration cursor past the shared prefix so
        # install/decode publishes hash only the blocks this request adds
        self._chain_pos[slot] = (full, hash(keys[full - 1]) if full
                                 else _HASH_ROOT)
        nb = self.blocks_for(cached)
        gather = np.zeros((self.max_blocks,), np.int32)     # null page tail
        for b in range(nb):
            page = self._hash_to_page[keys[b]]
            self._retain(page)
            self._hits[page] = self._hits.get(page, 0) + 1
            gather[b] = page
            if b < full:
                self.tables[slot, b] = page
        self._gather_tables[slot] = gather
        self._pinned[slot] = [int(gather[b]) for b in range(full, nb)]
        return cached

    def gather_prefix(self, slot: int, cache: list) -> list:
        """Copy the slot's shared-prefix pages into a fresh batch-1
        prefill cache (one jitted gather), releasing the COW pin."""
        gather = self._gather_tables.pop(slot)
        cache = _GATHER_PAGES(cache, self.cache, jnp.asarray(gather))
        for page in self._pinned.pop(slot, []):
            self._decref(page)
        return cache

    def register_prefix(self, slot: int, seq: np.ndarray) -> None:
        """Content-register the slot's full, finalized blocks of ``seq``
        so later admissions can share them. Idempotent; first writer
        wins (an identical page already registered keeps the registry
        entry and this slot's private copy stays unregistered).

        The per-slot chain cursor makes repeated publishes incremental:
        a slot's token sequence is append-only, so each install or
        decode boundary-crossing hashes only the blocks added since the
        last publish instead of re-walking the whole sequence.
        """
        if not self.prefix_enabled:
            return
        start, parent = self._chain_pos.get(slot, (0, _HASH_ROOT))
        nb = len(seq) // self.block_size
        if start >= nb:
            return
        window = np.asarray(seq, np.int32)[start * self.block_size:
                                           nb * self.block_size]
        self._register_window(slot, window, start, parent)

    def register_tokens(self, slot: int, prompt: np.ndarray,
                        out_tokens: List[int], upto: int) -> None:
        """Decode-path publish: register blocks finalized below position
        ``upto`` of the slot's (prompt + generated) sequence.

        Materializes only the tokens past the slot's chain cursor — on a
        page-boundary crossing that is one block's worth — so per-request
        publication cost stays O(tokens), not O(tokens^2 / block_size).
        """
        if not self.prefix_enabled:
            return
        start, parent = self._chain_pos.get(slot, (0, _HASH_ROOT))
        nb = upto // self.block_size
        lo, hi = start * self.block_size, nb * self.block_size
        if lo >= hi:
            return
        plen = len(prompt)
        parts = []
        if lo < plen:
            parts.append(np.asarray(prompt[lo:min(hi, plen)], np.int32))
        if hi > plen:
            parts.append(np.asarray(out_tokens[max(lo - plen, 0):
                                               hi - plen], np.int32))
        self._register_window(slot, np.concatenate(parts), start, parent)

    def _register_window(self, slot: int, window: np.ndarray,
                         start_block: int, parent: int) -> None:
        """Register ``window`` (token block(s) starting at block
        ``start_block``'s boundary) and advance the slot's cursor.

        A key that is already registered to a *different* page means two
        requests prefilled the same uncached prefix concurrently (each
        built a private copy; only the first registered). Instead of
        first-writer-wins leaving the duplicate invisible to sharing,
        the duplicate is deduplicated in place: the slot's table is
        repointed at the registered page and the private copy freed —
        so identical content is resident exactly once.
        """
        for off, key in enumerate(self._block_keys(window, parent)):
            b = start_block + off
            page = int(self.tables[slot, b])
            if page < 0:
                break
            self._chain_pos[slot] = (b + 1, hash(key))
            reg = self._hash_to_page.get(key)
            if reg is not None:
                if (reg != page and page not in self._page_hash
                        and self.ref[page] == 1):
                    self._retain(reg)
                    self.tables[slot, b] = reg
                    self._decref(page)      # private dup -> invalidate+free
                    self._hits[reg] = self._hits.get(reg, 0) + 1
                continue
            if page in self._page_hash:
                continue
            self._hash_to_page[key] = page
            self._page_hash[page] = key

    # -- allocation --------------------------------------------------------

    def _retain(self, page: int) -> None:
        if self.ref[page] == 0:
            self._cached.pop(page, None)
        self.ref[page] += 1

    def _decref(self, page: int) -> None:
        if self.ref[page] <= 0:
            # a silent decref-below-zero here would let the page be
            # handed to two owners later — fail at the corruption site
            raise RuntimeError(f"decref of unreferenced page {page}: "
                               f"double free or table corruption")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            if page in self._page_hash:
                self._cached[page] = None       # resident, evictable (LRU)
            else:
                self.cache = _INVALIDATE_PAGES(
                    self.cache, jnp.asarray([page], np.int32))
                self._free.append(page)

    def _take_page(self) -> Optional[int]:
        """Pop a writable page: the free list first, then the *least
        reused* cached-free page (evicted: hash entry dropped, positions
        invalidated). Weighting eviction by prefix hit count keeps a hot
        shared prefix — e.g. the system prompt — resident through a
        burst of one-off prompts that pure LRU would let flush it; ties
        fall back to LRU (min() scans the OrderedDict in insertion
        order, so the oldest least-hit page wins). None when every page
        is referenced."""
        if self._free:
            return self._free.pop()
        if self._cached:
            page = min(self._cached, key=lambda p: self._hits.get(p, 0))
            self._cached.pop(page)
            self._hits.pop(page, None)
            del self._hash_to_page[self._page_hash.pop(page)]
            self.cache = _INVALIDATE_PAGES(self.cache,
                                           jnp.asarray([page], np.int32))
            return page
        return None

    def ensure(self, slot: int, block: int) -> bool:
        """Allocate ``block`` for ``slot`` if needed; False when out of
        pages (the engine preempts a request and retries)."""
        if self.tables[slot, block] >= 0:
            return True
        page = self._take_page()
        if page is None:
            return False
        self.ref[page] = 1
        self.tables[slot, block] = page
        return True

    def ensure_writable(self, slot: int, block: int) -> bool:
        """``ensure`` plus copy-on-write: a resident but *shared* block
        (ref count > 1) is duplicated into a private page before the
        caller writes into it, so sharers never observe the write. False
        when out of pages."""
        page = int(self.tables[slot, block])
        if page < 0:
            return self.ensure(slot, block)
        if self.ref[page] <= 1:
            return True
        fresh = self._take_page()
        if fresh is None:
            return False
        assert block >= self._shared_blocks[slot], \
            "COW inside the shared prefix would break the install mask"
        self.cache = _COPY_PAGE(self.cache, jnp.int32(page),
                                jnp.int32(fresh))
        self.ref[fresh] = 1
        self.tables[slot, block] = fresh
        self._decref(page)
        return True

    # -- device ops --------------------------------------------------------

    def write(self, slot: int, src_cache: list) -> None:
        """Scatter a prefilled batch-1 cache into the slot's pages (and
        its slot-resident rows). Shared-prefix blocks are masked out of
        the scatter — they already hold this content and other requests
        may be reading them."""
        t = self.tables[slot].copy()
        t[: self._shared_blocks[slot]] = -1
        table = np.where(t >= 0, t, self.num_pages).astype(np.int32)
        self.cache = _WRITE_PAGES(self.cache, src_cache,
                                  jnp.asarray(table), jnp.int32(slot))

    def release(self, slot: int) -> None:
        """Drop the slot's claim on its pages and reset its slot-resident
        rows. Each page's ref count is decremented; pages reaching zero
        go back to the free list (positions invalidated) unless they are
        content-registered, in which case they stay resident as
        cached-free prefix pages until evicted by an allocation."""
        if slot in self._pinned:
            raise RuntimeError(
                f"release of slot {slot} during a prefix gather: its COW "
                f"pins would leak (finish the admission first)")
        owned = [int(p) for p in self.tables[slot] if p >= 0]
        to_free = []
        for page in owned:
            if self.ref[page] <= 0:
                raise RuntimeError(f"double free of page {page} "
                                   f"(slot {slot})")
            self.ref[page] -= 1
            if self.ref[page] == 0:
                if page in self._page_hash:
                    self._cached[page] = None
                else:
                    to_free.append(page)
        table = np.full((self.max_blocks,), self.num_pages, np.int32)
        table[: len(to_free)] = to_free
        self.cache = _RELEASE_PAGES(self.cache, jnp.asarray(table),
                                    jnp.int32(slot))
        self._free.extend(to_free)
        self.tables[slot] = -1
        self._shared_blocks[slot] = 0
        self._gather_tables.pop(slot, None)
        self._chain_pos.pop(slot, None)

    # -- views -------------------------------------------------------------

    def read_tables(self) -> np.ndarray:
        """(num_slots, max_blocks) gather tables: unallocated -> null page."""
        return np.where(self.tables >= 0, self.tables, 0).astype(np.int32)

    def fresh_prefill_cache(self) -> list:
        """Batch-1 contiguous cache whose rows split evenly into blocks."""
        return lm.init_cache(self.cfg, 1, self.padded_len, self.dtype)

    def check_invariants(self) -> None:
        """Assert pool-conservation invariants (test hook).

        Free-list + cached-free + referenced pages partition the usable
        pool; every block-table entry is counted by exactly its page's
        ref count; nothing is simultaneously free and referenced.
        """
        free = set(self._free)
        cached = set(self._cached)
        pinned: Dict[int, int] = {}
        for pages in self._pinned.values():
            for p in pages:
                pinned[p] = pinned.get(p, 0) + 1
        refs = np.zeros_like(self.ref)
        for row in self.tables:
            for p in row[row >= 0]:
                refs[p] += 1
        for p, n in pinned.items():
            refs[p] += n
        assert not (free & cached), "page both free and cached"
        assert 0 not in free and 0 not in cached, "null page escaped"
        in_use = {p for p in range(1, self.num_pages) if self.ref[p] > 0}
        assert not (in_use & free), "referenced page on the free list"
        assert not (in_use & cached), "referenced page marked cached-free"
        assert (refs == self.ref).all(), \
            f"ref counts drifted: {self.ref.tolist()} vs {refs.tolist()}"
        total = len(free) + len(cached) + len(in_use)
        assert total == self.usable_pages, \
            f"pages leaked: {total} accounted of {self.usable_pages}"
        for page, h in self._page_hash.items():
            assert self._hash_to_page.get(h) == page, "hash registry skew"
        assert len(self._hash_to_page) == len(self._page_hash)
        assert set(self._cached) <= set(self._page_hash), \
            "cached-free page without a registry entry"
