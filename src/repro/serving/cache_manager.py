"""Cache pools for continuous batching: slot rows or paged blocks.

``SlotCacheManager`` is the PR-1 layout: one batched cache pytree
(``lm.init_cache(cfg, num_slots, max_len)``) whose batch rows are
*slots* — every slot permanently reserves ``max_len`` positions, so pool
memory scales with the worst case even when traffic is short.

``PagedCacheManager`` replaces the full-attention rows with a pool of
fixed-size pages plus per-slot block tables (vLLM-style). Pages are
allocated on demand (prefill blocks at admission, the tail block as
decode crosses a page boundary) and returned to a free list when the
request finishes or is preempted, so concurrency is bounded by *tokens
actually resident*, not ``num_slots * max_len``. Sliding-window rings and
SSM/RWKV recurrent state stay slot-resident (O(window)/O(1) per request —
nothing to reclaim).

All device ops are jitted once with slot/table indices traced, so serving
any number of requests compiles a fixed handful of cache ops; the pool
buffers are donated through every call (no per-step reallocation).

Which pool an ``EngineCore`` drives — and when pages are claimed — is
decided by the cache backends in ``backend.py``: prefill (one-shot or
chunk-by-chunk via ``fresh_prefill_cache``) always builds a batch-1
contiguous cache that ``write`` installs into the pool in one scatter;
with chunked prefill the paged backend claims each chunk's blocks as the
prompt cursor advances (``ensure``), so pool accounting tracks the K/V
actually resident before the install.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm

# module-level jits: the trace cache survives across pool instances, so
# repeated engine runs reuse the compiled cache ops instead of re-tracing
# them per manager instance
_WRITE_SLOT = jax.jit(lm.write_cache_slot, donate_argnums=(0,))
_RESET_SLOT = jax.jit(lm.reset_cache_slot, donate_argnums=(0,))
_WRITE_PAGES = jax.jit(lm.write_cache_pages, donate_argnums=(0,))
_RELEASE_PAGES = jax.jit(lm.release_cache_pages, donate_argnums=(0,))


class SlotCacheManager:
    """Fixed pool of ``num_slots`` cache rows.

    Which slot is free belongs to the ``Scheduler`` (the slot lifecycle is
    scheduling state); this class owns the device arrays and the row-level
    operations on them.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.dtype = dtype
        self.cache = lm.init_cache(cfg, num_slots, max_len, dtype)
        self._write = _WRITE_SLOT
        self._reset = _RESET_SLOT

    # -- row writes --------------------------------------------------------

    def release(self, slot: int) -> None:
        """Clear a freed slot row (pos -> -1, states -> 0).

        Isolation is already guaranteed by ``write`` fully overwriting the
        row at the next admission; the reset keeps freed rows inert and
        makes pool state inspectable between requests.
        """
        self.cache = self._reset(self.cache, jnp.int32(slot))

    def write(self, slot: int, src_cache: list) -> None:
        """Install a prefilled batch-1 cache into ``slot``'s row."""
        self.cache = self._write(self.cache, src_cache, jnp.int32(slot))

    def fresh_prefill_cache(self) -> list:
        """Batch-1 cache matching the pool's row shapes, for one prefill."""
        return lm.init_cache(self.cfg, 1, self.max_len, self.dtype)


class PagedCacheManager:
    """Paged K/V pool: ``num_pages`` fixed-size pages + per-slot block tables.

    The Python side owns the free-page list and the ``(num_slots,
    max_blocks)`` block tables (-1 = unallocated); the device side holds
    the page arrays. Physical page 0 is reserved as the null page (read
    target of unallocated table entries), so ``usable_pages = num_pages -
    1``. ``num_pages=None`` sizes the pool to full slot-cache parity
    (every slot can hold ``max_len`` tokens) — pass something smaller to
    actually share memory.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 num_pages: int | None = None, block_size: int = 16,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.block_size = block_size
        self.max_blocks = -(-max_len // block_size)
        self.padded_len = self.max_blocks * block_size
        if num_pages is None:
            num_pages = num_slots * self.max_blocks + 1
        assert num_pages >= 2, "need at least the null page + one real page"
        self.num_pages = num_pages
        self.usable_pages = num_pages - 1
        self.dtype = dtype
        self.cache = lm.init_paged_cache(cfg, num_slots, num_pages,
                                         block_size, self.padded_len, dtype)
        self._free = list(range(num_pages - 1, 0, -1))   # page 0 = null
        self.tables = np.full((num_slots, self.max_blocks), -1, np.int32)

    # -- accounting --------------------------------------------------------

    def blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.block_size)

    @property
    def free_page_count(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.usable_pages - len(self._free)

    def can_admit(self, prefill_len: int, reserved: int = 0) -> bool:
        """Pages available for the prefill plus the first decode write.

        ``reserved`` discounts pages already promised to earlier
        admissions in the same tick (the engine's gate reserves as it
        approves, before any allocation happens).
        """
        return (self.free_page_count - reserved
                >= self.blocks_for(prefill_len + 1))

    def check_capacity(self, total_tokens: int) -> None:
        """Liveness bound: a request must fit the pool when running alone
        (otherwise preemption could cycle forever) and its block table."""
        if self.blocks_for(total_tokens) > self.usable_pages:
            raise ValueError(
                f"request needs {self.blocks_for(total_tokens)} pages but "
                f"the pool holds {self.usable_pages}")
        if total_tokens > self.padded_len:
            raise ValueError(
                f"request needs {total_tokens} positions but block tables "
                f"address {self.padded_len}")

    # -- allocation --------------------------------------------------------

    def allocate_prefill(self, slot: int, prefill_len: int) -> None:
        """Claim the pages that will hold a prefilled request's K/V."""
        assert (self.tables[slot] < 0).all(), "slot still owns pages"
        nb = self.blocks_for(prefill_len)
        if nb > len(self._free):
            raise RuntimeError("admission without enough free pages")
        for b in range(nb):
            self.tables[slot, b] = self._free.pop()

    def ensure(self, slot: int, block: int) -> bool:
        """Allocate ``block`` for ``slot`` if needed; False when out of
        pages (the engine preempts a request and retries)."""
        if self.tables[slot, block] >= 0:
            return True
        if not self._free:
            return False
        self.tables[slot, block] = self._free.pop()
        return True

    # -- device ops --------------------------------------------------------

    def write(self, slot: int, src_cache: list) -> None:
        """Scatter a prefilled batch-1 cache into the slot's pages (and
        its slot-resident rows)."""
        table = np.where(self.tables[slot] >= 0, self.tables[slot],
                         self.num_pages).astype(np.int32)
        self.cache = _WRITE_PAGES(self.cache, src_cache,
                                  jnp.asarray(table), jnp.int32(slot))

    def release(self, slot: int) -> None:
        """Invalidate the slot's pages (pos -> -1), reset its slot-resident
        rows, and return the pages to the free list."""
        owned = self.tables[slot][self.tables[slot] >= 0]
        table = np.full((self.max_blocks,), self.num_pages, np.int32)
        table[: len(owned)] = owned
        self.cache = _RELEASE_PAGES(self.cache, jnp.asarray(table),
                                    jnp.int32(slot))
        self._free.extend(int(p) for p in owned)
        self.tables[slot] = -1

    # -- views -------------------------------------------------------------

    def read_tables(self) -> np.ndarray:
        """(num_slots, max_blocks) gather tables: unallocated -> null page."""
        return np.where(self.tables >= 0, self.tables, 0).astype(np.int32)

    def fresh_prefill_cache(self) -> list:
        """Batch-1 contiguous cache whose rows split evenly into blocks."""
        return lm.init_cache(self.cfg, 1, self.padded_len, self.dtype)
