"""Slot-based KV/recurrent cache pool for continuous batching.

The pool is one batched cache pytree (``lm.init_cache(cfg, num_slots,
max_len)``) whose batch rows are *slots*. The batch-major, position-
indexed layout means both lifecycle operations are pure row writes:

  * admission: a request prefilled into a batch-1 cache is scattered into
    its slot row (``lm.write_cache_slot``)
  * release:   the row is cleared (``lm.reset_cache_slot``) before the
    scheduler returns the slot to its free pool

Both are jitted once with the slot index traced, so serving any number of
requests compiles exactly two cache ops; the pool buffers are donated
through every call (no per-step reallocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm

# module-level jits: the trace cache survives across pool instances, so
# repeated engine runs reuse the two compiled cache ops instead of
# re-tracing them per SlotCacheManager
_WRITE_SLOT = jax.jit(lm.write_cache_slot, donate_argnums=(0,))
_RESET_SLOT = jax.jit(lm.reset_cache_slot, donate_argnums=(0,))


class SlotCacheManager:
    """Fixed pool of ``num_slots`` cache rows.

    Which slot is free belongs to the ``Scheduler`` (the slot lifecycle is
    scheduling state); this class owns the device arrays and the row-level
    operations on them.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.dtype = dtype
        self.cache = lm.init_cache(cfg, num_slots, max_len, dtype)
        self._write = _WRITE_SLOT
        self._reset = _RESET_SLOT

    # -- row writes --------------------------------------------------------

    def release(self, slot: int) -> None:
        """Clear a freed slot row (pos -> -1, states -> 0).

        Isolation is already guaranteed by ``write`` fully overwriting the
        row at the next admission; the reset keeps freed rows inert and
        makes pool state inspectable between requests.
        """
        self.cache = self._reset(self.cache, jnp.int32(slot))

    def write(self, slot: int, src_cache: list) -> None:
        """Install a prefilled batch-1 cache into ``slot``'s row."""
        self.cache = self._write(self.cache, src_cache, jnp.int32(slot))

    def fresh_prefill_cache(self) -> list:
        """Batch-1 cache matching the pool's row shapes, for one prefill."""
        return lm.init_cache(self.cfg, 1, self.max_len, self.dtype)
