"""Cache backends: the pluggable seam between EngineCore and pool layout.

The step-driven core is backend-agnostic; everything layout-specific —
slot rows vs paged blocks, admission gating, prefix sharing, per-chunk
page allocation, preemption when the pool runs dry, and how a decode
launch names its rows — lives behind these two small classes instead of
engine subclass method overrides.

``SlotBackend`` is the trivial case: every slot permanently owns a
``max_len`` cache row, so admission needs nothing beyond a FREE slot and
decode always launches the full slot count.

``PagedBackend`` manages the paged K/V pool: admission is gated on free
pages (strict FIFO head-of-line) charging only the *uncached* suffix
when prefix caching is on, ``begin_prefill`` claims an admission's
shared-prefix pages (ref counted) and ``gather_prefill_cache`` seeds its
batch-1 prefill cache from them, ``install`` masks shared blocks out of
the pool scatter and content-registers the newly written full blocks,
chunked prefill allocates each chunk's blocks as the prompt cursor
advances, decode allocates the tail block on demand (registering each
block it finalizes and duplicating copy-on-write any block it would
write while shared), and when the pool runs dry the latest-admitted
request — decoding *or* mid chunked prefill — is preempted (its pages
decref'd, the request requeued at the front). Decode launches pack the
active requests into the low batch rows and pass the packed count as a
traced scalar — the paged-attention kernel skips padding rows without
retracing (dynamic valid-row masking); ``decode_buckets=True``
additionally shrinks the launch width to the active count rounded up to
a power of two (one retrace per bucket — a legacy knob now that padding
rows cost nothing in-kernel).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.cache_manager import PagedCacheManager, SlotCacheManager
from repro.serving.scheduler import DECODE, PREFILL, Scheduler, Slot
from repro.serving.request import RequestState


class SlotBackend:
    """Slot-row pool: every slot reserves ``max_len`` positions."""

    paged = False
    decode_fn = "decode"            # EngineCore fns attribute to launch

    def make_pool(self, cfg: ModelConfig, num_slots: int, max_len: int):
        return SlotCacheManager(cfg, num_slots, max_len)

    def check_capacity(self, pool, total_tokens: int) -> None:
        pass                        # Scheduler.submit enforces max_len

    def pool_idle(self, pool) -> bool:
        """True when nothing is resident in the pool — the state in
        which an admission refusal proves the request can *never* fit."""
        return True                 # slot rows are per-slot, never scarce

    def admission_gate(self, pool):
        return None                 # a FREE slot suffices

    def begin_prefill(self, pool, slot: Slot, st: RequestState,
                      toks: np.ndarray) -> int:
        """Claim whatever cached prefix the pool holds for this
        admission; returns the number of prefill tokens skipped."""
        return 0                    # slot rows are never shared

    def gather_prefill_cache(self, pool, slot: Slot, cached: int, cache):
        """Seed a fresh batch-1 prefill cache with the shared prefix."""
        return cache                # nothing cached, nothing to gather

    def install(self, pool, slot: Slot, st: RequestState, src_cache,
                toks: np.ndarray) -> None:
        """Install a finished prefill into the pool (and publish any
        newly finalized blocks to the prefix cache)."""
        pool.write(slot.index, src_cache)

    def alloc_prefill_chunk(self, pool, sched: Scheduler, stats,
                            slot: Slot, upto_tokens: int,
                            faults=None) -> bool:
        return True                 # the row already exists

    def pre_decode(self, pool, sched: Scheduler, stats,
                   active: List[Slot], faults=None) -> List[Slot]:
        return active               # rows never run out

    def decode_rows(self, pool, active: List[Slot], num_slots: int
                    ) -> Tuple[int, Dict[int, Slot], dict]:
        """Launch width, row->slot mapping, and backend-extra jit args."""
        return num_slots, {s.index: s for s in active}, {}


class PagedBackend(SlotBackend):
    """Paged K/V pool: block tables, ref-counted pages, prefix sharing,
    copy-on-write, on-demand allocation, preemption."""

    paged = True
    decode_fn = "decode_paged"

    def __init__(self, num_pages: Optional[int] = None,
                 block_size: int = 16, decode_buckets: bool = False,
                 prefix_cache: bool = False):
        self.num_pages = num_pages
        self.block_size = block_size
        self.decode_buckets = decode_buckets
        self.prefix_cache = prefix_cache

    def make_pool(self, cfg: ModelConfig, num_slots: int, max_len: int):
        return PagedCacheManager(cfg, num_slots, max_len,
                                 num_pages=self.num_pages,
                                 block_size=self.block_size,
                                 prefix_cache=self.prefix_cache)

    def check_capacity(self, pool, total_tokens: int) -> None:
        pool.check_capacity(total_tokens)

    def pool_idle(self, pool) -> bool:
        # cached-free pages are evictable on demand, so "idle" means no
        # referenced pages — the admission gate already counts cached-free
        # pages as allocatable supply
        return pool.pages_in_use == 0

    def admission_gate(self, pool):
        # admissions() gates the whole batch before the engine allocates
        # any pages, so the gate must reserve as it approves: otherwise
        # two requests could both pass against the same free pages. The
        # charge covers the uncached suffix plus the first decode
        # write's block AND any matched pages currently cached-free
        # (retaining a hit pins them, shrinking the evictable supply as
        # surely as an allocation would). Registrations/evictions between
        # this snapshot and the admission's share_prefix can still shift
        # the match; the preemption fallback in the allocation paths
        # absorbs that residual race.
        reserved = 0

        def gate(st: RequestState) -> bool:
            nonlocal reserved
            _, charge = pool.admission_charge(st.prefill_token_seq())
            if pool.free_page_count - reserved < charge:
                return False
            reserved += charge
            return True

        return gate

    # -- prefix sharing ----------------------------------------------------

    def begin_prefill(self, pool, slot: Slot, st: RequestState,
                      toks: np.ndarray) -> int:
        cached = pool.share_prefix(slot.index, toks)
        if cached:
            st.cached_prefix_tokens += cached
        return cached

    def gather_prefill_cache(self, pool, slot: Slot, cached: int, cache):
        if cached:
            cache = pool.gather_prefix(slot.index, cache)
        return cache

    def install(self, pool, slot: Slot, st: RequestState, src_cache,
                toks: np.ndarray) -> None:
        pool.write(slot.index, src_cache)
        pool.register_prefix(slot.index, toks)

    # -- allocation / preemption -------------------------------------------

    def alloc_prefill_chunk(self, pool, sched: Scheduler, stats,
                            slot: Slot, upto_tokens: int,
                            faults=None) -> bool:
        """Claim the blocks covering prompt positions [0, upto_tokens).

        Chunked prefill allocates pages as the prompt cursor advances
        instead of all at admission, so pool pressure tracks the K/V
        actually resident. Shared-prefix blocks below the cursor are
        already claimed by ``begin_prefill`` (a self-preemption restarts
        from the re-matched prefix boundary, so they are always
        resident) — only the blocks this chunk adds are walked. When the
        pool runs dry mid-prefill (decode tail allocations got there
        first), the *latest-admitted* request is preempted — which is
        usually the prefilling slot itself (ties on admit_step also
        self-preempt): a new prompt must not evict older in-flight
        decodes. Returns False when ``slot`` was preempted (its partial
        chunk cache is discarded and it re-prefills from the queue
        front).
        """
        first = slot.prefill_pos // pool.block_size
        for block in range(first, pool.blocks_for(upto_tokens)):
            # an injected allocation fault behaves exactly like a dry
            # pool: the same preemption/retry machinery runs (each
            # scheduled fault fires once, so the loop still terminates)
            while ((faults is not None and faults.alloc_fault(sched.step))
                   or not pool.ensure_writable(slot.index, block)):
                victims = [s for s in sched.slots
                           if s.state in (DECODE, PREFILL)
                           and s.req is not None]
                newest = max(v.req.admit_step for v in victims)
                victim = (slot if slot.req.admit_step == newest else
                          max(victims, key=lambda v: v.req.admit_step))
                self._evict(pool, sched, stats, victim)
                if victim is slot:
                    return False
        return True

    def pre_decode(self, pool, sched: Scheduler, stats,
                   active: List[Slot], faults=None) -> List[Slot]:
        """Allocate each active slot's tail page, preempting the latest-
        admitted request when the pool is exhausted. Crossing a page
        boundary finalizes the previous block: its content is registered
        in the prefix cache so later admissions (multi-turn resubmits,
        preemption resumes) can share it."""
        for s in active:
            if s.state != DECODE:   # already preempted this tick
                continue
            block = s.next_pos // pool.block_size
            fresh = pool.tables[s.index, block] < 0
            preempted = False
            while ((faults is not None and faults.alloc_fault(sched.step))
                   or not pool.ensure_writable(s.index, block)):
                if not self._reclaim(pool, sched, stats, protect=s):
                    self._evict(pool, sched, stats, s)
                    preempted = True
                    break
            if fresh and not preempted and pool.prefix_enabled:
                pool.register_tokens(s.index, s.req.prompt,
                                     s.req.out_tokens, s.next_pos)
        return [s for s in active if s.state == DECODE]

    def _reclaim(self, pool, sched: Scheduler, stats, protect: Slot) -> bool:
        """Preempt the latest-admitted request other than ``protect`` —
        decoding or mid chunked prefill — releasing its page refs. False
        when there is nothing left to reclaim."""
        victims = [s for s in sched.slots
                   if s.state in (DECODE, PREFILL) and s is not protect]
        if not victims:
            return False
        self._evict(pool, sched, stats,
                    max(victims, key=lambda v: v.req.admit_step))
        return True

    @staticmethod
    def _evict(pool, sched: Scheduler, stats, victim: Slot) -> None:
        """Drop one request's page refs and requeue it at the front.
        Pages it shared with other requests (or that remain content-
        registered) stay resident; only its private unregistered pages
        return to the free list."""
        pool.release(victim.index)
        sched.preempt(victim)
        stats.preemptions += 1

    def decode_rows(self, pool, active: List[Slot], num_slots: int
                    ) -> Tuple[int, Dict[int, Slot], dict]:
        # active requests are always packed into the low batch rows and
        # the packed count rides along as a *traced* scalar: the paged
        # attention kernel masks rows >= active dynamically, so every
        # active-request count reuses the one full-width trace.
        # decode_buckets additionally shrinks the launch width to the
        # next power of two (one retrace per bucket) — a legacy knob now
        # that padding rows are skipped in-kernel either way.
        m = (_bucket_pow2(len(active), num_slots) if self.decode_buckets
             else num_slots)
        rows = {i: s for i, s in enumerate(active)}
        tables = np.zeros((m, pool.max_blocks), np.int32)
        slot_ids = np.full((m,), num_slots, np.int32)    # OOB = padding
        read_tables = pool.read_tables()
        for i, s in rows.items():
            tables[i] = read_tables[s.index]
            slot_ids[i] = s.index
        return m, rows, {"tables": tables, "slot_ids": slot_ids,
                         "active": np.int32(len(active))}


def _bucket_pow2(n: int, cap: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)
