"""Deterministic fault injection for the serving engine.

The robustness layer (abort, deadlines, NaN isolation, preemption under
allocation failure, step-failure containment) is only trustworthy if the
failure paths actually run — in normal operation they almost never do.
``FaultInjector`` gives every failure path a seam the tests (and the
crash-consistency sweep in ``tests/test_robustness.py``) can drive
*deterministically*: faults are scheduled against the engine's tick
clock, so the same schedule against the same workload reproduces the
same interleaving bit-for-bit, with no reliance on real NaNs, real OOM,
or real backend crashes.

Injection points (each consulted by the core/backend at the real code
path the fault exercises, so everything downstream of the seam is the
production path, not a test double):

  * ``alloc_fault`` — consumed by the paged backend right before a page
    allocation (``ensure_writable``): an injected failure behaves
    exactly like a dry pool, driving the preemption/retry machinery.
  * ``poisoned`` — consulted where the engine checks the decode/prefill
    per-row finite-logit flag: an injected hit marks request ``rid``'s
    row non-finite at tick ``t``, driving the poisoned-request isolation
    path (finish ERROR, release, batch survivors untouched).
  * ``raise_step_error`` — raised inside the engine's decode-launch try
    block: stands in for a backend/device failure of the whole tick.
  * ``sleep`` — stalls a tick for a scheduled duration: a straggler
    tick for wall-clock watchdog/metrics behavior. ``hold_at`` is the
    deterministic variant: the tick blocks on an event until the test
    calls ``release`` (or a safety timeout fires), which is how the
    server tests pin the engine mid-flight while they fill the
    admission queue (deterministic HTTP 429) or disconnect a streaming
    client (deterministic abort), with no sleeps to race against.

``FaultInjector.random(seed, ...)`` builds a seeded randomized schedule
(the crash-consistency sweep's driver); the fluent ``*_at`` methods
build exact scripted schedules. ``log`` records every fault actually
delivered, so tests can assert a schedule fired.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

# safety net for hold_at: a test that forgets to release() fails its own
# assertions instead of hanging the suite forever
HOLD_TIMEOUT_S = 30.0


class FaultInjectedError(RuntimeError):
    """An injected backend step failure (never raised in production)."""


@dataclasses.dataclass
class FaultInjector:
    """Tick-scheduled fault plan, consumed as the engine runs.

    All schedules key on the engine tick clock (``Scheduler.step``).
    ``alloc_faults`` entries are *consumed* (each injected allocation
    failure fires once); row poisons, step errors, and slow ticks fire
    whenever their tick is reached.
    """

    alloc_faults: Dict[int, int] = dataclasses.field(default_factory=dict)
    nan_rows: Set[Tuple[int, int]] = dataclasses.field(default_factory=set)
    step_errors: Dict[int, str] = dataclasses.field(default_factory=dict)
    slow_ticks: Dict[int, float] = dataclasses.field(default_factory=dict)
    holds: Dict[int, threading.Event] = dataclasses.field(default_factory=dict)
    log: List[dict] = dataclasses.field(default_factory=list)

    # -- scripted-schedule builders (fluent) -------------------------------

    def alloc_fault_at(self, tick: int, count: int = 1) -> "FaultInjector":
        """Fail the next ``count`` page allocations attempted at ``tick``."""
        self.alloc_faults[tick] = self.alloc_faults.get(tick, 0) + count
        return self

    def nan_at(self, tick: int, rid: int) -> "FaultInjector":
        """Poison request ``rid``'s logit row at ``tick`` (prefill or
        decode, whichever the request reaches that tick)."""
        self.nan_rows.add((tick, rid))
        return self

    def step_error_at(self, tick: int,
                      message: str = "injected backend step failure"
                      ) -> "FaultInjector":
        self.step_errors[tick] = message
        return self

    def slow_tick_at(self, tick: int, seconds: float) -> "FaultInjector":
        self.slow_ticks[tick] = seconds
        return self

    def hold_at(self, tick: int) -> "FaultInjector":
        """Block ``tick`` (on the thread driving ``step()``) until
        :meth:`release` — the deterministic straggler the server tests
        use to pin the engine while they act from another thread."""
        self.holds[tick] = threading.Event()
        return self

    def release(self, tick: Optional[int] = None) -> None:
        """Release one held tick (or all of them when ``tick`` is None).
        Safe to call before the tick is reached: the hold is consumed
        pre-released and never blocks."""
        for t, ev in self.holds.items():
            if tick is None or t == tick:
                ev.set()

    # -- consumption (called by core/backend) ------------------------------

    def alloc_fault(self, tick: int) -> bool:
        """True exactly once per scheduled allocation failure at ``tick``."""
        left = self.alloc_faults.get(tick, 0)
        if left <= 0:
            return False
        self.alloc_faults[tick] = left - 1
        self.log.append({"kind": "alloc_fault", "tick": tick})
        return True

    def poisoned(self, tick: int, rid: int) -> bool:
        """True when ``rid``'s logit row is scheduled non-finite at
        ``tick`` (the injected analogue of the in-jit isfinite guard)."""
        if (tick, rid) not in self.nan_rows:
            return False
        self.log.append({"kind": "nan", "tick": tick, "rid": rid})
        return True

    def raise_step_error(self, tick: int) -> None:
        msg = self.step_errors.get(tick)
        if msg is not None:
            self.log.append({"kind": "step_error", "tick": tick})
            raise FaultInjectedError(msg)

    def sleep(self, tick: int) -> None:
        dt = self.slow_ticks.get(tick)
        if dt:
            self.log.append({"kind": "slow_tick", "tick": tick, "dt": dt})
            time.sleep(dt)
        ev = self.holds.get(tick)
        if ev is not None and not ev.is_set():
            self.log.append({"kind": "hold", "tick": tick})
            ev.wait(HOLD_TIMEOUT_S)

    # -- randomized schedules ----------------------------------------------

    @classmethod
    def random(cls, seed: int, ticks: int, rids: List[int],
               p_alloc: float = 0.0, p_nan: float = 0.0,
               p_step_error: float = 0.0) -> "FaultInjector":
        """Seeded randomized schedule over ``ticks`` engine ticks.

        Each tick independently draws an allocation failure (probability
        ``p_alloc``), a poisoned row for a uniformly chosen rid
        (``p_nan``), and a whole-tick step error (``p_step_error``).
        Identical (seed, ticks, rids, probabilities) produce identical
        schedules — the sweep's reproducibility contract.
        """
        rng = np.random.default_rng(seed)
        inj = cls()
        for t in range(ticks):
            if p_alloc and rng.random() < p_alloc:
                inj.alloc_fault_at(t)
            if p_nan and rids and rng.random() < p_nan:
                inj.nan_at(t, int(rng.choice(rids)))
            if p_step_error and rng.random() < p_step_error:
                inj.step_error_at(t)
        return inj
