"""Request/response types for the step-driven serving API.

The old ``Request`` dataclass mixed immutable inputs with engine-owned
mutable state; the step-driven core splits them:

  * :class:`SamplingParams` + :class:`GenerationRequest` — what the
    caller submits. Immutable; safe to share and resubmit.
  * :class:`RequestState` — engine-owned progress: generated tokens,
    finish reason, tick-clock metrics, preemption count. Owned by one
    ``EngineCore``; callers read it, never mutate it.
  * :class:`RequestOutput` / :class:`StepOutput` — what one engine tick
    surfaces: the per-request token *delta* produced by that tick, so a
    caller can stream tokens as they are emitted.
  * :class:`Request` — the legacy record the batch-blocking ``run()``
    compatibility wrapper still accepts and returns (inputs and results
    in one object, as before the redesign).

Metrics are in *ticks* of the engine clock (one ``EngineCore.step()``
call each). They are ``None`` until the underlying event has happened —
a never-admitted request has no queue wait, an unfinished one no
latency — instead of the nonsense negatives the old properties returned.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class FinishReason(str, enum.Enum):
    """Why a request stopped. ``str``-valued, so comparisons against the
    legacy literals (``reason == "eos"``, ``reason in ("length", "eos")``)
    keep working and the value serializes as its plain string."""

    LENGTH = "length"               # hit max_new_tokens
    EOS = "eos"                     # sampled the request's eos_token
    ABORTED = "aborted"             # caller cancelled via abort_request
    DEADLINE = "deadline"           # exceeded SamplingParams.deadline_steps
    QUEUE_TIMEOUT = "queue_timeout"  # never admitted within queue_timeout_steps
    CAPACITY = "capacity"           # can never fit / preemption budget spent
    ERROR = "error"                 # non-finite logits or backend step failure

    def __str__(self) -> str:       # str(reason) == "eos", not the repr
        return self.value

    def to_openai(self) -> str:
        """The OpenAI wire-format ``finish_reason`` string for this
        reason. EOS maps to ``"stop"`` and both watchdog expirations map
        to ``"timeout"`` (the structured detail — which timeout, and any
        error text — rides in the response's ``finish_details``);
        everything else serializes as its own value."""
        return _OPENAI_FINISH[self]


_OPENAI_FINISH = {
    FinishReason.LENGTH: "length",
    FinishReason.EOS: "stop",
    FinishReason.ABORTED: "abort",
    FinishReason.DEADLINE: "timeout",
    FinishReason.QUEUE_TIMEOUT: "timeout",
    FinishReason.CAPACITY: "capacity",
    FinishReason.ERROR: "error",
}


# legacy aliases (pre-enum modules import these names)
FINISH_LENGTH = FinishReason.LENGTH
FINISH_EOS = FinishReason.EOS


class QueueFullError(RuntimeError):
    """Admission backpressure: the core's bounded submit queue is full.

    The caller should shed load or retry later; nothing was enqueued."""


class CapacityError(ValueError):
    """The request can never be served by this engine's pool (too many
    cache positions / pages even running alone), or it exhausted its
    preemption-retry budget. Subclasses ``ValueError`` so pre-existing
    callers catching the old untyped rejection keep working."""


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How to sample and when to stop. Immutable and shareable."""

    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    temperature: float = 0.0        # 0 -> greedy
    # robustness deadlines, in ticks of the engine clock (None = unbounded):
    # queue_timeout_steps bounds the wait for *first* admission (expired
    # requests finish QUEUE_TIMEOUT without ever running); deadline_steps
    # bounds submit-to-finish in any phase (expired requests finish
    # DEADLINE, keeping whatever tokens they produced)
    queue_timeout_steps: Optional[int] = None
    deadline_steps: Optional[int] = None


@dataclasses.dataclass(frozen=True, eq=False)
class GenerationRequest:
    """Immutable generation inputs: prompt tokens + sampling params.

    ``request_id`` may be supplied by the caller (it seeds the request's
    PRNG stream, so pinning it makes temperature>0 traces reproducible
    across runs and batch compositions); when ``None`` the core assigns
    the next monotonic id at ``add_request``.
    """

    prompt: np.ndarray              # (prompt_len,) int32
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    request_id: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


class _TickMetrics:
    """Guarded tick-clock metrics shared by RequestState and the legacy
    Request record. Each returns ``None`` until its event happened."""

    submit_step: int
    admit_step: int
    first_token_step: int
    finish_step: int

    @property
    def queue_wait_steps(self) -> Optional[int]:
        """Ticks spent queued before (last) admission; None if never
        admitted."""
        if self.submit_step < 0 or self.admit_step < 0:
            return None
        return self.admit_step - self.submit_step

    @property
    def ttft_steps(self) -> Optional[int]:
        """Ticks from submission to the first emitted token; None until a
        token has been emitted."""
        if self.submit_step < 0 or self.first_token_step < 0:
            return None
        return self.first_token_step - self.submit_step

    @property
    def latency_steps(self) -> Optional[int]:
        """Ticks from submission to completion; None while unfinished."""
        if self.submit_step < 0 or self.finish_step < 0:
            return None
        return self.finish_step - self.submit_step


@dataclasses.dataclass
class RequestState(_TickMetrics):
    """Engine-owned progress of one request.

    Created by ``EngineCore.add_request``; mutated only by the scheduler
    and core. ``rid`` is the resolved request id (explicit or assigned)
    and seeds the request's PRNG stream.
    """

    request: GenerationRequest
    rid: int = -1
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[FinishReason] = None
    error: Optional[str] = None     # diagnostic for ERROR finishes
    # tick-clock metrics (-1 = not yet)
    submit_step: int = -1
    admit_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1
    preemptions: int = 0            # times evicted to free cache pages
    # prompt tokens whose prefill was skipped because their pages were
    # found in the prefix cache (summed across admissions: a preempted
    # request that resumes through cached pages counts those hits too)
    cached_prefix_tokens: int = 0

    @property
    def sampling(self) -> SamplingParams:
        return self.request.sampling

    @property
    def prompt(self) -> np.ndarray:
        return self.request.prompt

    @property
    def prompt_len(self) -> int:
        return self.request.prompt_len

    @property
    def resume_prefill_len(self) -> int:
        """Tokens a (re-)admission must prefill: the prompt plus every
        generated token except the last, which is fed at the next decode
        step (fresh requests: just the prompt)."""
        return self.prompt_len + max(len(self.out_tokens) - 1, 0)

    def prefill_token_seq(self) -> np.ndarray:
        """The token sequence a (re-)admission prefills — and the content
        the prefix cache matches and registers pages against. Length
        equals :attr:`resume_prefill_len`."""
        if self.out_tokens:
            return np.concatenate([np.asarray(self.prompt, np.int32),
                                   np.asarray(self.out_tokens[:-1],
                                              np.int32)])
        return np.asarray(self.prompt, np.int32)


@dataclasses.dataclass
class RequestOutput:
    """One request's progress surfaced by one engine tick.

    ``new_tokens`` is the *delta* — only the tokens this tick emitted
    (normally one; the tick that finishes chunked prefill emits the
    prefill-sampled token). Concatenating every tick's ``new_tokens``
    reproduces the request's full ``out_tokens``.
    """

    request_id: int
    new_tokens: List[int]
    num_generated: int              # cumulative tokens so far
    finished: bool = False
    finish_reason: Optional[FinishReason] = None
    error: Optional[str] = None     # diagnostic for ERROR finishes


@dataclasses.dataclass
class StepOutput:
    """Everything one ``EngineCore.step()`` tick produced."""

    step: int                       # tick index that produced these
    outputs: List[RequestOutput]    # one entry per request that emitted

    def __bool__(self) -> bool:
        return bool(self.outputs)


@dataclasses.dataclass
class Request(_TickMetrics):
    """Legacy batch-API record: inputs and results in one object.

    Accepted and returned by the engines' ``run()`` compatibility
    wrapper, which converts it to a :class:`GenerationRequest` on the way
    in and copies the :class:`RequestState` results back on the way out.
    New code should submit :class:`GenerationRequest` to an
    ``EngineCore`` (or ``stream()``) instead.
    """

    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_token: Optional[int] = None
    temperature: float = 0.0        # 0 -> greedy
    queue_timeout_steps: Optional[int] = None
    deadline_steps: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[FinishReason] = None
    error: Optional[str] = None     # diagnostic for ERROR finishes
    # per-request metrics, in ticks of the engine clock (-1 = not yet;
    # the guarded _TickMetrics properties return None until then)
    submit_step: int = -1
    admit_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1
    preemptions: int = 0            # times evicted to free cache pages
    cached_prefix_tokens: int = 0   # prefill tokens served from the
    # prefix cache instead of being recomputed

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    def to_generation_request(self,
                              request_id: Optional[int] = None
                              ) -> GenerationRequest:
        return GenerationRequest(
            prompt=self.prompt,
            sampling=SamplingParams(
                max_new_tokens=self.max_new_tokens,
                eos_token=self.eos_token,
                temperature=self.temperature,
                queue_timeout_steps=self.queue_timeout_steps,
                deadline_steps=self.deadline_steps),
            request_id=request_id)

    def absorb(self, state: RequestState) -> None:
        """Copy a finished (or in-flight) state's results back in."""
        self.out_tokens = list(state.out_tokens)
        self.done = state.done
        self.finish_reason = state.finish_reason
        self.error = state.error
        self.submit_step = state.submit_step
        self.admit_step = state.admit_step
        self.first_token_step = state.first_token_step
        self.finish_step = state.finish_step
        self.preemptions = state.preemptions
        self.cached_prefix_tokens = state.cached_prefix_tokens
