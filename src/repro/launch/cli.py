"""Shared CLI plumbing for the serving launchers.

``repro.launch.serve`` (batch/stream workload runner) and
``repro.launch.server`` (the OpenAI-compatible HTTP front end) expose
the same model/engine/robustness surface; this module is the single
definition of those flags and of the argparse-namespace -> engine
construction, so the two launchers cannot drift apart flag-by-flag.

``calibrate_and_quantize`` lives here too (it is the shared offline
phase); ``repro.launch.serve`` re-exports it for existing importers.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.data import make_calibration_set
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import (PagedServingEngine, ServingEngine,
                           StaticBatchEngine)


def calibrate_and_quantize(params, cfg, method: str = "arc",
                           fmt: str = "nvfp4", n_calib: int = 8,
                           seq: int = 128, corpus: str = "wikitext2"):
    """Offline phase: calibration pass -> plans -> quantized weights."""
    quant = QuantConfig(method=method, fmt=fmt)
    calib = make_calibration_set(cfg.vocab_size, n_calib, seq, corpus=corpus)
    stats = None
    import jax.numpy as jnp
    for toks in calib.batches:
        s = capture_stats(params, cfg, tokens=jnp.asarray(toks))
        if stats is None:
            stats = {k: np.array(v) for k, v in s.items()}
        else:
            for k, v in s.items():
                np.maximum(stats[k], np.asarray(v), out=stats[k])
    plans = make_plan_bundle(stats, cfg, quant, params)
    if method in ("arc", "rtn"):
        qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                               pack=(fmt in ("nvfp4", "mxfp4")))
    else:
        qparams = params
    return qparams, quant, plans


# -- shared flag groups ------------------------------------------------------


def add_model_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="arc",
                    choices=["arc", "rtn", "smooth", "quarot", "none"])
    ap.add_argument("--fmt", default="nvfp4")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="deployed-linear kernel backend (pallas = fused "
                         "quant + packed NVFP4 GEMM)")
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (CPU)")


def add_engine_args(ap: argparse.ArgumentParser,
                    allow_static: bool = True) -> None:
    ap.add_argument("--batch", type=int, default=4,
                    help="cache slots (continuous) / batch size (static)")
    if allow_static:
        ap.add_argument("--static", action="store_true",
                        help="gang-scheduled fixed-batch baseline engine")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache pool (block tables, on-demand "
                         "page allocation, preemption when pages run dry)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size for --paged (default: slot "
                         "parity; smaller shares memory and may preempt)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="positions per KV page for --paged")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed paged pool (implies --paged): "
                         "requests sharing a prompt prefix reuse its pages "
                         "ref-counted; copy-on-write on shared-tail writes")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: feed prompts longer than N in "
                         "N-token slices across ticks (0 = one-shot)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="shared per-tick prefill token budget across all "
                         "admissions (vLLM-style max_num_batched_tokens; "
                         "0 = unbudgeted)")


def add_robustness_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="per-request deadline in engine ticks: requests "
                         "alive past it finish with reason 'deadline' "
                         "(0 = none)")
    ap.add_argument("--queue-timeout-steps", type=int, default=0,
                    help="max ticks a request may wait for first admission "
                         "before finishing with 'queue_timeout' (0 = none)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue: submissions beyond it "
                         "are rejected with QueueFullError (0 = unbounded; "
                         "the HTTP server maps rejections to 429)")
    ap.add_argument("--no-nan-guard", action="store_true",
                    help="disable the per-row non-finite-logit guard "
                         "(the isolation A/B baseline)")


# -- namespace -> objects ----------------------------------------------------


def build_model(args):
    """Resolve the config and run the offline phase; returns
    ``(cfg, qparams, quant, plans)`` and prints the phase timing."""
    import jax
    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    t0 = time.time()
    qparams, quant, plans = calibrate_and_quantize(params, cfg, args.method,
                                                   fmt=args.fmt)
    print(f"calibration+quantization: {time.time() - t0:.1f}s "
          f"(paper Table 4 analogue); method={args.method} fmt={args.fmt}")
    return cfg, qparams, quant, plans


def build_engine(args, qparams, cfg, quant, plans, max_len: int):
    """Construct the engine the flags describe. Raises ``ValueError`` on
    contradictory selections (callers surface it via ``ap.error``)."""
    if args.prefix_cache:
        args.paged = True
    static = getattr(args, "static", False)
    if static and args.paged:
        raise ValueError("--static and --paged are mutually exclusive")
    kw = {}
    if args.paged:
        cls = PagedServingEngine
        kw = {"num_pages": args.num_pages, "block_size": args.block_size,
              "prefix_cache": args.prefix_cache}
    else:
        cls = StaticBatchEngine if static else ServingEngine
    return cls(qparams, cfg, quant, plans, batch_size=args.batch,
               max_len=max_len, seed=args.seed,
               backend=args.backend, interpret=args.interpret,
               prefill_chunk=args.prefill_chunk or None,
               prefill_budget=args.prefill_budget or None,
               nan_guard=not args.no_nan_guard,
               max_queue=args.max_queue or None, **kw)


def engine_mode(args) -> str:
    return ("paged" if args.paged
            else "static" if getattr(args, "static", False) else "continuous")
