"""OpenAI-compatible HTTP server launcher.

    PYTHONPATH=src python -m repro.launch.server --smoke --method arc \
        --paged --prefix-cache --port 8000

Calibrates and quantizes the model (same offline phase as
``repro.launch.serve``), builds the serving engine the shared flags
describe, and serves it over the asyncio front end:

    curl http://127.0.0.1:8000/v1/chat/completions -d '{
        "messages": [{"role": "user", "content": "hello"}],
        "max_tokens": 16, "stream": true}'

Endpoints: ``/v1/completions``, ``/v1/chat/completions`` (JSON or SSE),
``/v1/models``, ``/health``, ``/metrics`` (Prometheus text). The
robustness flags become the serving policy: ``--max-queue`` turns into
HTTP 429 backpressure, ``--deadline-steps``/``--queue-timeout-steps``
into default per-request watchdogs (clients may override per request).
"""
from __future__ import annotations

import argparse
import asyncio
import logging
import signal

from repro.launch.cli import (add_engine_args, add_model_args,
                              add_robustness_args, build_engine, build_model,
                              engine_mode)
from repro.server import ServerApp, ServerDefaults


def run_server(engine, host: str = "127.0.0.1", port: int = 8000,
               model_id: str = "repro",
               defaults: ServerDefaults = None) -> None:
    """Serve one engine until SIGINT/SIGTERM (blocking)."""
    core = engine.make_core()
    app = ServerApp(core, model_id=model_id, defaults=defaults)

    async def _main():
        await app.start(host, port)
        print(f"listening on http://{host}:{app.port}  "
              f"(Ctrl-C to stop)")
        # graceful: signals set an event instead of raising mid-handler,
        # so in-flight connections unwind through app.stop()'s abort path
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, stop.set)
            except NotImplementedError:     # non-Unix event loops
                pass
        await stop.wait()
        print("shutting down (in-flight requests aborted)")
        await app.stop()

    try:
        asyncio.run(_main())
    except KeyboardInterrupt:               # signal handler unavailable
        print("shutting down (in-flight requests aborted)")


def main():
    ap = argparse.ArgumentParser()
    add_model_args(ap)
    # no --static: a gang-scheduled fixed batch cannot admit mid-flight,
    # which is the whole point of an online server
    add_engine_args(ap, allow_static=False)
    add_robustness_args(ap)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="0 picks an ephemeral port")
    ap.add_argument("--max-len", type=int, default=128,
                    help="cache positions per request (prompt + generation)")
    ap.add_argument("--default-max-tokens", type=int, default=64,
                    help="max_tokens applied when a request omits it")
    ap.add_argument("--model-id", default=None,
                    help="model id reported by /v1/models (default: --arch)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="per-request INFO logging")
    args = ap.parse_args()

    logging.basicConfig(
        level=logging.INFO if args.verbose else logging.WARNING,
        format="%(asctime)s %(name)s %(levelname)s %(message)s")

    cfg, qparams, quant, plans = build_model(args)
    try:
        engine = build_engine(args, qparams, cfg, quant, plans,
                              max_len=args.max_len)
    except ValueError as e:
        ap.error(str(e))
    defaults = ServerDefaults(
        max_new_tokens=args.default_max_tokens,
        deadline_steps=args.deadline_steps or None,
        queue_timeout_steps=args.queue_timeout_steps or None)
    print(f"{engine_mode(args)} engine, batch={args.batch}, "
          f"max_len={args.max_len}, backend={args.backend}")
    run_server(engine, host=args.host, port=args.port,
               model_id=args.model_id or args.arch, defaults=defaults)


if __name__ == "__main__":
    main()
