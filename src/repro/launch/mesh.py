"""Production mesh definitions (single pod 16x16, multi-pod 2x16x16).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh over whatever devices exist (CPU smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per-chip effective)
HBM_PER_CHIP = 16 * 1024 ** 3     # v5e: 16 GiB
