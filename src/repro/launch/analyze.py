"""Compiled-artifact lint CLI: run the ``repro.analysis`` rule suite
over the jitted serving entry points and print a per-entry report.

    # one engine, explicit knobs
    PYTHONPATH=src python -m repro.launch.analyze --arch qwen2-1.5b \
        --smoke --paged --backend pallas --interpret

    # the CI gate: slot/paged x dense/MoE on tiny proxies
    PYTHONPATH=src python -m repro.launch.analyze --matrix --fail-on error

Per entry point the report carries the rule findings (R1-R4, R6, R7 —
R5 is dynamic; see ``tests/test_retrace_guard.py``), the VMEM launch
table, and ``launch.hlo_analysis.analyze_hlo`` flops/bytes for cost
context. Exit status is nonzero when any finding at or above
``--fail-on`` severity survives.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, TextIO

import jax

from repro.analysis import DEFAULT_VMEM_LIMIT, Finding, lint_engine
from repro.analysis.rules import _SEV_ORDER
from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import PagedServingEngine, ServingEngine

# the CI matrix: one dense and one MoE proxy, slot and paged pools
MATRIX_ARCHS = ("qwen2-1.5b", "qwen3-moe-235b-a22b")


def build_engine(arch: str, paged: bool, backend: str = "pallas",
                 method: str = "arc", smoke: bool = True,
                 batch_size: int = 4, max_len: int = 64,
                 interpret: bool = True, prefill_chunk: Optional[int] = None):
    """A small serving engine over freshly calibrated ARC weights (the
    test-suite idiom: one capture batch stands in for calibration)."""
    cfg = ARCHS[arch]
    if smoke:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    quant = QuantConfig(method=method)
    plans = None
    qparams = params
    if method in ("arc", "rtn"):
        toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
        stats = capture_stats(params, cfg, tokens=toks)
        plans = make_plan_bundle(stats, cfg, quant, params)
        qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                               pack=True)
    cls = PagedServingEngine if paged else ServingEngine
    return cls(qparams, cfg, quant, plans, batch_size=batch_size,
               max_len=max_len, backend=backend, interpret=interpret,
               prefill_chunk=prefill_chunk)


def report_engine(engine, label: str,
                  vmem_limit: int = DEFAULT_VMEM_LIMIT,
                  out: TextIO = sys.stdout) -> List[Finding]:
    """Lint one engine and print its per-entry report; returns the
    findings (all severities)."""
    artifacts, findings = lint_engine(engine, vmem_limit=vmem_limit)
    print(f"== {label} ==", file=out)
    for entry, art in artifacts.items():
        acc = analyze_hlo(art.compiled_text)
        print(f"-- {entry}: {acc['flops'] / 1e6:.1f} MFLOP, "
              f"{acc['bytes'] / 1e6:.1f} MB accessed, "
              f"{len(art.hlo.input_output_alias)} aliased outputs",
              file=out)
        for rep in art.meta.get("vmem_reports", []):
            mark = (" OVER BUDGET" if rep["vmem_bytes"] > vmem_limit
                    else "")
            print(f"   vmem {rep['kernel']:<24s} x{rep['count']:<3d} "
                  f"grid={rep['grid']} blocks={rep['blocks']} "
                  f"{rep['vmem_bytes'] / 2**20:.2f} MiB{mark}", file=out)
    shown = [f for f in findings if f.severity != "info"] or findings
    for f in shown:
        print(f"   {f}", file=out)
    if not findings:
        print("   (no findings)", file=out)
    return findings


def _matrix_cells(backend: str):
    for arch in MATRIX_ARCHS:
        for paged in (False, True):
            yield arch, paged, backend


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (default; full-size lowering of "
                         "real archs is dryrun territory)")
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--backend", default="pallas",
                    choices=["reference", "pallas"])
    ap.add_argument("--method", default="arc",
                    choices=["arc", "rtn", "none"])
    ap.add_argument("--interpret", action="store_true", default=True)
    ap.add_argument("--prefill-chunk", type=int, default=4,
                    help="also exercise the chunked-prefill entry width")
    ap.add_argument("--vmem-limit-mib", type=float,
                    default=DEFAULT_VMEM_LIMIT / 2**20,
                    help="R6 per-kernel VMEM budget in MiB")
    ap.add_argument("--matrix", action="store_true",
                    help="the CI gate: slot/paged x dense/MoE proxies")
    ap.add_argument("--fail-on", default="error",
                    choices=["error", "warning", "never"],
                    help="exit nonzero when findings at/above this "
                         "severity survive")
    args = ap.parse_args(argv)
    limit = int(args.vmem_limit_mib * 2**20)

    cells = (_matrix_cells(args.backend) if args.matrix
             else [(args.arch, args.paged, args.backend)])
    all_findings: List[Finding] = []
    for arch, paged, backend in cells:
        label = f"{arch} {'paged' if paged else 'slot'} {backend}"
        try:
            engine = build_engine(arch, paged, backend=backend,
                                  method=args.method, smoke=args.smoke,
                                  interpret=args.interpret,
                                  prefill_chunk=args.prefill_chunk or None)
            all_findings += report_engine(engine, label, vmem_limit=limit)
        except Exception as e:      # noqa: BLE001 — report, don't crash the matrix
            if backend == "pallas" and not args.matrix:
                raise
            if backend == "pallas":
                # safety net: MoE routes through the fused pallas pipeline
                # (dropless dispatch + swiglu epilogue) and lints natively,
                # but if a cell's lowering ever breaks, lint it on the
                # reference backend (R2/R3/R4/R7 still bind) instead of
                # silently dropping the whole matrix
                print(f"== {label} == lowering failed "
                      f"({type(e).__name__}: {e}); retrying on the "
                      f"reference backend")
                engine = build_engine(arch, paged, backend="reference",
                                      method=args.method, smoke=args.smoke,
                                      interpret=args.interpret,
                                      prefill_chunk=args.prefill_chunk
                                      or None)
                all_findings += report_engine(
                    engine, label.replace("pallas", "reference(fallback)"),
                    vmem_limit=limit)
            else:
                raise

    errors = [f for f in all_findings if f.severity == "error"]
    warnings = [f for f in all_findings if f.severity == "warning"]
    print(f"\n{len(errors)} error(s), {len(warnings)} warning(s), "
          f"{len(all_findings) - len(errors) - len(warnings)} info")
    if args.fail_on == "never":
        return 0
    bar = _SEV_ORDER[args.fail_on]
    return 1 if any(_SEV_ORDER[f.severity] <= bar for f in all_findings) \
        else 0


if __name__ == "__main__":
    sys.exit(main())
