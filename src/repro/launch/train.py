"""Training launcher: fault-tolerant loop around make_train_step.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 20 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt
    # resume after failure/preemption:
    PYTHONPATH=src python -m repro.launch.train ... --resume

On real hardware the same entrypoint runs per-host under
``jax.distributed.initialize()`` with the production mesh; here the mesh
is whatever devices exist (CPU smoke) unless --mesh pod is forced.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS
from repro.data import SyntheticLM
from repro.distributed import FaultTolerantRunner, RunnerConfig
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import adamw_init
from repro.parallel import use_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key, jnp.float32)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, base_lr=args.lr, warmup=10,
                                      total=args.steps, remat=False),
                      donate_argnums=(0, 1))

    data = SyntheticLM(cfg.vocab_size, args.seed)
    stream = data.train_stream()
    it = stream.batches(args.batch, args.seq)

    manager = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
    runner = FaultTolerantRunner(manager, RunnerConfig(
        max_steps=args.steps, checkpoint_interval=args.ckpt_interval))
    runner.install_signal_handler()

    def batch_fn(stream):
        toks = next(it)
        B, S = toks.shape
        pos = np.broadcast_to(np.arange(S), (B, S)).astype(np.int32)
        return {"tokens": jnp.asarray(toks), "positions": jnp.asarray(pos)}

    def wrapped_step(params, opt_state, batch):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        return params, opt_state, metrics

    t0 = time.time()
    result = runner.run(wrapped_step, params, opt_state, stream, batch_fn)
    dt = time.time() - t0
    losses = result["losses"]
    print(f"done: {result['final_step']} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"events={[e['kind'] for e in result['events']]}")


if __name__ == "__main__":
    main()
