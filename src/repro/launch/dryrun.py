import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and extract roofline inputs from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k --mesh pod --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod

Per cell this produces JSON with:
    memory_analysis   bytes per device (argument/output/temp/generated)
    cost_analysis     HLO FLOPs and bytes accessed
    collectives       per-op wire-byte totals parsed from post-SPMD HLO
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

# canonical home moved to the analysis package (rule R7 shares it);
# re-exported here for compatibility (tests/benchmarks import it from
# this module)
from repro.analysis.collectives import parse_collectives
from repro.configs import ARCHS, SHAPES
from repro.configs.base import QuantConfig
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.parallel import (make_cache_shardings, make_param_shardings,
                            use_mesh)
from jax.sharding import NamedSharding, PartitionSpec as P


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if k in ("flops", "transcendentals", "optimal_seconds",
                     "bytes accessed")}


def batch_shardings(specs, mesh):
    """Input batch shardings: batch dim over DP axes when divisible."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in data_axes]))

    def spec_for(leaf):
        dims = [None] * len(leaf.shape)
        if leaf.shape and leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
            dims[0] = data_axes if len(data_axes) > 1 else data_axes[0]
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(spec_for, specs)


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: Path,
             quant_method: str = "arc", dump_hlo: bool = False,
             variant: str = "") -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'pod'}"
    if variant:
        tag += f"__{variant}"
    if shape_name == "long_500k" and not cfg.subquadratic:
        res = {"cell": tag, "status": "skipped",
               "reason": "full-attention arch: 500k decode needs sub-quadratic mixer"}
        _write(outdir, tag, res)
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with use_mesh(mesh):
            specs = ST.input_specs(cfg, shape)
            in_batch_shardings = batch_shardings(specs, mesh)
            if shape.kind == "train":
                params = ST.abstract_params(cfg, jnp.float32)
                opt = ST.abstract_opt_state(params)
                step = ST.make_train_step(cfg)
                pshard = make_param_shardings(params, mesh)
                from repro.optim import AdamWState
                oshard = AdamWState(
                    step=NamedSharding(mesh, P()),
                    m=make_param_shardings(opt.m, mesh),
                    v=make_param_shardings(opt.v, mesh))
                jitted = jax.jit(step, in_shardings=(pshard, oshard,
                                                     in_batch_shardings),
                                 donate_argnums=(0, 1))
                lowered = jitted.lower(params, opt, specs)
            else:
                quant = QuantConfig(method=quant_method, fmt="nvfp4")
                plans = ST.synthetic_plans(cfg)
                qparams = ST.abstract_qparams(cfg, quant, plans)
                cache_len = shape.seq_len
                cache = ST.abstract_cache(cfg, shape.global_batch, cache_len)
                pshard = make_param_shardings(qparams, mesh)
                cshard = make_cache_shardings(cache, mesh)
                if shape.kind == "prefill":
                    step = ST.make_prefill_step(cfg, quant, plans)
                else:
                    step = ST.make_serve_step(cfg, quant, plans)
                jitted = jax.jit(step, in_shardings=(pshard, cshard,
                                                     in_batch_shardings),
                                 donate_argnums=(1,))
                lowered = jitted.lower(qparams, cache, specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        from repro.launch.hlo_analysis import analyze_hlo
        hlo_acc = analyze_hlo(hlo)
        res = {
            "cell": tag, "status": "ok",
            "arch": arch, "shape": shape_name,
            "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
            "kind": shape.kind,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": _mem_dict(compiled),
            "cost": _cost_dict(compiled),
            "collectives": coll,
            # trip-count-aware totals (XLA cost_analysis counts loop bodies
            # once; this multiplies by known_trip_count up the call graph)
            "hlo_analysis": hlo_acc,
            "hlo_lines": hlo.count("\n"),
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
        }
        if dump_hlo:
            (outdir / f"{tag}.hlo").write_text(hlo)
    except Exception as e:
        res = {"cell": tag, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
    _write(outdir, tag, res)
    return res


def _write(outdir: Path, tag: str, res: dict) -> None:
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / f"{tag}.json").write_text(json.dumps(res, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", default="arc")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    outdir = Path(args.out)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    if args.all:
        from repro.configs import ASSIGNED
        archs = ASSIGNED
        shapes = list(SHAPES)
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else list(SHAPES)

    n_ok = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
                if args.variant:
                    tag += f"__{args.variant}"
                if args.skip_existing and (outdir / f"{tag}.json").exists():
                    prev = json.loads((outdir / f"{tag}.json").read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                res = run_cell(arch, shape, mp, outdir, args.quant,
                               args.dump_hlo, args.variant)
                ok = res["status"] in ("ok", "skipped")
                n_ok += ok
                n_err += (not ok)
                msg = res.get("error", "")[:120]
                print(f"[{res['status']:>7}] {tag} "
                      f"compile={res.get('compile_s', '-')}s {msg}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_err} errors")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
