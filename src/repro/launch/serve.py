"""Serving launcher: calibrate -> quantize (ARC NVFP4) -> continuous decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --method arc --requests 8 --new-tokens 12

Requests run through the continuous-batching engine (slot-based cache
pool, FIFO admission between decode steps); ``--static`` selects the
gang-scheduled fixed-batch baseline for comparison and ``--paged`` the
paged KV cache pool (block tables + on-demand page allocation;
``--num-pages`` shrinks the pool below slot parity to exercise page-gated
admission and preemption). ``--prefix-cache`` (implies ``--paged``) turns
the pool content-addressed: requests sharing a prompt prefix reuse its
pages ref-counted instead of recomputing them, with copy-on-write when a
shared tail page must be written. ``--backend pallas`` routes every deployed
linear through the fused Pallas pipeline (arc_fused_quantize -> packed
nvfp4_gemm); add ``--interpret`` to run those kernels bit-faithfully on
CPU. ``--prefill-chunk N`` feeds long prompts in N-token slices across
ticks (chunked prefill — bounds the admission stall a long prompt
imposes on in-flight decodes) and ``--stream`` prints tokens per tick as
the step-driven core emits them instead of waiting for completion.

``--http`` skips the synthetic workload and serves the engine over the
OpenAI-compatible HTTP front end instead (same as
``python -m repro.launch.server``, which exposes the full server flag
surface). Model/engine/robustness flags are shared with that launcher
via ``repro.launch.cli``.
"""
from __future__ import annotations

import argparse

import numpy as np

# re-exported: examples/serve_quantized.py (and any external caller)
# imports the offline phase from here
from repro.launch.cli import (add_engine_args, add_model_args,  # noqa: F401
                              add_robustness_args, build_engine, build_model,
                              calibrate_and_quantize, engine_mode)
from repro.serving import QueueFullError, Request


def main():
    ap = argparse.ArgumentParser()
    add_model_args(ap)
    add_engine_args(ap)
    add_robustness_args(ap)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples per request")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="vary prompt/generation lengths across requests")
    ap.add_argument("--stream", action="store_true",
                    help="print per-request token deltas as each tick "
                         "emits them (the streaming API)")
    ap.add_argument("--analyze", action="store_true",
                    help="after the engine is built, lint its compiled "
                         "entry points with the repro.analysis rule suite "
                         "and print the per-entry report before serving")
    ap.add_argument("--http", action="store_true",
                    help="serve over the OpenAI-compatible HTTP front end "
                         "instead of running the synthetic workload")
    ap.add_argument("--host", default="127.0.0.1", help="bind host (--http)")
    ap.add_argument("--port", type=int, default=8000, help="bind port (--http)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache positions per request with --http "
                         "(default 128; workload runs derive it)")
    args = ap.parse_args()
    if args.new_tokens < 1:
        ap.error("--new-tokens must be >= 1 (prefill samples the first token)")

    cfg, qparams, quant, plans = build_model(args)

    if args.prefix_cache:
        args.paged = True
    rng = np.random.default_rng(args.seed)
    # with --prefix-cache the workload models real shared-prefix traffic:
    # every prompt starts with one system prompt whose pages are shared
    sys_prompt = (rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
                  if args.prefix_cache else np.zeros((0,), np.int32))
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.integers(4, 17)) if args.mixed_lengths else 16
        new = (int(rng.integers(min(2, args.new_tokens), args.new_tokens + 1))
               if args.mixed_lengths else args.new_tokens)
        prompt = np.concatenate([
            sys_prompt,
            rng.integers(0, cfg.vocab_size, plen).astype(np.int32)])
        reqs.append(Request(prompt=prompt, max_new_tokens=new,
                            temperature=args.temperature,
                            deadline_steps=args.deadline_steps or None,
                            queue_timeout_steps=(args.queue_timeout_steps
                                                 or None)))
    max_len = (args.max_len or 128) if args.http \
        else len(sys_prompt) + 16 + args.new_tokens + 1
    try:
        engine = build_engine(args, qparams, cfg, quant, plans,
                              max_len=max_len)
    except ValueError as e:
        ap.error(str(e))
    if args.analyze:
        from repro.launch.analyze import report_engine
        report_engine(engine, f"{args.arch} ({'paged' if args.paged else 'slot'}"
                              f" pool, backend={args.backend})")
    if args.http:
        from repro.launch.server import run_server
        from repro.server import ServerDefaults
        run_server(engine, host=args.host, port=args.port,
                   model_id=args.arch,
                   defaults=ServerDefaults(
                       max_new_tokens=args.new_tokens,
                       deadline_steps=args.deadline_steps or None,
                       queue_timeout_steps=args.queue_timeout_steps or None))
        return
    try:
        if args.stream:
            for out in engine.stream(reqs):
                tag = (f" [{out.finish_reason}]" if out.finished else "")
                print(f"  req{out.request_id}: +{out.new_tokens} "
                      f"({out.num_generated} total){tag}")
        else:
            engine.run(reqs)
    except QueueFullError as e:
        print(f"admission rejected: {e}")
    s = engine.last_stats
    print(f"backend={args.backend}"
          f"{' (interpret)' if args.interpret else ''}")
    print(f"{engine_mode(args)} engine: "
          f"served {len(reqs)} requests, {s.generated_tokens} tokens in "
          f"{s.wall_seconds:.1f}s ({s.summary()['wall_tokens_per_s']:.1f} "
          f"tok/s on CPU emulation)")
    print(f"decode steps: {s.decode_steps}  padding waste: "
          f"{100 * s.padding_waste:.1f}%  tokens/step: "
          f"{s.tokens_per_step:.2f}")
    if args.paged:
        print(f"page pool: {s.num_pages} pages, peak {s.peak_pages}, "
              f"mean utilization {100 * s.page_utilization:.1f}%, "
              f"{s.preemptions} preemptions")
    if args.prefix_cache:
        print(f"prefix cache: {s.cached_prefix_tokens} prefill tokens "
              f"served from shared pages ({s.prefill_tokens} computed)")
    if (s.aborted or s.expired or s.rejected or s.nan_isolated
            or s.step_failures):
        print(f"robustness: {s.aborted} aborted, {s.expired} expired "
              f"(deadline/timeout/budget), {s.rejected} rejected, "
              f"{s.nan_isolated} NaN-isolated, {s.step_failures} failed "
              f"steps")
    lat = [r.latency_steps for r in reqs if r.latency_steps is not None]
    if lat:
        print(f"latency (decode-step ticks): p50={int(np.median(lat))} "
              f"max={max(lat)}")
    print("sample output:", reqs[0].out_tokens[:8])


if __name__ == "__main__":
    main()
