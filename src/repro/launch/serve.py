"""Serving launcher: calibrate -> quantize (ARC NVFP4) -> continuous decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --method arc --requests 8 --new-tokens 12

Requests run through the continuous-batching engine (slot-based cache
pool, FIFO admission between decode steps); ``--static`` selects the
gang-scheduled fixed-batch baseline for comparison and ``--paged`` the
paged KV cache pool (block tables + on-demand page allocation;
``--num-pages`` shrinks the pool below slot parity to exercise page-gated
admission and preemption). ``--prefix-cache`` (implies ``--paged``) turns
the pool content-addressed: requests sharing a prompt prefix reuse its
pages ref-counted instead of recomputing them, with copy-on-write when a
shared tail page must be written. ``--backend pallas`` routes every deployed
linear through the fused Pallas pipeline (arc_fused_quantize -> packed
nvfp4_gemm); add ``--interpret`` to run those kernels bit-faithfully on
CPU. ``--prefill-chunk N`` feeds long prompts in N-token slices across
ticks (chunked prefill — bounds the admission stall a long prompt
imposes on in-flight decodes) and ``--stream`` prints tokens per tick as
the step-driven core emits them instead of waiting for completion.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.data import SyntheticLM, make_calibration_set
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import (PagedServingEngine, QueueFullError, Request,
                           ServingEngine, StaticBatchEngine)


def calibrate_and_quantize(params, cfg, method: str = "arc",
                           fmt: str = "nvfp4", n_calib: int = 8,
                           seq: int = 128, corpus: str = "wikitext2"):
    """Offline phase: calibration pass -> plans -> quantized weights."""
    quant = QuantConfig(method=method, fmt=fmt)
    calib = make_calibration_set(cfg.vocab_size, n_calib, seq, corpus=corpus)
    stats = None
    import jax.numpy as jnp
    for toks in calib.batches:
        s = capture_stats(params, cfg, tokens=jnp.asarray(toks))
        if stats is None:
            stats = {k: np.array(v) for k, v in s.items()}
        else:
            for k, v in s.items():
                np.maximum(stats[k], np.asarray(v), out=stats[k])
    plans = make_plan_bundle(stats, cfg, quant, params)
    if method in ("arc", "rtn"):
        qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                               pack=(fmt in ("nvfp4", "mxfp4")))
    else:
        qparams = params
    return qparams, quant, plans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="arc",
                    choices=["arc", "rtn", "smooth", "quarot", "none"])
    ap.add_argument("--fmt", default="nvfp4")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4,
                    help="cache slots (continuous) / batch size (static)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static", action="store_true",
                    help="gang-scheduled fixed-batch baseline engine")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache pool (block tables, on-demand "
                         "page allocation, preemption when pages run dry)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size for --paged (default: slot "
                         "parity; smaller shares memory and may preempt)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="positions per KV page for --paged")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed paged pool (implies --paged): "
                         "requests sharing a prompt prefix reuse its pages "
                         "ref-counted; copy-on-write on shared-tail writes")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="deployed-linear kernel backend (pallas = fused "
                         "quant + packed NVFP4 GEMM)")
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (CPU)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples per request")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="vary prompt/generation lengths across requests")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: feed prompts longer than N in "
                         "N-token slices across ticks (0 = one-shot)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="shared per-tick prefill token budget across all "
                         "admissions (vLLM-style max_num_batched_tokens; "
                         "0 = unbudgeted)")
    ap.add_argument("--stream", action="store_true",
                    help="print per-request token deltas as each tick "
                         "emits them (the streaming API)")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="per-request deadline in engine ticks: requests "
                         "alive past it finish with reason 'deadline' "
                         "(0 = none)")
    ap.add_argument("--queue-timeout-steps", type=int, default=0,
                    help="max ticks a request may wait for first admission "
                         "before finishing with 'queue_timeout' (0 = none)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue: submissions beyond it "
                         "are rejected with QueueFullError (0 = unbounded)")
    ap.add_argument("--no-nan-guard", action="store_true",
                    help="disable the per-row non-finite-logit guard "
                         "(the isolation A/B baseline)")
    ap.add_argument("--analyze", action="store_true",
                    help="after the engine is built, lint its compiled "
                         "entry points with the repro.analysis rule suite "
                         "and print the per-entry report before serving")
    args = ap.parse_args()
    if args.new_tokens < 1:
        ap.error("--new-tokens must be >= 1 (prefill samples the first token)")

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)

    t0 = time.time()
    qparams, quant, plans = calibrate_and_quantize(params, cfg, args.method,
                                                   fmt=args.fmt)
    t_quant = time.time() - t0
    print(f"calibration+quantization: {t_quant:.1f}s "
          f"(paper Table 4 analogue); method={args.method} fmt={args.fmt}")

    if args.prefix_cache:
        args.paged = True
    rng = np.random.default_rng(args.seed)
    # with --prefix-cache the workload models real shared-prefix traffic:
    # every prompt starts with one system prompt whose pages are shared
    sys_prompt = (rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
                  if args.prefix_cache else np.zeros((0,), np.int32))
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.integers(4, 17)) if args.mixed_lengths else 16
        new = (int(rng.integers(min(2, args.new_tokens), args.new_tokens + 1))
               if args.mixed_lengths else args.new_tokens)
        prompt = np.concatenate([
            sys_prompt,
            rng.integers(0, cfg.vocab_size, plen).astype(np.int32)])
        reqs.append(Request(prompt=prompt, max_new_tokens=new,
                            temperature=args.temperature,
                            deadline_steps=args.deadline_steps or None,
                            queue_timeout_steps=(args.queue_timeout_steps
                                                 or None)))
    if args.static and args.paged:
        ap.error("--static and --paged are mutually exclusive")
    kw = {}
    if args.paged:
        cls = PagedServingEngine
        kw = {"num_pages": args.num_pages, "block_size": args.block_size,
              "prefix_cache": args.prefix_cache}
    else:
        cls = StaticBatchEngine if args.static else ServingEngine
    engine = cls(qparams, cfg, quant, plans, batch_size=args.batch,
                 max_len=len(sys_prompt) + 16 + args.new_tokens + 1,
                 seed=args.seed,
                 backend=args.backend, interpret=args.interpret,
                 prefill_chunk=args.prefill_chunk or None,
                 prefill_budget=args.prefill_budget or None,
                 nan_guard=not args.no_nan_guard,
                 max_queue=args.max_queue or None, **kw)
    if args.analyze:
        from repro.launch.analyze import report_engine
        report_engine(engine, f"{args.arch} ({'paged' if args.paged else 'slot'}"
                              f" pool, backend={args.backend})")
    try:
        if args.stream:
            for out in engine.stream(reqs):
                tag = (f" [{out.finish_reason}]" if out.finished else "")
                print(f"  req{out.request_id}: +{out.new_tokens} "
                      f"({out.num_generated} total){tag}")
        else:
            engine.run(reqs)
    except QueueFullError as e:
        print(f"admission rejected: {e}")
    s = engine.last_stats
    print(f"backend={args.backend}"
          f"{' (interpret)' if args.interpret else ''}")
    mode = ("paged" if args.paged
            else "static" if args.static else "continuous")
    print(f"{mode} engine: "
          f"served {len(reqs)} requests, {s.generated_tokens} tokens in "
          f"{s.wall_seconds:.1f}s ({s.summary()['wall_tokens_per_s']:.1f} "
          f"tok/s on CPU emulation)")
    print(f"decode steps: {s.decode_steps}  padding waste: "
          f"{100 * s.padding_waste:.1f}%  tokens/step: "
          f"{s.tokens_per_step:.2f}")
    if args.paged:
        print(f"page pool: {s.num_pages} pages, peak {s.peak_pages}, "
              f"mean utilization {100 * s.page_utilization:.1f}%, "
              f"{s.preemptions} preemptions")
    if args.prefix_cache:
        print(f"prefix cache: {s.cached_prefix_tokens} prefill tokens "
              f"served from shared pages ({s.prefill_tokens} computed)")
    if (s.aborted or s.expired or s.rejected or s.nan_isolated
            or s.step_failures):
        print(f"robustness: {s.aborted} aborted, {s.expired} expired "
              f"(deadline/timeout/budget), {s.rejected} rejected, "
              f"{s.nan_isolated} NaN-isolated, {s.step_failures} failed "
              f"steps")
    lat = [r.latency_steps for r in reqs if r.latency_steps is not None]
    if lat:
        print(f"latency (decode-step ticks): p50={int(np.median(lat))} "
              f"max={max(lat)}")
    print("sample output:", reqs[0].out_tokens[:8])


if __name__ == "__main__":
    main()
