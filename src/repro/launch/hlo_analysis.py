"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scanned-layer models by ~num_periods (and flash-attention
inner loops by another factor). This analyzer parses the post-SPMD HLO
text, builds the computation call graph with ``known_trip_count`` from
backend_config, and accumulates:

    * dot FLOPs          (2 * M * N * K from operand/result shapes)
    * convolution FLOPs  (rare here)
    * HBM bytes          (sum of operand+result bytes of fusions/dots/
                          copies at loop-body granularity — a bandwidth
                          proxy consistent with XLA's 'bytes accessed')
    * collective wire bytes per op kind (ring model, replica-group aware)

all multiplied by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    # fp8 scale codes and packed 4-bit nibbles flow through the deployed
    # NVFP4 path — dropping them understated its HBM bytes
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e8m0fnu": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_CALL_RE = re.compile(r"(?:body=|condition=|calls=|to_apply=)%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    shape = [int(d) for d in dims.split(",")] if dims else []
    return dt, shape


def _all_result_bytes(lhs_text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(lhs_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def analyze_hlo(hlo: str) -> Dict[str, float]:
    # ---- pass 1: split into computations, record defs/shapes ------------
    comps: Dict[str, list] = {}
    cur = None
    shapes: Dict[str, tuple] = {}     # %name -> (dtype, dims) of its result
    for raw in hlo.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line.strip()) if "{" in line and "->" in line else None
        if mc and not line.lstrip().startswith("%param"):
            cur = mc.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        md = _DEF_RE.match(line)
        if md:
            name, rhs = md.group(1), md.group(2)
            fs = _first_shape(rhs)
            if fs:
                shapes[name] = fs

    # ---- pass 2: call graph with trip counts -----------------------------
    # caller_multiplier[comp] = product of trip counts from ENTRY to comp
    callers: Dict[str, list] = defaultdict(list)   # comp -> [(caller, mult)]
    for cname, lines in comps.items():
        for line in lines:
            trip = 1
            mt = _TRIP_RE.search(line)
            is_while = " while(" in line
            if mt and is_while:
                trip = int(mt.group(1))
            elif is_while:
                trip = 1   # unknown trip count: conservative
            for callee in _CALL_RE.findall(line):
                mult = trip if is_while else 1
                # condition runs trip+1 times; ignore (cheap)
                callers[callee].append((cname, mult))

    mult_cache: Dict[str, float] = {}

    def multiplier(comp: str, depth=0) -> float:
        if comp in mult_cache:
            return mult_cache[comp]
        if depth > 50 or not callers.get(comp):
            mult_cache[comp] = 1.0
            return 1.0
        # a computation can be referenced by exactly one structural caller
        # in post-optimization HLO; take the max path to be safe
        best = 0.0
        for caller, mult in callers[comp]:
            if caller == comp:
                continue
            best = max(best, mult * multiplier(caller, depth + 1))
        mult_cache[comp] = best or 1.0
        return mult_cache[comp]

    # ---- pass 3: accumulate costs ----------------------------------------
    flops = 0.0
    bytes_acc = 0.0
    coll = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
            "all-to-all": 0.0, "collective-permute": 0.0, "count": 0.0}

    def op_operands(rhs: str):
        m = re.search(r"\(([^)]*)\)", rhs)
        if not m:
            return []
        return re.findall(r"%([\w.\-]+)", m.group(1))

    for cname, lines in comps.items():
        mult = multiplier(cname)
        for line in lines:
            if "= " not in line:
                continue
            lhs, rhs = line.split("= ", 1)
            # dot flops
            if re.search(r"\bdot\(", rhs):
                res = _first_shape(rhs.split("dot(")[0])
                ops = op_operands(rhs)
                if res and ops and ops[0] in shapes:
                    _, rdims = res  # result shape parsed from pre-opcode text
                    _, ldims = shapes[ops[0]]
                    out_elems = math.prod(rdims) if rdims else 1
                    # K = product of lhs contracting dims from the dims
                    # annotation -> flops = 2 * out_elems * K
                    mk = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                    if mk and mk.group(1):
                        kdims = [int(i) for i in mk.group(1).split(",")]
                        ksize = math.prod(ldims[i] for i in kdims
                                          if i < len(ldims))
                        flops += 2.0 * out_elems * ksize * mult
                bytes_acc += _all_result_bytes(rhs.split("dot(")[0]) * mult
                continue
            # collectives (result shapes precede the opcode in the rhs)
            mcoll = _COLL_RE.search(rhs)
            if mcoll and "-done" not in rhs:
                op = mcoll.group(1)
                rb = _all_result_bytes(rhs[: mcoll.start()])
                gm = _GROUPS_IOTA_RE.search(rhs)
                if gm:
                    n = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST_RE.search(rhs)
                    n = len(gl.group(1).split(",")) if gl else 2
                n = max(n, 2)
                if op == "all-reduce":
                    wire = 2.0 * (n - 1) / n * rb
                elif op == "all-gather":
                    wire = (n - 1) / n * rb
                elif op == "reduce-scatter":
                    wire = (n - 1.0) * rb
                elif op == "all-to-all":
                    wire = (n - 1) / n * rb
                else:
                    wire = rb
                coll[op] += wire * mult
                coll["count"] += mult
                continue
            # generic bandwidth proxy: bytes of results of fusions/copies
            mop = re.search(r"\b(fusion|copy|convert|dynamic-update-slice|"
                            r"dynamic-slice|broadcast|transpose|reshape)\(",
                            rhs)
            if mop:
                bytes_acc += _all_result_bytes(rhs[: mop.start()]) * mult

    return {"flops": flops, "bytes": bytes_acc, "collectives": coll}
