"""Step builders + ShapeDtypeStruct input specs for every (arch x shape).

``train_step`` lowers the training path (bf16 substrate, remat over
periods); ``prefill_step``/``serve_step`` lower the ARCQuant serving path
with offline-quantized packed-NVFP4 weights — the paper's deployment
scenario. All builders are mesh-agnostic; shardings are applied by the
caller (dryrun.py / train.py / serve.py).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig, ShapeConfig
from repro.models import lm
from repro.models.lm import PlanBundle
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.quant import quantize_weights_for_serving
from repro.quant.apply import QUANTIZABLE

# ---------------------------------------------------------------------------
# Synthetic plans (dry-run: no calibration data for full-size models)
# ---------------------------------------------------------------------------

DEFAULT_S = 256   # augmented channels per layer; paper Fig. 8: marginal at <=512


def linear_k_dims(cfg: ModelConfig) -> Dict[str, int]:
    """Reduction-dim K for every quantizable linear, by plan name."""
    d, hd = cfg.d_model, cfg.head_dim
    d_in = cfg.mamba_expand * d
    out: Dict[str, int] = {}
    for i, (mixer, ffn) in enumerate(zip(cfg.mixer_pattern, cfg.ffn_pattern)):
        ffn_kind = "rwkv_cmix" if cfg.family == "ssm" else ffn
        if mixer in ("full", "local"):
            out[f"b{i}.attn.wq"] = d
            out[f"b{i}.attn.wk"] = d
            out[f"b{i}.attn.wv"] = d
            out[f"b{i}.attn.wo"] = cfg.num_heads * hd
        elif mixer == "mamba":
            out[f"b{i}.mamba.in_proj"] = d
            out[f"b{i}.mamba.x_proj"] = d_in
            out[f"b{i}.mamba.out_proj"] = d_in
        elif mixer == "rwkv":
            for nm in ("r", "k", "v", "g", "o"):
                out[f"b{i}.rwkv.tmix_{nm}"] = d
        if ffn_kind == "moe":
            out[f"b{i}.moe.experts_gate"] = d
            out[f"b{i}.moe.experts_up"] = d
            out[f"b{i}.moe.experts_down"] = cfg.expert_ff()
        elif ffn_kind == "rwkv_cmix":
            out[f"b{i}.cmix.cmix_k"] = d
            out[f"b{i}.cmix.cmix_v"] = cfg.d_ff
            out[f"b{i}.cmix.cmix_r"] = d
        else:
            out[f"b{i}.mlp.w_gate"] = d
            out[f"b{i}.mlp.w_up"] = d
            out[f"b{i}.mlp.w_down"] = cfg.d_ff
    return out


def synthetic_plans(cfg: ModelConfig, s: int = DEFAULT_S) -> PlanBundle:
    """Identity-order plans with fixed S (structure-only, for the dry-run)."""
    arrays, meta = {}, {}
    p = cfg.num_periods
    for name, k in linear_k_dims(cfg).items():
        order = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (p, k))
        arrays[name] = {"order": order}
        meta[name] = min(s, (k // 4) // 16 * 16)
    return PlanBundle(arrays=arrays, meta=meta)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell. Training: full sequences; decode: one new
    token against a seq_len KV cache. Modality frontends are stubs: [vlm]/
    [audio] archs receive precomputed patch/frame embeddings."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        # runtime positions: supports packed sequences AND prevents XLA from
        # constant-folding causal masks into an all-block-pairs buffer
        specs["positions"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend != "text":
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend != "text":
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        specs["positions"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode: one token, cache of seq_len
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        if cfg.frontend != "text":
            specs["embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
        specs["positions"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return specs


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0), dtype))


def abstract_opt_state(params_struct):
    return jax.eval_shape(adamw_init, params_struct)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, max_len, jnp.bfloat16))


def abstract_qparams(cfg: ModelConfig, quant: QuantConfig, plans: PlanBundle,
                     pack: bool = True):
    """Struct tree of the offline-quantized serving weights."""
    pstruct = abstract_params(cfg)
    return jax.eval_shape(
        functools.partial(quantize_weights_for_serving, cfg=cfg, quant=quant,
                          plans=plans, pack=pack), pstruct)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, base_lr: float = 3e-4,
                    warmup: int = 100, total: int = 10000,
                    schedule=None, remat: bool = True):
    sched = schedule or cosine_schedule(base_lr, warmup, total)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return lm.next_token_loss(p, cfg, batch["tokens"],
                                      embeds=batch.get("embeds"),
                                      positions=batch.get("positions"),
                                      remat=remat)
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = sched(opt_state.step)
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "nll": aux["nll"],
                                   "moe_loss": aux["moe_loss"], "lr": lr}

    return train_step


def make_prefill_step(cfg: ModelConfig, quant: QuantConfig,
                      plans: PlanBundle):
    def prefill_step(qparams, cache, batch):
        logits, cache, _ = lm.forward(
            qparams, cfg, tokens=batch["tokens"], embeds=batch.get("embeds"),
            positions=batch["positions"], cache=cache, quant=quant,
            plans=plans)
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, quant: QuantConfig, plans: PlanBundle):
    """One decode step: next-token logits + greedy sample + cache update."""
    def serve_step(qparams, cache, batch):
        logits, cache, _ = lm.forward(
            qparams, cfg, tokens=batch["tokens"], embeds=batch.get("embeds"),
            positions=batch["positions"], cache=cache, quant=quant,
            plans=plans)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits[:, -1], cache

    return serve_step
