from repro.parallel.sharding import (axis_size, cache_sharding_rules,
                                     get_mesh, logical_to_spec,
                                     make_cache_shardings,
                                     make_param_shardings, maybe_shard,
                                     param_sharding_rules, set_mesh,
                                     shardable, use_mesh)

__all__ = ["axis_size", "cache_sharding_rules", "get_mesh", "logical_to_spec",
           "make_cache_shardings", "make_param_shardings", "maybe_shard",
           "param_sharding_rules", "set_mesh", "shardable", "use_mesh"]
