"""Logical-axis sharding rules (DP/TP/EP/SP) for the 2D/3D meshes.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The "pod" axis extends data parallelism across pods (gradient all-reduce
crosses the DCN once per step). Inside the model we annotate activations
with *logical* axes and map them here; a dimension is only sharded when
its size divides the mesh axis — otherwise it is replicated, which keeps
every (arch × shape) cell compileable without GSPMD padding waste.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _STATE.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


class use_mesh:
    """Context manager installing the active mesh for maybe_shard()."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)


def axis_size(mesh: Optional[Mesh], name: str) -> int:
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def _data_axes(mesh: Mesh):
    """DP axes: ("pod","data") when multi-pod, else ("data",)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shardable(dim: int, mesh: Optional[Mesh], axis) -> bool:
    if mesh is None:
        return False
    if isinstance(axis, str):
        return dim % axis_size(mesh, axis) == 0
    n = int(np.prod([axis_size(mesh, a) for a in axis]))
    return dim % n == 0


def logical_to_spec(logical: Sequence[Optional[str]], mesh: Optional[Mesh],
                    dims: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to mesh axes, dropping non-divisible shards.

    Logical names: "batch" -> (pod,)data, "model" -> model, "seq" -> None
    (sequence kept local; SP variants map it to "model"), "experts" -> model.
    """
    if mesh is None:
        return P()
    out = []
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        if name == "batch":
            axes = _data_axes(mesh)
        elif name in ("model", "experts", "vocab", "heads", "ff"):
            axes = ("model",)
        elif name == "seq_model":      # sequence parallelism over model axis
            axes = ("model",)
        else:
            out.append(None)
            continue
        ok = dims is None or shardable(dims[i], mesh, axes)
        if not ok:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def maybe_shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without one)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# path-substring -> which trailing dim is the TP ("model") dim. Column-
# parallel weights shard their OUTPUT dim; row-parallel their INPUT dim
# (weights are (out, in)). -1 = last dim, -2 = second-to-last, None = no TP.
# The leading stacked-period dim (scan) is never sharded.
_PARAM_TP_RULES = [
    ("embed", -2), ("lm_head", -2),              # (vocab, d): vocab over model
    ("wq", -2), ("wk", -2), ("wv", -2),          # column-parallel QKV
    ("wo", -1),                                  # row-parallel output proj
    ("w_gate", -2), ("w_up", -2),                # column-parallel
    ("w_down", -1),                              # row-parallel
    ("experts", -3),                             # (E, ., .): expert parallelism
    ("router", None),
    ("in_proj", -2), ("x_proj", -1), ("out_proj", -1),   # mamba
    ("tmix_", -2), ("cmix_k", -2), ("cmix_v", -1), ("cmix_r", -2),
]

FSDP_MIN_SIZE = 8 * 1024 * 1024   # leaves above this also shard over "data"


def param_sharding_rules(path: str, shape: Sequence[int],
                         mesh: Optional[Mesh]) -> P:
    """PartitionSpec for a parameter identified by its tree path.

    TP dim over "model" (divisibility-checked); for large leaves, one other
    dim is additionally sharded over the DP axes (FSDP/ZeRO-3 style — GSPMD
    inserts the per-layer all-gathers).
    """
    if mesh is None:
        return P()
    ndim = len(shape)
    spec: list = [None] * ndim
    tp_dim = None
    matched = False
    for key, rule in _PARAM_TP_RULES:
        if key in path:
            matched = True
            if rule is not None and ndim >= -rule:
                d = ndim + rule
                if shardable(shape[d], mesh, "model"):
                    spec[d] = "model"
                    tp_dim = d
            break
    if not matched:
        return P()
    size = int(np.prod(shape))
    if size >= FSDP_MIN_SIZE:
        data_axes = _data_axes(mesh)
        # shard the largest remaining dim over the DP axes
        for d in sorted(range(ndim), key=lambda i: -shape[i]):
            if d == tp_dim:
                continue
            if shardable(shape[d], mesh, data_axes):
                spec[d] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
    return P(*spec)


def make_param_shardings(params, mesh: Optional[Mesh]):
    """NamedShardings for a parameter pytree (QTensor-aware via flatten)."""
    if mesh is None:
        return None

    def path_str(path) -> str:
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)

    def spec_for(path, leaf):
        p = path_str(path)
        return NamedSharding(mesh, param_sharding_rules(p, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ---------------------------------------------------------------------------
# Cache sharding rules (serving path)
# ---------------------------------------------------------------------------


def cache_sharding_rules(path: str, shape: Sequence[int],
                         mesh: Optional[Mesh]) -> P:
    """KV / recurrent-state cache sharding.

    Leaves carry a leading period-stack dim. Batch shards over DP axes;
    heads/state dims over "model" when divisible. For single-request
    long-context (batch==1), the KV sequence dim shards over "data"
    (sequence parallelism for the cache).
    """
    if mesh is None:
        return P()
    ndim = len(shape)
    spec: list = [None] * ndim
    data_axes = _data_axes(mesh)
    leaf = path.rsplit("/", 1)[-1]
    # shapes: (P, B, ...) — dim 1 is batch
    if ndim >= 2 and shardable(shape[1], mesh, data_axes):
        spec[1] = data_axes if len(data_axes) > 1 else data_axes[0]
        batch_sharded = True
    else:
        batch_sharded = False
    if leaf in ("k", "v", "pos"):
        # (P, B, L, Hkv, D) / pos (P, B, L). Heads shard over model when
        # divisible; otherwise the cache *sequence* shards over model
        # (sequence-parallel KV: each model rank holds an L/16 slice and the
        # flash kv-chunk loop gathers one chunk at a time). Single-request
        # long-context (batch==1) additionally shards L over the DP axes.
        l_axes = []
        if not batch_sharded and ndim >= 3:
            l_axes += list(data_axes)
        # NOTE(perf log P5b): padded head sharding (36 heads over 16
        # ranks) is rejected by pjit for *input* arrays — in_shardings
        # require divisibility — so cache heads shard only when divisible
        # and the L dim shards over model otherwise.
        heads_sharded = (leaf != "pos" and ndim >= 4
                         and shardable(shape[3], mesh, "model"))
        if heads_sharded:
            spec[3] = "model"
        elif ndim >= 3:
            l_axes.append("model")
        if l_axes and ndim >= 3 and shardable(shape[2], mesh, tuple(l_axes)):
            spec[2] = tuple(l_axes) if len(l_axes) > 1 else l_axes[0]
    elif leaf == "conv":
        # (P, B, dc-1, d_in)
        if ndim >= 4 and shardable(shape[3], mesh, "model"):
            spec[3] = "model"
    elif leaf == "ssm":
        # (P, B, d_in, n)
        if ndim >= 3 and shardable(shape[2], mesh, "model"):
            spec[2] = "model"
    elif leaf == "wkv":
        # (P, B, H, hd, hd)
        if ndim >= 3 and shardable(shape[2], mesh, "model"):
            spec[2] = "model"
    return P(*spec)


def make_cache_shardings(cache, mesh: Optional[Mesh]):
    if mesh is None:
        return None

    def path_str(path) -> str:
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)

    def spec_for(path, leaf):
        return NamedSharding(mesh, cache_sharding_rules(path_str(path),
                                                        leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, cache)
