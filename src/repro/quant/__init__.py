from repro.quant.apply import (make_plan_bundle, plan_summary,
                               quantize_weights_for_serving,
                               reinterleave_legacy_qparams,
                               reinterleave_qtensor)

__all__ = ["make_plan_bundle", "plan_summary", "quantize_weights_for_serving",
           "reinterleave_legacy_qparams", "reinterleave_qtensor"]
