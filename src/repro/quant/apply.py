"""Model-level quantization pass.

Turns calibration capture stats into a scan-ready ``PlanBundle`` (per-layer
channel orders stacked over periods + static outlier counts S) and converts
the weight pytree into offline-quantized (optionally ARC-augmented)
``QTensor`` leaves for the serving path — the paper's "Offline Weight
Quantization" (§3.2).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import arc as ARC
from repro.core import quant as Q
from repro.models.lm import PlanBundle


def make_plan_bundle(stats: Dict[str, jax.Array], cfg: ModelConfig,
                     quant: QuantConfig,
                     params: Optional[Dict] = None) -> PlanBundle:
    """stats: {"b{i}.{module}.{param}": (num_periods, K) absmax}.

    Per-period channel orders are traced scan inputs; S must be static per
    layer-name, so we take the max S across periods (rounded to the block
    size) — a superset of each period's compensation set, which can only
    tighten the error. Smoothing vectors (for the SmoothQuant baseline) are
    derived when ``params`` is given.
    """
    arrays: Dict[str, Dict[str, jax.Array]] = {}
    meta: Dict[str, int] = {}
    for name, st in stats.items():
        st = np.asarray(jax.device_get(st), np.float32)   # (P, K)
        if st.ndim == 1:
            st = st[None]
        orders = []
        s_max = 0
        for row in st:
            plan = ARC.select_outliers(row, quant.fmt,
                                       max_fraction=quant.max_outlier_fraction)
            orders.append(plan.order)
            s_max = max(s_max, plan.s)
        entry = {"order": jnp.asarray(np.stack(orders))}
        if params is not None:
            w = _lookup_weight(params, name)
            if w is not None:
                w_absmax = _weight_absmax(w)
                smooth = np.power(np.maximum(st, 1e-5), 0.5) / \
                    np.power(np.maximum(w_absmax, 1e-5), 0.5)
                entry["smooth"] = jnp.asarray(
                    np.where(np.isfinite(smooth) & (smooth > 0), smooth, 1.0))
        arrays[name] = entry
        meta[name] = s_max
    return PlanBundle(arrays=arrays, meta=meta)


def _weight_absmax(w) -> np.ndarray:
    """Per-input-channel |W| max for stacked weights (P, ..., K) -> (P, K)."""
    wn = np.abs(np.asarray(jax.device_get(w), np.float32))
    # reduce all dims except first (period) and last (K)
    axes = tuple(range(1, wn.ndim - 1))
    return wn.max(axis=axes) if axes else wn


def _lookup_weight(params: Dict, plan_name: str):
    try:
        _, module, leaf = plan_name.split(".", 2)
        i = int(plan_name.split(".")[0][1:])
        return params["blocks"][i][module][leaf]
    except (KeyError, ValueError, IndexError):
        return None


# ---------------------------------------------------------------------------
# Offline weight quantization (serving path)
# ---------------------------------------------------------------------------

# module -> leaves that are quantizable linear weights (reduction on last axis)
QUANTIZABLE = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
    "moe": ("experts_gate", "experts_up", "experts_down"),
    "mamba": ("in_proj", "x_proj", "out_proj"),
    "rwkv": ("tmix_r", "tmix_k", "tmix_v", "tmix_g", "tmix_o"),
    "cmix": ("cmix_k", "cmix_v", "cmix_r"),
}


def quantize_weights_for_serving(params: Dict, cfg: ModelConfig,
                                 quant: QuantConfig,
                                 plans: Optional[PlanBundle] = None,
                                 pack: bool = False) -> Dict:
    """Replace linear weights with offline-quantized QTensors.

    * method == "rtn": plain blockwise quantization.
    * method == "arc": reorder along K per the plan, quantize, duplicate the
      quantized outlier columns (paper §3.2 "Offline Weight Quantization").
    Non-weight leaves (biases, norms, recurrence params) pass through.
    """
    new_blocks = []
    for i, block in enumerate(params["blocks"]):
        nb = dict(block)
        for module, leaves in QUANTIZABLE.items():
            if module not in block:
                continue
            sub = dict(block[module])
            for leaf in leaves:
                w = sub[leaf]                      # (P, ..., K)
                name = f"b{i}.{module}.{leaf}"
                # expert weights (P, E, f, d) are quantized per expert
                # (per-tensor FP32 scale granularity = one weight matrix),
                # matching the online simulated path exactly.
                nbatch = w.ndim - 2
                if quant.method == "arc" and plans is not None and \
                        name in plans.arrays:
                    order = plans.arrays[name]["order"]        # (P, K)
                    s = plans.meta[name]
                    fn = lambda wp, op: _augment_weight(wp, op, s, quant.fmt)
                    for ax in range(nbatch - 1):
                        fn = jax.vmap(fn, in_axes=(0, None))
                    qw = jax.vmap(fn)(w, order)
                else:
                    fn = lambda wp: Q.quantize(wp, quant.fmt)
                    for ax in range(nbatch - 1):
                        fn = jax.vmap(fn)
                    qw = jax.vmap(fn)(w)
                if pack and quant.fmt in ("nvfp4", "mxfp4"):
                    pfn = lambda t: t.to_packed()
                    for ax in range(nbatch):
                        pfn = jax.vmap(pfn)
                    qw = pfn(qw)
                sub[leaf] = qw
            nb[module] = sub
        new_blocks.append(nb)
    out = dict(params)
    out["blocks"] = new_blocks
    return out


def _augment_weight(w: jax.Array, order: jax.Array, s: int, fmt: str) -> Q.QTensor:
    wr = jnp.take(w, order, axis=-1)
    wq = Q.quantize(wr, fmt)
    if s == 0:
        return wq
    g = wq.fmt.block_size
    dup = Q.QTensor(wq.elements[..., :s], wq.scales[..., : s // g],
                    wq.fmt_name, s, wq.tensor_scale)
    return Q.concat_k(wq, dup)


def plan_summary(plans: PlanBundle) -> Dict[str, dict]:
    """Per-layer S statistics (paper Fig. 7)."""
    out = {}
    for name, s in plans.meta.items():
        k = int(plans.arrays[name]["order"].shape[-1])
        out[name] = {"S": int(s), "K": k, "overhead": s / k}
    return out
