"""Model-level quantization pass.

Turns calibration capture stats into a scan-ready ``PlanBundle`` (per-layer
channel orders stacked over periods + static outlier counts S) and converts
the weight pytree into offline-quantized (optionally ARC-augmented)
``QTensor`` leaves for the serving path — the paper's "Offline Weight
Quantization" (§3.2).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import arc as ARC
from repro.core import formats as F
from repro.core import quant as Q
from repro.models.lm import PlanBundle


def make_plan_bundle(stats: Dict[str, jax.Array], cfg: ModelConfig,
                     quant: QuantConfig,
                     params: Optional[Dict] = None) -> PlanBundle:
    """stats: {"b{i}.{module}.{param}": (num_periods, K) absmax}.

    Per-period channel orders are traced scan inputs; S must be static per
    layer-name, so we take the max S across periods (rounded to the block
    size) — a superset of each period's compensation set, which can only
    tighten the error. Smoothing vectors (for the SmoothQuant baseline) are
    derived when ``params`` is given.
    """
    arrays: Dict[str, Dict[str, jax.Array]] = {}
    meta: Dict[str, int] = {}
    for name, st in stats.items():
        st = np.asarray(jax.device_get(st), np.float32)   # (P, K)
        if st.ndim == 1:
            st = st[None]
        orders = []
        act_scales = []
        s_max = 0
        for row in st:
            plan = ARC.select_outliers(row, quant.fmt,
                                       max_fraction=quant.max_outlier_fraction)
            orders.append(plan.order)
            s_max = max(s_max, plan.s)
            # calibrated per-tensor FP32 activation scales (primary,
            # residual) for the deployed one-pass quantization path: the
            # residual of an E2M1 block is bounded by its block scale
            # ~ amax / element_max, so its tensor scale sits one
            # element_max factor below the primary's.
            amax = float(row.max())
            t1 = amax / (F.E2M1_MAX * F.E4M3_MAX) if amax > 0 else 1.0
            act_scales.append((t1, t1 / F.E2M1_MAX))
        entry = {"order": jnp.asarray(np.stack(orders)),
                 "act_scales": jnp.asarray(act_scales, jnp.float32)}
        if params is not None:
            w = _lookup_weight(params, name)
            if w is not None:
                w_absmax = _weight_absmax(w)
                smooth = np.power(np.maximum(st, 1e-5), 0.5) / \
                    np.power(np.maximum(w_absmax, 1e-5), 0.5)
                entry["smooth"] = jnp.asarray(
                    np.where(np.isfinite(smooth) & (smooth > 0), smooth, 1.0))
        arrays[name] = entry
        meta[name] = s_max
    return PlanBundle(arrays=arrays, meta=meta,
                      fused=_fused_swiglu_pairs(arrays, meta))


# gate-leaf -> up-leaf suffixes of the swiglu pairs the serving path may
# fuse into one dual-weight GEMM launch (dense MLP + per-expert MoE FFN)
_SWIGLU_PAIRS = (("mlp.w_gate", "mlp.w_up"),
                 ("moe.experts_gate", "moe.experts_up"))


def _fused_swiglu_pairs(arrays: Dict[str, Dict[str, jax.Array]],
                        meta: Dict[str, int]) -> Dict[str, str]:
    """Gate-name -> up-name pairs safe for the fused swiglu epilogue.

    A pair qualifies only when both linears ended up with an *identical*
    quantization plan — same S, same channel order, same calibrated
    activation scales — because the fused kernel quantizes the shared
    input once (with the gate's plan) and feeds both weights. Gate and up
    see the same activations, so calibration normally produces identical
    plans; any divergence (e.g. hand-edited plans) simply drops the pair
    back to separate launches.
    """
    fused: Dict[str, str] = {}
    for name in arrays:
        for gleaf, uleaf in _SWIGLU_PAIRS:
            if not name.endswith("." + gleaf):
                continue
            sib = name[: -len(gleaf)] + uleaf
            if sib not in arrays or meta.get(sib) != meta.get(name):
                continue
            if not np.array_equal(np.asarray(arrays[name]["order"]),
                                  np.asarray(arrays[sib]["order"])):
                continue
            if not np.array_equal(np.asarray(arrays[name]["act_scales"]),
                                  np.asarray(arrays[sib]["act_scales"])):
                continue
            fused[name] = sib
    return fused


def _weight_absmax(w) -> np.ndarray:
    """Per-input-channel |W| max for stacked weights (P, ..., K) -> (P, K)."""
    wn = np.abs(np.asarray(jax.device_get(w), np.float32))
    # reduce all dims except first (period) and last (K)
    axes = tuple(range(1, wn.ndim - 1))
    return wn.max(axis=axes) if axes else wn


def _lookup_weight(params: Dict, plan_name: str):
    try:
        _, module, leaf = plan_name.split(".", 2)
        i = int(plan_name.split(".")[0][1:])
        return params["blocks"][i][module][leaf]
    except (KeyError, ValueError, IndexError):
        return None


# ---------------------------------------------------------------------------
# Offline weight quantization (serving path)
# ---------------------------------------------------------------------------

# module -> leaves that are quantizable linear weights (reduction on last axis)
QUANTIZABLE = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
    "moe": ("experts_gate", "experts_up", "experts_down"),
    "mamba": ("in_proj", "x_proj", "out_proj"),
    "rwkv": ("tmix_r", "tmix_k", "tmix_v", "tmix_g", "tmix_o"),
    "cmix": ("cmix_k", "cmix_v", "cmix_r"),
}


def quantize_weights_for_serving(params: Dict, cfg: ModelConfig,
                                 quant: QuantConfig,
                                 plans: Optional[PlanBundle] = None,
                                 pack: bool = False) -> Dict:
    """Replace linear weights with offline-quantized QTensors.

    * method == "rtn": plain blockwise quantization.
    * method == "arc": reorder along K per the plan, quantize, duplicate the
      quantized outlier columns (paper §3.2 "Offline Weight Quantization"),
      stored in the canonical interleaved channel layout (Appendix D) that
      both the emulated path and the Pallas kernels consume.
    Non-weight leaves (biases, norms, recurrence params) pass through.
    With ``pack=True`` the QTensors use the deployment storage (two E2M1
    codes/byte + 8-bit scale codes) that ``nvfp4_gemm`` decodes in-kernel.
    """
    new_blocks = []
    for i, block in enumerate(params["blocks"]):
        nb = dict(block)
        for module, leaves in QUANTIZABLE.items():
            if module not in block:
                continue
            sub = dict(block[module])
            for leaf in leaves:
                w = sub[leaf]                      # (P, ..., K)
                name = f"b{i}.{module}.{leaf}"
                # expert weights (P, E, f, d) are quantized per expert
                # (per-tensor FP32 scale granularity = one weight matrix),
                # matching the online simulated path exactly.
                nbatch = w.ndim - 2
                if quant.method == "arc" and plans is not None and \
                        name in plans.arrays:
                    order = plans.arrays[name]["order"]        # (P, K)
                    s = plans.meta[name]
                    fn = lambda wp, op: _augment_weight(wp, op, s, quant.fmt)
                    for ax in range(nbatch - 1):
                        fn = jax.vmap(fn, in_axes=(0, None))
                    qw = jax.vmap(fn)(w, order)
                else:
                    fn = lambda wp: Q.quantize(wp, quant.fmt)
                    for ax in range(nbatch - 1):
                        fn = jax.vmap(fn)
                    qw = jax.vmap(fn)(w)
                if pack and quant.fmt in ("nvfp4", "mxfp4"):
                    pfn = lambda t: t.to_packed()
                    for ax in range(nbatch):
                        pfn = jax.vmap(pfn)
                    qw = pfn(qw)
                sub[leaf] = qw
            nb[module] = sub
        new_blocks.append(nb)
    out = dict(params)
    out["blocks"] = new_blocks
    return out


def _augment_weight(w: jax.Array, order: jax.Array, s: int, fmt: str) -> Q.QTensor:
    """Reorder, quantize, duplicate the S outlier columns, and emit the
    canonical interleaved layout [P0|R0|P1|R1|...] — the same permutation
    (``core.arc.interleaved_permutation``) the Pallas pipeline uses, so
    QTensor consumers and ``nvfp4_gemm`` agree on channel placement."""
    wr = jnp.take(w, order, axis=-1)
    wq = Q.quantize(wr, fmt)
    if s == 0:
        return wq
    g = wq.fmt.block_size
    dup = Q.QTensor(wq.elements[..., :s], wq.scales[..., : s // g],
                    wq.fmt_name, s, wq.tensor_scale)
    return ARC.to_interleaved(Q.concat_k(wq, dup), w.shape[-1], s)


def reinterleave_qtensor(qt: Q.QTensor, s: int) -> Q.QTensor:
    """Convert a legacy concat-K augmented QTensor ([primary | dup-tail])
    into the canonical interleaved layout. Works on both storage modes
    (f32 carrier and packed byte pairs); a no-op when s == 0."""
    if s == 0:
        return qt
    g = qt.fmt.block_size
    k = qt.valid_k - s
    perm = np.asarray(ARC.interleaved_permutation(k, s, g))
    scale_perm = jnp.asarray(perm[::g] // g)
    scales = jnp.take(qt.scales, scale_perm, axis=-1)
    if qt.packed:
        codes = F.unpack_e2m1(qt.elements)
        elements = F.pack_e2m1(jnp.take(codes, jnp.asarray(perm), axis=-1))
    else:
        elements = jnp.take(qt.elements, jnp.asarray(perm), axis=-1)
    return Q.QTensor(elements, scales, qt.fmt_name, qt.valid_k,
                     qt.tensor_scale, qt.packed)


def reinterleave_legacy_qparams(params: Dict, plans: PlanBundle) -> Dict:
    """Loader shim: re-layout a pre-interleave serving checkpoint.

    Older checkpoints stored ARC-augmented weights as
    [primary_0..K-1 | dup_0..S-1] (concat-K); the kernels and the unified
    emulated path now expect the interleaved layout. Applies
    ``reinterleave_qtensor`` to every quantized linear named in ``plans``.
    """
    new_blocks = []
    for i, block in enumerate(params["blocks"]):
        nb = dict(block)
        for module, leaves in QUANTIZABLE.items():
            if module not in block:
                continue
            sub = dict(block[module])
            for leaf in leaves:
                w = sub[leaf]
                name = f"b{i}.{module}.{leaf}"
                s = plans.meta.get(name, 0)
                if isinstance(w, Q.QTensor) and s:
                    fn = functools.partial(reinterleave_qtensor, s=s)
                    for _ in range(w.elements.ndim - 2):
                        fn = jax.vmap(fn)
                    sub[leaf] = fn(w)
            nb[module] = sub
        new_blocks.append(nb)
    out = dict(params)
    out["blocks"] = new_blocks
    return out


def plan_summary(plans: PlanBundle) -> Dict[str, dict]:
    """Per-layer S statistics (paper Fig. 7)."""
    out = {}
    for name, s in plans.meta.items():
        k = int(plans.arrays[name]["order"].shape[-1])
        out[name] = {"S": int(s), "K": k, "overhead": s / k}
    return out
