from repro.checkpoint.store import (CheckpointManager, load_checkpoint,
                                    load_serving_checkpoint, save_checkpoint,
                                    save_serving_checkpoint)

__all__ = ["CheckpointManager", "load_checkpoint", "load_serving_checkpoint",
           "save_checkpoint", "save_serving_checkpoint"]
