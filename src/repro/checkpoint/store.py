"""Checkpointing: sharded npz store with atomic commit and retention.

Design for the 1000+-node deployment (documented here, exercised at
single-host scale in tests):

  * every host writes only its addressable shards (``jax.device_get`` of
    its local shards); the layout key is the flattened tree path, so a
    restore onto a different mesh re-shards via ``jax.device_put`` with the
    target sharding — elastic restarts with a changed DP degree re-use the
    same checkpoint.
  * writes go to ``step_XXXX.tmp/`` then ``os.replace`` into place — a
    preempted writer never corrupts the latest checkpoint (atomic commit).
  * a ``latest`` pointer file is written after the directory commit;
    readers resolve through it, so torn writes are invisible.
  * retention keeps the newest K checkpoints (plus every ``keep_every``-th
    for disaster recovery).
  * data-pipeline state (stream step) and the RNG key ride along, so a
    restart resumes the exact batch sequence.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        if hasattr(leaf, "sharding"):
            arr = jax.device_put(arr, leaf.sharding)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef,
                                        [l for l in leaves])


def save_checkpoint(directory: str | Path, step: int, params: Any,
                    opt_state: Any = None, extra: Optional[Dict] = None) -> Path:
    """Atomic checkpoint write. Returns the committed directory."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    np.savez(tmp / "params.npz", **_flatten(params))
    if opt_state is not None:
        np.savez(tmp / "opt_state.npz", **_flatten(opt_state))
    meta = {"step": step, "time": time.time(), "extra": extra or {}}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    (directory / "latest.tmp").write_text(final.name)
    os.replace(directory / "latest.tmp", directory / "latest")
    return final


def load_checkpoint(directory: str | Path, params_like: Any,
                    opt_like: Any = None,
                    step: Optional[int] = None) -> Tuple[Any, Any, Dict]:
    """Restore (params, opt_state, meta); shards onto params_like's shardings."""
    directory = Path(directory)
    if step is None:
        name = (directory / "latest").read_text().strip()
    else:
        name = f"step_{step:08d}"
    ckpt = directory / name
    pflat = dict(np.load(ckpt / "params.npz"))
    params = _unflatten_into(params_like, pflat)
    opt = None
    if opt_like is not None and (ckpt / "opt_state.npz").exists():
        opt = _unflatten_into(opt_like, dict(np.load(ckpt / "opt_state.npz")))
    meta = json.loads((ckpt / "meta.json").read_text())
    return params, opt, meta


# ---------------------------------------------------------------------------
# Serving (quantized-weight) checkpoints — layout-stamped
# ---------------------------------------------------------------------------

# the canonical augmented-weight layout current code produces; older
# serving checkpoints (no stamp) used the concat-K layout
WEIGHT_LAYOUT = "interleaved"
_LEGACY_LAYOUT = "concat_k"


def save_serving_checkpoint(directory: str | Path, step: int, qparams: Any,
                            extra: Optional[Dict] = None) -> Path:
    """Save offline-quantized serving weights, stamping the ARC layout."""
    extra = dict(extra or {})
    extra.setdefault("weight_layout", WEIGHT_LAYOUT)
    return save_checkpoint(directory, step, qparams, extra=extra)


def load_serving_checkpoint(directory: str | Path, params_like: Any,
                            plans=None,
                            step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore serving weights, re-interleaving legacy-layout checkpoints.

    Checkpoints written before the interleaved unification stored
    ARC-augmented QTensors as [primary | duplicated-outlier-tail]; their
    meta carries no ``weight_layout`` stamp. Those are converted on read
    (``quant.apply.reinterleave_legacy_qparams``, which needs ``plans``
    for the per-layer outlier counts); stamped checkpoints load as-is.
    """
    params, _, meta = load_checkpoint(directory, params_like, step=step)
    layout = meta.get("extra", {}).get("weight_layout", _LEGACY_LAYOUT)
    if layout != WEIGHT_LAYOUT:
        if plans is None:
            raise ValueError(
                f"checkpoint uses legacy '{layout}' augmented-weight layout; "
                "pass the PlanBundle so it can be re-interleaved on read")
        from repro.quant.apply import reinterleave_legacy_qparams
        params = reinterleave_legacy_qparams(params, plans)
    return params, meta


class CheckpointManager:
    """Retention + cadence policy around save/load."""

    def __init__(self, directory: str | Path, interval: int = 100,
                 keep: int = 3, keep_every: int = 1000):
        self.directory = Path(directory)
        self.interval = interval
        self.keep = keep
        self.keep_every = keep_every

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0

    def save(self, step: int, params, opt_state=None, extra=None) -> Path:
        path = save_checkpoint(self.directory, step, params, opt_state, extra)
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        ptr = self.directory / "latest"
        if not ptr.exists():
            return None
        return int(ptr.read_text().strip().split("_")[1])

    def restore(self, params_like, opt_like=None):
        return load_checkpoint(self.directory, params_like, opt_like)

    def _gc(self) -> None:
        ckpts = sorted(self.directory.glob("step_*"))
        ckpts = [c for c in ckpts if c.is_dir() and not c.name.endswith(".tmp")]
        drop = ckpts[:-self.keep] if self.keep else []
        for c in drop:
            step = int(c.name.split("_")[1])
            if self.keep_every and step % self.keep_every == 0:
                continue
            shutil.rmtree(c)
