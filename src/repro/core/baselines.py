"""Baseline PTQ strategies adapted to block-scaled formats (paper §4.1).

  * RTN            — plain blockwise round-to-nearest (quant.quantize)
  * SmoothQuant    — difficulty migration X' = X/s, W' = W*s (Xiao et al.)
  * QuaRot-style   — Hadamard rotation of the K dimension (Ashkboos et al.)
  * Atom-style     — mixed precision: top-S channels in a high-precision
                     format, bulk in 4-bit (Zhao et al.). On Blackwell this
                     breaks Tensor-Core uniformity (paper §3.1); we emulate
                     it for accuracy comparison only.
  * W4A8           — MXFP4 weights + MXFP8 activations reference.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import quant as Q


# ---------------------------------------------------------------------------
# RTN
# ---------------------------------------------------------------------------


def rtn_matmul(x: jax.Array, w: jax.Array, fmt: str = "nvfp4") -> jax.Array:
    return Q.qmatmul(Q.quantize(x, fmt), Q.quantize(w, fmt))


def w4a8_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """W4A8 reference: MXFP4 weights, MXFP8 activations."""
    return Q.qmatmul(Q.quantize(x, "mxfp8"), Q.quantize(w, "mxfp4"))


# ---------------------------------------------------------------------------
# SmoothQuant
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SmoothPlan:
    smooth: np.ndarray  # (K,) per-channel divisor for X, multiplier for W


def make_smooth_plan(act_absmax: np.ndarray, w_absmax: np.ndarray,
                     alpha: float = 0.5) -> SmoothPlan:
    a = np.asarray(act_absmax, np.float64)
    w = np.asarray(w_absmax, np.float64)
    s = np.power(np.maximum(a, 1e-5), alpha) / np.power(np.maximum(w, 1e-5), 1 - alpha)
    s = np.where(np.isfinite(s) & (s > 0), s, 1.0)
    return SmoothPlan(smooth=s.astype(np.float32))


def smooth_matmul(x: jax.Array, w: jax.Array, plan: SmoothPlan,
                  fmt: str = "nvfp4") -> jax.Array:
    s = jnp.asarray(plan.smooth)
    return rtn_matmul(x / s, w * s, fmt)


# ---------------------------------------------------------------------------
# QuaRot-style Hadamard rotation
# ---------------------------------------------------------------------------


def hadamard_matrix(k: int) -> np.ndarray:
    """Randomized orthogonal Hadamard-like transform for arbitrary K.

    For power-of-two K this is the exact normalized Sylvester Hadamard;
    otherwise we use H_{2^m} on the largest power-of-two prefix blocks
    (block-diagonal), which preserves orthogonality.
    """
    def pow2_h(n: int) -> np.ndarray:
        h = np.array([[1.0]])
        while h.shape[0] < n:
            h = np.block([[h, h], [h, -h]])
        return h / np.sqrt(h.shape[0])

    if k & (k - 1) == 0:
        return pow2_h(k).astype(np.float32)
    # block-diagonal decomposition over power-of-two chunks
    blocks = []
    rem = k
    while rem:
        b = 1 << (rem.bit_length() - 1)
        blocks.append(pow2_h(b))
        rem -= b
    out = np.zeros((k, k), np.float64)
    i = 0
    for b in blocks:
        n = b.shape[0]
        out[i:i + n, i:i + n] = b
        i += n
    return out.astype(np.float32)


def quarot_matmul(x: jax.Array, w: jax.Array, fmt: str = "nvfp4",
                  h: Optional[jax.Array] = None) -> jax.Array:
    """Rotate K dim of both operands: (XH)(WH)^T = XW^T exactly; quantize after."""
    if h is None:
        h = jnp.asarray(hadamard_matrix(x.shape[-1]))
    xh = jnp.matmul(x, h)
    wh = jnp.matmul(w, h)
    return rtn_matmul(xh, wh, fmt)


# ---------------------------------------------------------------------------
# Atom-style mixed precision (emulated — hardware-infeasible on NVFP4 MMA)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AtomPlan:
    order: np.ndarray
    s: int                      # channels kept in high precision
    lo_fmt: str = "nvfp4"
    hi_fmt: str = "mxfp8"


def make_atom_plan(act_absmax: np.ndarray, s: int = 128,
                   lo_fmt: str = "nvfp4", hi_fmt: str = "mxfp8") -> AtomPlan:
    order = np.argsort(-np.asarray(act_absmax), kind="stable").astype(np.int32)
    g = max(F.get_format(lo_fmt).block_size, F.get_format(hi_fmt).block_size)
    s = int(-(-s // g) * g)
    return AtomPlan(order=order, s=s, lo_fmt=lo_fmt, hi_fmt=hi_fmt)


def atom_matmul(x: jax.Array, w: jax.Array, plan: AtomPlan) -> jax.Array:
    order = jnp.asarray(plan.order)
    xr = jnp.take(x, order, axis=-1)
    wr = jnp.take(w, order, axis=-1)
    s = plan.s
    hi = Q.qmatmul(Q.quantize(xr[..., :s], plan.hi_fmt),
                   Q.quantize(wr[..., :s], plan.hi_fmt))
    lo = Q.qmatmul(Q.quantize(xr[..., s:], plan.lo_fmt),
                   Q.quantize(wr[..., s:], plan.lo_fmt))
    return hi + lo
