"""Worst-case error bound analysis (paper §3.4).

Notation: dynamic range M, scale alignment overhead alpha = s/M >= 1,
precision limit epsilon (eps4 = 2^-2 for E2M1, eps8 = 2^-4 for E4M3,
eps4^2 = eps8).

  MXFP8 single-stage :  B_mx  = alpha_mx * M * eps8,   alpha_mx in [1, 2)
  ARCQuant dual-stage:  B_arc = (alpha1 * alpha2) * M * eps8,
                        sup alpha1*alpha2 = 1.125^2 ~= 1.266 < 2

so the dual-stage NVFP4 worst case is *tighter* than MXFP8's.
"""
from __future__ import annotations

import dataclasses

import numpy as np

EPS4 = 2.0 ** -2      # E2M1 precision limit
EPS8 = 2.0 ** -4      # E4M3 precision limit
ALPHA_MX_SUP = 2.0    # E8M0 scales are powers of two -> alpha in [1,2)
ALPHA_NV_SUP = 1.125  # E4M3 scales have 2^-3 mantissa steps -> alpha in [1,1.125]


def mxfp8_bound(m: float, alpha: float = ALPHA_MX_SUP) -> float:
    """B_mx = alpha_mx * M * eps8  (paper Eq. 3, worst case alpha_mx -> 2)."""
    return alpha * m * EPS8


def arc_bound(m: float, alpha1: float = ALPHA_NV_SUP,
              alpha2: float = ALPHA_NV_SUP) -> float:
    """B_arc = (alpha1 alpha2) M eps8  (paper Eq. 4)."""
    return alpha1 * alpha2 * m * EPS8


def bound_ratio() -> float:
    """sup B_arc / sup B_mx = 1.266/2 ~= 0.633 — ARC strictly tighter."""
    return (ALPHA_NV_SUP ** 2) / ALPHA_MX_SUP


@dataclasses.dataclass
class EmpiricalErrors:
    max_err_arc: float
    max_err_mxfp8: float
    bound_arc: float
    bound_mxfp8: float

    @property
    def arc_within_bound(self) -> bool:
        return self.max_err_arc <= self.bound_arc * (1 + 1e-6)

    @property
    def mx_within_bound(self) -> bool:
        return self.max_err_mxfp8 <= self.bound_mxfp8 * (1 + 1e-6)


def empirical_worst_case(x: np.ndarray) -> EmpiricalErrors:
    """Measure dual-stage NVFP4 vs single-stage MXFP8 errors on data ``x``.

    ``x`` is treated as a single block-compensated channel group (i.e. all
    values receive residual compensation), matching the §3.4 setting.
    """
    import jax.numpy as jnp

    from repro.core import quant as Q

    x = np.asarray(x, np.float32).reshape(1, -1)
    pad = (-x.shape[-1]) % 32
    if pad:
        x = np.pad(x, [(0, 0), (0, pad)])
    m = float(np.abs(x).max())

    # single-stage MXFP8
    mx = Q.quantize_dequantize(jnp.asarray(x), "mxfp8")
    err_mx = float(np.abs(np.asarray(mx) - x).max())

    # dual-stage NVFP4: primary + residual
    q1 = Q.quantize_dequantize(jnp.asarray(x), "nvfp4")
    r = jnp.asarray(x) - q1
    q2 = Q.quantize_dequantize(r, "nvfp4")
    err_arc = float(np.abs(np.asarray(q1 + q2) - x).max())

    return EmpiricalErrors(
        max_err_arc=err_arc,
        max_err_mxfp8=err_mx,
        bound_arc=arc_bound(m),
        bound_mxfp8=mxfp8_bound(m),
    )
