"""Block-scaled quantize / dequantize (paper §3.1, Appendix A).

Quantization always operates on the *last* axis (the GEMM reduction
dimension K) in groups of ``fmt.block_size``. The result is a ``QTensor``
holding quantized element values (f32 carrier), per-block scales, and —
for NVFP4 — the per-tensor FP32 scale that aligns the E4M3 block scales.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import formats as F


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Quantized tensor: ``dequant = elements * scale`` (broadcast per block).

    ``elements`` is padded up to a multiple of the block size along K;
    ``valid_k`` records the logical (unpadded) length.

    Storage modes for E2M1-element formats (nvfp4/mxfp4):
      * f32 carrier (default) — element *values*, used in the math paths
      * ``packed=True`` — uint8 holding two 4-bit code points per byte
        (the deployment representation: ~4.5 bits/value with bf16 block
        scales, which are exact for E4M3/E8M0 values)
    """

    elements: jax.Array          # (..., Kp) values, or (..., Kp//2) packed codes
    scales: jax.Array            # (..., Kp // g) effective per-block scales
    fmt_name: str
    valid_k: int
    tensor_scale: Optional[jax.Array] = None   # NVFP4 only (informational)
    packed: bool = False

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return ((self.elements, self.scales, self.tensor_scale),
                (self.fmt_name, self.valid_k, self.packed))

    @classmethod
    def tree_unflatten(cls, aux, children):
        elements, scales, tensor_scale = children
        return cls(elements, scales, aux[0], aux[1], tensor_scale, aux[2])

    # -- api ----------------------------------------------------------------
    @property
    def fmt(self) -> F.BlockFormat:
        return F.get_format(self.fmt_name)

    @property
    def shape(self):
        return (*self.elements.shape[:-1], self.valid_k)

    def element_values(self) -> jax.Array:
        """Quantized element values as f32, unpacking codes if needed."""
        if not self.packed:
            return self.elements
        codes = F.unpack_e2m1(self.elements)
        return F.decode_e2m1(codes)

    def scale_values(self) -> jax.Array:
        """Effective f32 block scales, decoding 8-bit codes if packed."""
        if not self.packed:
            return self.scales
        if self.fmt_name == "nvfp4":
            return F.decode_e4m3(self.scales) * self.tensor_scale
        return F.decode_e8m0(self.scales)

    def dequantize(self) -> jax.Array:
        g = self.fmt.block_size
        el = self.element_values()
        x = el.reshape(*el.shape[:-1], -1, g)
        x = x * self.scale_values()[..., None].astype(jnp.float32)
        return x.reshape(el.shape)[..., : self.valid_k]

    def to_packed(self) -> "QTensor":
        """Deployment storage: 2 E2M1 codes/byte + true 8-bit scale codes
        (E4M3 relative to the FP32 tensor scale for NVFP4; E8M0 for MXFP4)
        = the spec's 4.5 bits/value. Bit-exact roundtrip."""
        assert self.fmt_name in ("nvfp4", "mxfp4") and not self.packed
        codes = F.encode_e2m1(self.elements)
        if self.fmt_name == "nvfp4":
            sc = F.encode_e4m3(self.scales / self.tensor_scale)
        else:
            sc = F.encode_e8m0(self.scales)
        return QTensor(F.pack_e2m1(codes), sc, self.fmt_name, self.valid_k,
                       self.tensor_scale, True)

    def bits_per_value(self) -> float:
        g = self.fmt.block_size
        return self.fmt.element_bits + 8.0 / g


def _block_amax(x: jax.Array, g: int) -> jax.Array:
    xb = x.reshape(*x.shape[:-1], -1, g)
    return jnp.max(jnp.abs(xb), axis=-1)


def compute_scales(x: jax.Array, fmt: F.BlockFormat,
                   tensor_amax: Optional[jax.Array] = None,
                   tensor_scale: Optional[jax.Array] = None):
    """Per-block effective scales for ``fmt`` (and the NVFP4 tensor scale).

    ``tensor_scale`` (NVFP4 only) bypasses the amax-derived FP32 scale with
    a calibration-time constant — the deployed serving configuration, where
    online activation quantization must not take a second pass over X.
    """
    g = fmt.block_size
    amax = _block_amax(x, g)
    if fmt.scale_kind == "e8m0":
        # OCP MX: shared scale = 2^(floor(log2(amax)) - emax_elem).
        _, ef = jnp.frexp(jnp.where(amax > 0, amax, 1.0))
        e = (ef - 1).astype(jnp.float32)
        emax_elem = jnp.floor(jnp.log2(jnp.asarray(fmt.element_max)))
        scales = jnp.where(amax > 0,
                           jnp.ldexp(jnp.float32(1.0),
                                     (e - emax_elem).astype(jnp.int32)), 1.0)
        return scales, None
    if fmt.scale_kind == "e4m3+tensor":
        # NVFP4: block scale is E4M3 *relative to* a per-tensor FP32 scale
        # chosen so the largest block scale maps to the top of E4M3 range.
        if tensor_scale is not None:
            t = jnp.asarray(tensor_scale, jnp.float32)
        else:
            if tensor_amax is None:
                tensor_amax = jnp.max(jnp.abs(x))
            t = tensor_amax / (fmt.element_max * F.E4M3_MAX)
        t = jnp.where(t > 0, t, 1.0)
        block = F.quantize_e4m3(amax / fmt.element_max / t)
        block = jnp.maximum(block, jnp.float32(2.0 ** -9))  # smallest e4m3 subnormal
        scales = block * t
        return scales, t
    if fmt.scale_kind == "f32":
        qmax = fmt.element_max
        scales = jnp.where(amax > 0, amax / qmax, 1.0)
        return scales, None
    raise ValueError(fmt.scale_kind)


def quantize(x: jax.Array, fmt: F.BlockFormat | str,
             tensor_amax: Optional[jax.Array] = None,
             tensor_scale: Optional[jax.Array] = None) -> QTensor:
    """Blockwise RTN quantization along the last axis (paper Eq. 1)."""
    if isinstance(fmt, str):
        fmt = F.get_format(fmt)
    g = fmt.block_size
    x = jnp.asarray(x, jnp.float32)
    k = x.shape[-1]
    pad = (-k) % g
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    scales, t = compute_scales(x, fmt, tensor_amax, tensor_scale)
    xb = x.reshape(*x.shape[:-1], -1, g)
    q = fmt.quantize_element(xb / scales[..., None])
    q = jnp.clip(q, -fmt.element_max, fmt.element_max)
    elements = q.reshape(x.shape)
    return QTensor(elements, scales, fmt.name, k, t)


def quantize_dequantize(x: jax.Array, fmt: F.BlockFormat | str,
                        tensor_amax: Optional[jax.Array] = None) -> jax.Array:
    """Fake-quant helper: Q(X) = s_X * Q_X (paper notation)."""
    return quantize(x, fmt, tensor_amax).dequantize().astype(x.dtype)


def concat_k(a: QTensor, b: QTensor) -> QTensor:
    """Concatenate two QTensors along the reduction dimension K.

    Both operands must be block-aligned (valid_k % g == 0) — guaranteed by
    construction in the ARC augmentation path where S % 16 == 0.
    """
    assert a.fmt_name == b.fmt_name
    g = a.fmt.block_size
    assert a.valid_k % g == 0 and b.valid_k % g == 0, (a.valid_k, b.valid_k)
    elements = jnp.concatenate([a.elements, b.elements], axis=-1)
    scales = jnp.concatenate([a.scales, b.scales], axis=-1)
    return QTensor(elements, scales, a.fmt_name, a.valid_k + b.valid_k,
                   a.tensor_scale)


def qmatmul(xq: QTensor, wq: QTensor, preferred_dtype=jnp.float32) -> jax.Array:
    """Emulated unified-precision GEMM: dequantize then MXU matmul.

    On Blackwell this is a native NVFP4 MMA; on TPU we dequantize into the
    bf16 datapath. The *math* (including the augmented reduction dimension)
    is identical, which is what the accuracy experiments exercise.
    """
    x = xq.dequantize()
    w = wq.dequantize()
    return jnp.matmul(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16).T,
                      preferred_element_type=preferred_dtype)
