"""Bit-exact emulation of block-scaled numerical formats (paper Appendix A).

All codecs are pure-jnp, vectorised, and jit-safe. Values are held in
float32 carriers; ``encode_*``/``decode_*`` expose the integer code points
so the Pallas kernels can operate on packed representations.

Formats (paper Table 7):
  MXFP8  : FP8 E4M3 elements, g=32, E8M0 scale
  MXFP4  : FP4 E2M1 elements, g=32, E8M0 scale
  NVFP4  : FP4 E2M1 elements, g=16, E4M3 scale + per-tensor FP32 scale
  INT4   : symmetric int4, group scale in f32 (reference integer baseline)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Generic minifloat round-to-nearest-even
# ---------------------------------------------------------------------------


def quantize_minifloat(x: jax.Array, mbits: int, emin: int, max_normal: float) -> jax.Array:
    """Round ``x`` to the nearest representable minifloat value (RNE).

    mbits       number of mantissa bits
    emin        exponent of the smallest *normal* number (subnormals below)
    max_normal  saturation value (no inf encoding — scales/elements saturate)
    """
    x = jnp.asarray(x, jnp.float32)
    absx = jnp.abs(x)
    # Exponent of the value, clamped at emin so the subnormal range shares
    # the fixed step 2^(emin - mbits). frexp is bit-exact (log2 is 1 ulp
    # off at exact powers of two, which flips floor()).
    _, ef = jnp.frexp(jnp.where(absx > 0, absx, 1.0))
    e = (ef - 1).astype(jnp.float32)
    e = jnp.maximum(e, float(emin))
    # ldexp: exact powers of two (XLA lowers exp2 via exp, which is inexact)
    step = jnp.ldexp(jnp.float32(1.0), (e - mbits).astype(jnp.int32))
    # jnp.round implements round-half-to-even, matching IEEE RNE.
    q = jnp.round(absx / step) * step
    q = jnp.minimum(q, float(max_normal))
    return jnp.sign(x) * jnp.where(absx > 0, q, 0.0)


# E2M1 (FP4): +-{0, .5, 1, 1.5, 2, 3, 4, 6}
E2M1_VALUES = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], np.float32)
E2M1_MAX = 6.0
E4M3_MAX = 448.0
E5M2_MAX = 57344.0

quantize_e2m1 = partial(quantize_minifloat, mbits=1, emin=0, max_normal=E2M1_MAX)
quantize_e4m3 = partial(quantize_minifloat, mbits=3, emin=-6, max_normal=E4M3_MAX)
quantize_e5m2 = partial(quantize_minifloat, mbits=2, emin=-14, max_normal=E5M2_MAX)


def quantize_e8m0(x: jax.Array) -> jax.Array:
    """Power-of-two scale (exponent-only, OCP MX shared scale).

    Per the OCP MX spec the shared scale is 2^(floor(log2(amax)) - emax_elem);
    this helper just snaps a positive scale to the nearest *lower* power of
    two (exponent floor), the caller supplies amax/max_normal_elem.
    """
    x = jnp.asarray(x, jnp.float32)
    _, ef = jnp.frexp(jnp.where(x > 0, x, 1.0))
    e = jnp.clip((ef - 1).astype(jnp.float32), -127.0, 127.0)
    return jnp.where(x > 0, jnp.ldexp(jnp.float32(1.0), e.astype(jnp.int32)), 1.0)


# ---------------------------------------------------------------------------
# E2M1 code points (for packed kernels)
# ---------------------------------------------------------------------------


def encode_e2m1(values: jax.Array) -> jax.Array:
    """Map *already-quantized* E2M1 values to 4-bit codes (sign<<3 | idx)."""
    v = jnp.asarray(values, jnp.float32)
    mags = jnp.abs(v)
    table = jnp.asarray(E2M1_VALUES)
    idx = jnp.argmin(jnp.abs(mags[..., None] - table[None, :]), axis=-1)
    sign = (v < 0).astype(jnp.uint8)
    return (sign << 3 | idx.astype(jnp.uint8)).astype(jnp.uint8)


def decode_e2m1(codes: jax.Array) -> jax.Array:
    codes = codes.astype(jnp.int32)
    idx = codes & 0x7
    sign = 1.0 - 2.0 * ((codes >> 3) & 1).astype(jnp.float32)
    return sign * jnp.take(jnp.asarray(E2M1_VALUES), idx)


def encode_e4m3(v: jax.Array) -> jax.Array:
    """Encode positive *already-E4M3-rounded* values to their 8-bit codes."""
    v = jnp.asarray(v, jnp.float32)
    _, ef = jnp.frexp(jnp.where(v > 0, v, 1.0))
    e = jnp.clip((ef - 1).astype(jnp.float32), -6.0, 8.0)
    m = jnp.round(v / jnp.ldexp(jnp.float32(1.0), (e - 3.0).astype(jnp.int32)))  # 8..15 normals
    # mantissa overflow (m == 16) bumps the exponent
    e = jnp.where(m >= 16, e + 1, e)
    m = jnp.where(m >= 16, 8, m)
    normal = v >= jnp.float32(2.0 ** -6)
    byte_n = ((e + 7).astype(jnp.int32) << 3) | (m - 8).astype(jnp.int32)
    byte_s = jnp.round(v * 512.0).astype(jnp.int32)   # subnormal step 2^-9
    byte = jnp.where(normal, byte_n, jnp.clip(byte_s, 0, 7))
    return jnp.where(v > 0, byte, 0).astype(jnp.uint8)


def decode_e4m3(codes: jax.Array) -> jax.Array:
    c = codes.astype(jnp.int32)
    e = (c >> 3) & 0xF
    m = (c & 7).astype(jnp.float32)
    normal = e > 0
    val_n = (8.0 + m) * jnp.ldexp(jnp.float32(1.0), e - 10)
    val_s = m * jnp.float32(2.0 ** -9)
    return jnp.where(normal, val_n, val_s)


def encode_e8m0(v: jax.Array) -> jax.Array:
    """Encode power-of-two scales to 8-bit biased exponents (bit-exact)."""
    _, ef = jnp.frexp(jnp.where(v > 0, v, 1.0))
    return jnp.clip((ef - 1) + 127, 0, 254).astype(jnp.uint8)


def decode_e8m0(codes: jax.Array) -> jax.Array:
    return jnp.ldexp(jnp.float32(1.0), codes.astype(jnp.int32) - 127)


def pack_e2m1(codes: jax.Array) -> jax.Array:
    """Pack pairs of 4-bit codes along the last axis into uint8."""
    assert codes.shape[-1] % 2 == 0
    lo = codes[..., 0::2].astype(jnp.uint8)
    hi = codes[..., 1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_e2m1(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


# ---------------------------------------------------------------------------
# Format descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockFormat:
    """A block-scaled numeric format (paper Table 7)."""

    name: str
    element_bits: int
    block_size: int
    element_max: float          # max normal of the element dtype
    scale_kind: str             # "e8m0" | "e4m3+tensor" | "f32"
    # precision limit epsilon = 2^-(mbits+1) of the element type at max binade
    epsilon: float

    def quantize_element(self, x: jax.Array) -> jax.Array:
        if self.name in ("nvfp4", "mxfp4"):
            return quantize_e2m1(x)
        if self.name == "mxfp8":
            return quantize_e4m3(x)
        if self.name == "int4":
            return jnp.clip(jnp.round(x), -7, 7)
        raise ValueError(self.name)


NVFP4 = BlockFormat("nvfp4", 4, 16, E2M1_MAX, "e4m3+tensor", epsilon=0.25)
MXFP4 = BlockFormat("mxfp4", 4, 32, E2M1_MAX, "e8m0", epsilon=0.25)
MXFP8 = BlockFormat("mxfp8", 8, 32, E4M3_MAX, "e8m0", epsilon=0.0625)
INT4 = BlockFormat("int4", 4, 128, 7.0, "f32", epsilon=0.5 / 7.0)

FORMATS = {f.name: f for f in (NVFP4, MXFP4, MXFP8, INT4)}


def get_format(name: str) -> BlockFormat:
    return FORMATS[name]
