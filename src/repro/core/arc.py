"""ARCQuant — Augmented Residual Channels (paper §3.2, §3.3).

Offline (calibration time):
  * per-channel absmax stats -> channel reordering indices (descending)
  * outlier count S from the threshold rule  tau = 2^-3 * M   (M = layer max)
  * S is rounded up to a multiple of the block size (16 for NVFP4) so the
    augmented channels tile exactly into scale blocks, matching the
    interleaved hardware layout of Appendix D.

Online (per forward):
  * reorder activations, primary block quantization Q_X
  * residual of the first S channels  R_o = X_o - s_X * Q_X_o
  * quantize the residual  Q_R_o  and concatenate along K
  * one unified GEMM over (N, K + S, M):
        Y ~= Q(X) Q(W)^T + Q(R_o) Q(W_o)^T            (paper Eq. 2)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import quant as Q


@dataclasses.dataclass(frozen=True)
class ArcPlan:
    """Offline-calibrated plan for one linear layer."""

    order: np.ndarray            # (K,) channel permutation, outliers first
    s: int                       # number of augmented residual channels
    fmt_name: str = "nvfp4"
    layer_max: float = 0.0       # M — calibration layer-wise abs maximum

    @property
    def fmt(self) -> F.BlockFormat:
        return F.get_format(self.fmt_name)

    @property
    def inverse_order(self) -> np.ndarray:
        inv = np.empty_like(self.order)
        inv[self.order] = np.arange(self.order.size)
        return inv


def select_outliers(channel_absmax: np.ndarray, fmt: F.BlockFormat | str = "nvfp4",
                    max_fraction: float = 0.25,
                    threshold_exp: int = -3) -> ArcPlan:
    """Adaptive outlier identification (paper §3.2).

    tau = 2^threshold_exp * M reflects the 3-bit exponent-width gap between
    the per-tensor E5M2 reference and the E2M1 target: channels below tau
    sit in the range where NVFP4 already matches the FP8 reference, so
    only channels above tau get residual compensation.
    """
    if isinstance(fmt, str):
        fmt = F.get_format(fmt)
    stats = np.asarray(channel_absmax, np.float64)
    k = stats.size
    m = float(stats.max()) if k else 0.0
    tau = (2.0 ** threshold_exp) * m
    order = np.argsort(-stats, kind="stable").astype(np.int32)
    s_raw = int((stats > tau).sum())
    g = fmt.block_size
    s = min(int(-(-s_raw // g) * g), int(max_fraction * k) // g * g)
    s = max(s, 0)
    return ArcPlan(order=order, s=s, fmt_name=fmt.name, layer_max=m)


# ---------------------------------------------------------------------------
# Online activation path (paper §3.2 "Online Activation Quantization")
# ---------------------------------------------------------------------------


def augment_activations(x: jax.Array, plan: ArcPlan) -> Q.QTensor:
    """Reorder -> primary quant -> residual quant -> concat along K."""
    fmt = plan.fmt
    xr = jnp.take(x, jnp.asarray(plan.order), axis=-1)
    xq = Q.quantize(xr, fmt)
    if plan.s == 0:
        return xq
    s = plan.s
    x_o = xr[..., :s]
    deq = xq.dequantize()[..., :s]
    r_o = x_o - deq
    rq = Q.quantize(r_o, fmt)
    return Q.concat_k(xq, rq)


# ---------------------------------------------------------------------------
# Offline weight path (paper §3.2 "Offline Weight Quantization")
# ---------------------------------------------------------------------------


def augment_weights(w: jax.Array, plan: ArcPlan) -> Q.QTensor:
    """Reorder W along K, quantize, duplicate the quantized outlier columns.

    The duplicated columns reuse the *already-quantized* values and scales
    (no re-quantization), so the GEMM's extra S columns compute exactly
    R_o Q(W_o)^T.
    """
    fmt = plan.fmt
    wr = jnp.take(w, jnp.asarray(plan.order), axis=-1)
    wq = Q.quantize(wr, fmt)
    if plan.s == 0:
        return wq
    g = fmt.block_size
    s = plan.s
    dup = Q.QTensor(wq.elements[..., :s], wq.scales[..., : s // g],
                    wq.fmt_name, s, wq.tensor_scale)
    return Q.concat_k(wq, dup)


# ---------------------------------------------------------------------------
# Unified GEMM execution (paper Eq. 2) + explicit two-GEMM reference
# ---------------------------------------------------------------------------


def arc_matmul(x: jax.Array, w_aug: Q.QTensor, plan: ArcPlan) -> jax.Array:
    """Y = Q(X_aug) Q(W_aug)^T — single GEMM over the extended K+S dim."""
    x_aug = augment_activations(x, plan)
    return Q.qmatmul(x_aug, w_aug)


def arc_matmul_reference(x: jax.Array, w: jax.Array, plan: ArcPlan) -> jax.Array:
    """Explicit compensation: Q(X)Q(W)^T + Q(R_o)Q(W_o)^T (for equivalence tests)."""
    fmt = plan.fmt
    xr = jnp.take(x, jnp.asarray(plan.order), axis=-1)
    wr = jnp.take(w, jnp.asarray(plan.order), axis=-1)
    xq = Q.quantize(xr, fmt)
    wq = Q.quantize(wr, fmt)
    y = Q.qmatmul(xq, wq)
    if plan.s == 0:
        return y
    s = plan.s
    r_o = xr[..., :s] - xq.dequantize()[..., :s]
    rq = Q.quantize(r_o, fmt)
    g = fmt.block_size
    wo = Q.QTensor(wq.elements[..., :s], wq.scales[..., : s // g],
                   wq.fmt_name, s, wq.tensor_scale)
    return y + Q.qmatmul(rq, wo)


def fake_quant_matmul(x: jax.Array, w: jax.Array, plan: ArcPlan) -> jax.Array:
    """High-level simulated path used inside models: bf16 matmul of the
    dequantized augmented operands, numerically equal to arc_matmul."""
    return arc_matmul(x, augment_weights(w, plan), plan)


# ---------------------------------------------------------------------------
# Interleaved channel layout (paper Appendix D)
# ---------------------------------------------------------------------------


def interleaved_permutation(k: int, s: int, g: int = 16) -> np.ndarray:
    """Permutation of the augmented K+S axis into the hardware layout.

    Logical layout is [primary_0..K-1 | residual_0..S-1]; the kernel layout
    interleaves each 16-channel primary outlier block with its residual
    block: [P0 R0 P1 R1 ... P_{S/g-1} R_{S/g-1} P_{S/g} ... P_{K/g-1}].
    GEMM accumulation is permutation-invariant along K, so results match.
    """
    assert s % g == 0 and k % g == 0
    blocks_k, blocks_s = k // g, s // g
    out = []
    for b in range(blocks_k):
        out.extend(range(b * g, (b + 1) * g))
        if b < blocks_s:
            out.extend(range(k + b * g, k + (b + 1) * g))
    return np.asarray(out, np.int32)


def to_interleaved(qt: Q.QTensor, k: int, s: int) -> Q.QTensor:
    """Reorder an augmented QTensor into the interleaved kernel layout."""
    g = qt.fmt.block_size
    perm = jnp.asarray(interleaved_permutation(k, s, g))
    elements = jnp.take(qt.elements, perm, axis=-1)
    scales = jnp.take(qt.scales, jnp.asarray(perm[::g] // g), axis=-1)
    return Q.QTensor(elements, scales, qt.fmt_name, qt.valid_k, qt.tensor_scale)
