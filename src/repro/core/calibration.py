"""Calibration pass (paper §4.1 / Appendix B.1).

The paper calibrates on 128 x 2048-token WikiText2 segments; here the
calibration stream is any iterable of activation matrices per layer. The
only statistic ARCQuant needs is the per-channel absolute maximum, which
makes the pass a cheap streaming reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arc
from repro.core import formats as F


@dataclasses.dataclass
class ChannelStats:
    """Streaming per-channel absmax accumulator for one linear layer."""

    absmax: np.ndarray

    @classmethod
    def init(cls, k: int) -> "ChannelStats":
        return cls(np.zeros((k,), np.float32))

    def update(self, x) -> None:
        x = np.asarray(jax.device_get(x))
        flat = np.abs(x.reshape(-1, x.shape[-1])).max(axis=0)
        np.maximum(self.absmax, flat, out=self.absmax)


class Calibrator:
    """Collects per-layer channel stats and emits ArcPlans.

    Usage:
        calib = Calibrator()
        for batch in calib_set:
            acts = model.capture_linear_inputs(params, batch)
            calib.observe(acts)           # {layer_name: (tokens, K)}
        plans = calib.make_plans(fmt="nvfp4")
    """

    def __init__(self) -> None:
        self.stats: Dict[str, ChannelStats] = {}

    def observe(self, acts: Mapping[str, jax.Array]) -> None:
        for name, x in acts.items():
            k = x.shape[-1]
            if name not in self.stats:
                self.stats[name] = ChannelStats.init(k)
            self.stats[name].update(x)

    def observe_stats(self, stats: Mapping[str, jax.Array]) -> None:
        """Observe pre-reduced per-channel absmax vectors (scan-friendly)."""
        for name, v in stats.items():
            v = np.asarray(jax.device_get(v), np.float32).reshape(-1)
            if name not in self.stats:
                self.stats[name] = ChannelStats(v.copy())
            else:
                np.maximum(self.stats[name].absmax, v, out=self.stats[name].absmax)

    def make_plans(self, fmt: F.BlockFormat | str = "nvfp4",
                   max_fraction: float = 0.25) -> Dict[str, arc.ArcPlan]:
        return {name: arc.select_outliers(st.absmax, fmt, max_fraction)
                for name, st in self.stats.items()}

    def summary(self) -> Dict[str, dict]:
        out = {}
        for name, st in self.stats.items():
            m = float(st.absmax.max())
            tau = m / 8.0
            out[name] = {
                "k": int(st.absmax.size),
                "layer_max": m,
                "outliers_above_tau": int((st.absmax > tau).sum()),
            }
        return out
