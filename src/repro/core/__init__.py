"""ARCQuant core: formats, block quantizers, augmented residual channels."""
from repro.core import arc, baselines, calibration, error_bounds, formats, quant
from repro.core.arc import (ArcPlan, arc_matmul, arc_matmul_reference,
                            augment_activations, augment_weights,
                            fake_quant_matmul, select_outliers)
from repro.core.calibration import Calibrator
from repro.core.formats import FORMATS, INT4, MXFP4, MXFP8, NVFP4, get_format
from repro.core.quant import QTensor, qmatmul, quantize, quantize_dequantize

__all__ = [
    "arc", "baselines", "calibration", "error_bounds", "formats", "quant",
    "ArcPlan", "arc_matmul", "arc_matmul_reference", "augment_activations",
    "augment_weights", "fake_quant_matmul", "select_outliers", "Calibrator",
    "FORMATS", "INT4", "MXFP4", "MXFP8", "NVFP4", "get_format",
    "QTensor", "qmatmul", "quantize", "quantize_dequantize",
]
