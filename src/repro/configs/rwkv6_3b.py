"""RWKV6 "Finch" 3B [arXiv:2404.05892]: attention-free, data-dependent decay.
Sub-quadratic (O(1) recurrent state) -> eligible for long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    mixer_pattern=("rwkv",), ffn_pattern=("dense",),  # ffn -> rwkv channel-mix
    rwkv_head_dim=64,
    subquadratic=True,
)
