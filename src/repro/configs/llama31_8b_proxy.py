"""Llama-3.1-8B — the paper's own primary evaluation model (Table 1/2).
Included for the accuracy benchmarks at reduced scale."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama31-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    rope_theta=5e5,
)
