"""Gemma-3-12B [hf:google/gemma-3, unverified]: 5:1 local:global attention,
sliding window 1024, 128k context. 5/6 of layers are windowed ->
sub-quadratic enough for long_500k (global-layer KV is the O(S) part)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    mixer_pattern=("local", "local", "local", "local", "local", "full"),
    sliding_window=1024, rope_theta=1e6,
    tie_embeddings=True,
    subquadratic=True,
)
