"""Qwen2-VL-2B backbone [arXiv:2409.12191]: M-RoPE, dynamic-resolution ViT
frontend stubbed (input_specs supplies precomputed patch embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, mrope=True, rope_theta=1e6,
    frontend="vision_stub",
)
