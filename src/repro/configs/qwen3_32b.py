"""Qwen3-32B [hf:Qwen/Qwen3-8B family]: qk_norm, GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936,
    qk_norm=True, rope_theta=1e6,
)
