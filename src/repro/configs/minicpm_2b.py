"""MiniCPM-2B [arXiv:2404.06395]: llama-like arch, MHA (kv=36); WSD schedule
implemented in repro.optim (the paper's training contribution)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122753,
    tie_embeddings=True,
)
