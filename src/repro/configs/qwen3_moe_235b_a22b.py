"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family]: 128 experts, top-8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    qk_norm=True, rope_theta=1e6,
    mixer_pattern=("full",), ffn_pattern=("moe",),
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
)
