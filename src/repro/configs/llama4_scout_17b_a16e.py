"""Llama-4-Scout-17B-16E [hf:meta-llama, unverified]: MoE top-1, 16 experts.
Early-fusion multimodality out of scope (text backbone per assignment)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    rope_theta=5e5,
    mixer_pattern=("full",), ffn_pattern=("moe",),
    num_experts=16, experts_per_token=1, moe_d_ff=8192,
)
