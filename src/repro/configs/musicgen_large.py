"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens;
audio codec frontend stubbed (precomputed frame embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    rope_theta=1e4,   # adaptation: RoPE in place of sinusoidal embeddings
    frontend="audio_stub",
)
