"""Architecture registry: the 10 assigned archs + the paper's own model."""
from repro.configs import base
from repro.configs.base import (LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                                DECODE_32K, ModelConfig, QuantConfig,
                                ShapeConfig)

from repro.configs.qwen2_vl_2b import CONFIG as QWEN2_VL_2B
from repro.configs.musicgen_large import CONFIG as MUSICGEN_LARGE
from repro.configs.qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B
from repro.configs.llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.jamba_v01_52b import CONFIG as JAMBA_52B
from repro.configs.qwen2_1_5b import CONFIG as QWEN2_1_5B
from repro.configs.qwen3_32b import CONFIG as QWEN3_32B
from repro.configs.minicpm_2b import CONFIG as MINICPM_2B
from repro.configs.gemma3_12b import CONFIG as GEMMA3_12B
from repro.configs.llama31_8b_proxy import CONFIG as LLAMA31_8B

ARCHS = {c.name: c for c in (
    QWEN2_VL_2B, MUSICGEN_LARGE, QWEN3_MOE_235B, LLAMA4_SCOUT, RWKV6_3B,
    JAMBA_52B, QWEN2_1_5B, QWEN3_32B, MINICPM_2B, GEMMA3_12B, LLAMA31_8B,
)}

ASSIGNED = [c.name for c in (
    QWEN2_VL_2B, MUSICGEN_LARGE, QWEN3_MOE_235B, LLAMA4_SCOUT, RWKV6_3B,
    JAMBA_52B, QWEN2_1_5B, QWEN3_32B, MINICPM_2B, GEMMA3_12B,
)]


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name]


def cells():
    """All 40 assigned (arch x shape) cells with skip annotations."""
    out = []
    for name in ASSIGNED:
        cfg = ARCHS[name]
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and not cfg.subquadratic:
                skip = "full-attention arch: 500k decode needs sub-quadratic mixer"
            out.append((cfg, shape, skip))
    return out


__all__ = ["ARCHS", "ASSIGNED", "get_arch", "cells", "ModelConfig",
           "QuantConfig", "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K",
           "DECODE_32K", "LONG_500K", "base"]
