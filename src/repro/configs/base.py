"""Model/parallelism/quantization configuration system.

Every assigned architecture is expressed as a ``ModelConfig``; reduced
("smoke") variants are derived with ``cfg.reduced()``. Layer heterogeneity
(Jamba's 1:7 Mamba:attention interleave, Gemma-3's 5:1 local:global) is
expressed as a *period*: a short per-layer pattern repeated depth/period
times, which lets the runtime scan over stacked period parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# Per-layer mixer kinds
FULL_ATTN = "full"
LOCAL_ATTN = "local"
MAMBA = "mamba"
RWKV = "rwkv"

DENSE_FFN = "dense"
MOE_FFN = "moe"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False              # Qwen2-VL multimodal RoPE (3 position streams)
    sliding_window: int = 4096       # window for LOCAL_ATTN layers

    # layer pattern: tuple of (mixer, ffn) kinds, one per layer of a period;
    # repeated num_layers/len(pattern) times. Default: all full-attn dense.
    mixer_pattern: Tuple[str, ...] = (FULL_ATTN,)
    ffn_pattern: Tuple[str, ...] = (DENSE_FFN,)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # dropless inference dispatch: per-group expert capacity = S*K, so
    # every routed (token, expert) assignment gets a slot and no token is
    # ever dropped — prefill numerics become independent of batch shape,
    # which re-enables prefix-cache sharing on MoE configs. False restores
    # the fixed capacity_factor dispatch (training-style, may drop).
    moe_dropless: bool = True

    # SSM (Mamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # RWKV6
    rwkv_head_dim: int = 64

    # frontend: text | vision_stub | audio_stub — stubs consume precomputed
    # patch/frame embeddings (paper assignment: backbone only)
    frontend: str = "text"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # long-context eligibility (sub-quadratic mixers); pure full-attention
    # archs skip the long_500k shape (see DESIGN.md §4)
    subquadratic: bool = False

    # ---------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        period = len(self.mixer_pattern)
        assert self.num_layers % period == 0, (self.name, self.num_layers, period)
        assert len(self.ffn_pattern) in (1, period)
        if len(self.ffn_pattern) == 1 and period > 1:
            object.__setattr__(self, "ffn_pattern", self.ffn_pattern * period)

    # ---------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.mixer_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def layer_kinds(self) -> Sequence[Tuple[str, str]]:
        return [(m, f) for m, f in zip(self.mixer_pattern, self.ffn_pattern)] * self.num_periods

    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, h = self.d_model, self.head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for mixer, ffn in self.layer_kinds:
            if mixer in (FULL_ATTN, LOCAL_ATTN):
                n += d * h * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * h * d
            elif mixer == MAMBA:
                d_in = self.mamba_expand * d
                n += d * 2 * d_in + d_in * self.mamba_d_conv
                n += d_in * (self.mamba_d_state * 2 + 1) + d_in * d  # proj + out
            elif mixer == RWKV:
                n += 5 * d * d + d * d  # r,k,v,g,w projections + out
            if ffn == MOE_FFN:
                n += self.num_experts * 3 * d * self.expert_ff()
            else:
                n += 3 * d * self.d_ff
            n += 2 * d  # norms
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        n = self.param_count()
        for mixer, ffn in self.layer_kinds:
            if ffn == MOE_FFN:
                n -= (self.num_experts - self.experts_per_token) * 3 * self.d_model * self.expert_ff()
        return n

    # ---------------------------------------------------------------
    def reduced(self, layers: Optional[int] = None) -> "ModelConfig":
        """Smoke-test-size variant of the same family (CPU-friendly)."""
        period = self.period
        num_layers = layers or max(period, 2 if period == 1 else period)
        if num_layers % period:
            num_layers = period
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            moe_d_ff=64 if self.num_experts else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            sliding_window=64,
            mamba_d_state=8,
            rwkv_head_dim=32,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How the serving path quantizes linears."""

    method: str = "none"             # none | rtn | smooth | quarot | atom | arc
    fmt: str = "nvfp4"
    act_fmt: str = ""                # "" -> same as fmt (W4A8 sets mxfp8)
    max_outlier_fraction: float = 0.25
    # NVFP4 activation FP32-scale granularity: "tensor" shares one scale
    # across the whole activation tensor (batch included — the eval
    # default), "token" computes it per token row, which makes serving
    # numerics independent of batch composition (continuous batching
    # requires a request's tokens not to change with its batch company),
    # "calibrated" uses the per-layer FP32 scales captured at calibration
    # time (paper App. D deployed config; batch-invariant and one-pass,
    # required by the fused Pallas quantization kernel)
    act_scale: str = "tensor"        # tensor | token | calibrated
    # kernel backend for deployed (QTensor-weight) linears: "reference"
    # emulates the unified GEMM by dequantizing into the bf16 datapath;
    # "pallas" runs arc_fused_quantize -> nvfp4_gemm over packed NVFP4
    # operands (``interpret=True`` runs the same kernels bit-faithfully on
    # CPU — the CI configuration)
    backend: str = "reference"       # reference | pallas
    interpret: bool = False
    # paged-attention decode: True streams K/V pages through the Pallas
    # kernel (block table walked in-kernel, no (B, nblocks*block_size)
    # gather); False keeps the jnp gather fallback — the parity oracle
    # and the A/B baseline for benchmarks/paged_attention.py
    attn_kernel: bool = True
    # fused GEMM epilogues on the pallas serving path: gate/up MLP pairs
    # sharing one quantization plan run a single dual-weight GEMM with
    # silu(g)*u computed on the VMEM accumulators (the (M, F) gate/up
    # intermediates never round-trip HBM, and the activations are
    # quantized once instead of twice), and linear biases add inside the
    # out-tile store. Bit-identical to the unfused path; False keeps the
    # separate launches — the A/B baseline for
    # benchmarks/deployed_serving.py
    fuse_epilogue: bool = True

    @property
    def activation_fmt(self) -> str:
        return self.act_fmt or self.fmt
