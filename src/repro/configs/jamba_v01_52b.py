"""Jamba-v0.1-52B [arXiv:2403.19887]: Mamba:attention 1:7 interleave
(attention at offset 4 of each 8-layer block), MoE every other layer
(16 experts, top-2). Sub-quadratic overall -> eligible for long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    mixer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "full", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe", "dense", "moe",
                 "dense", "moe", "dense", "moe"),
    num_experts=16, experts_per_token=2, moe_d_ff=14336,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    subquadratic=True,
)
