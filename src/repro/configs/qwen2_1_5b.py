"""Qwen2-1.5B [arXiv:2407.10671]: GQA with QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6,
)
