"""Transformer building blocks: norms, RoPE/M-RoPE, quantization-aware
linears, chunked (flash-style) attention, SwiGLU MLP, sort-based MoE.

All functions are pure. Quantization enters through ``dense()``: the
``LayerCtx`` carries the PTQ method, per-layer ARC plans (traced channel
orders + static S), and an optional calibration-capture dict. Weights are
either plain arrays (simulated quantization: quantize->dequantize->bf16
matmul, bit-exact math) or pre-quantized ``QTensor`` leaves (deployed
serving path, ARC-augmented offline per paper §3.2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arc as ARC
from repro.core import baselines as BL
from repro.core import quant as Q
from repro.configs.base import ModelConfig, QuantConfig
from repro.kernels import ops as KOPS
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import nvfp4_gemm, nvfp4_gemm_swiglu
from repro.parallel.sharding import maybe_shard


# ---------------------------------------------------------------------------
# Context threading quantization state through layer calls
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerCtx:
    cfg: ModelConfig
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    # traced per-period plan arrays: name -> {"order": (K,) i32, "smooth": (K,) f32}
    plan_arrays: Optional[Dict[str, Dict[str, jax.Array]]] = None
    # static plan metadata: name -> S (int)
    plan_meta: Optional[Dict[str, int]] = None
    # calibration capture: mutated dict name -> (K,) absmax
    capture: Optional[Dict[str, jax.Array]] = None
    # deployed fused-norm serving: name -> RMSNorm gamma for linears whose
    # input arrives *pre-norm* (the norm is folded into the quantization
    # pass — in-kernel for backend="pallas", in f32 jnp for "reference")
    fused_gamma: Optional[Dict[str, jax.Array]] = None
    # fused swiglu epilogue: gate-linear name -> up-linear name for pairs
    # sharing one quantization plan (see PlanBundle.fused) — eligible for
    # the dual-weight nvfp4_gemm_swiglu launch on the pallas path
    fused_pairs: Optional[Dict[str, str]] = None

    def plan_for(self, name: str):
        if self.plan_arrays is None or name not in self.plan_arrays:
            return None, 0
        s = self.plan_meta.get(name, 0) if self.plan_meta else 0
        return self.plan_arrays[name], s


def _einsum_mm(x: jax.Array, w: jax.Array) -> jax.Array:
    """y[..., m] = sum_k x[..., k] w[m, k] with f32 accumulation."""
    return jnp.einsum("...k,mk->...m", x.astype(jnp.bfloat16),
                      w.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


def dense(ctx: LayerCtx, name: str, x: jax.Array, w: Any,
          b: Optional[jax.Array] = None, quantize: bool = True) -> jax.Array:
    """Quantization-aware linear. ``w`` is (out, in) or a QTensor."""
    in_dtype = x.dtype
    if ctx.capture is not None and quantize:
        flat = jnp.abs(x.reshape(-1, x.shape[-1]))
        stat = jnp.max(flat, axis=0)
        prev = ctx.capture.get(name)
        ctx.capture[name] = stat if prev is None else jnp.maximum(prev, stat)

    method = ctx.quant.method if quantize else "none"

    # fused bias epilogue: on the deployed pallas path the bias adds onto
    # the f32 accumulator inside the GEMM's out-tile store instead of as a
    # follow-up XLA op (bit-identical: same f32 add, one fewer (M, N)
    # round trip)
    fuse_bias = (b is not None and isinstance(w, Q.QTensor)
                 and method == "arc" and ctx.quant.backend == "pallas"
                 and ctx.quant.fuse_epilogue)

    if isinstance(w, Q.QTensor):
        y = _deployed_matmul(ctx, name, x, w, method,
                             bias=b if fuse_bias else None)
    else:
        y = _simulated_matmul(ctx, name, x, w, method)
    if b is not None and not fuse_bias:
        y = y + b
    return y.astype(in_dtype)


def _simulated_matmul(ctx: LayerCtx, name: str, x, w, method: str):
    q = ctx.quant
    if method == "none":
        return _einsum_mm(x, w)
    if method == "rtn":
        xf = x.astype(jnp.float32)
        xq = Q.quantize_dequantize(xf, q.activation_fmt, _act_amax(xf, q))
        wq = Q.quantize_dequantize(w.astype(jnp.float32), q.fmt)
        return _einsum_mm(xq, wq)
    if method == "smooth":
        arrs, _ = ctx.plan_for(name)
        s = arrs["smooth"] if arrs and "smooth" in arrs else jnp.ones(x.shape[-1])
        xq = Q.quantize_dequantize(x.astype(jnp.float32) / s, q.activation_fmt)
        wq = Q.quantize_dequantize(w.astype(jnp.float32) * s, q.fmt)
        return _einsum_mm(xq, wq)
    if method == "quarot":
        h = jnp.asarray(BL.hadamard_matrix(x.shape[-1]))
        xh = jnp.matmul(x.astype(jnp.float32), h)
        wh = jnp.matmul(w.astype(jnp.float32), h)
        xq = Q.quantize_dequantize(xh, q.activation_fmt)
        wq = Q.quantize_dequantize(wh, q.fmt)
        return _einsum_mm(xq, wq)
    if method == "atom":
        arrs, s = ctx.plan_for(name)
        order = arrs["order"] if arrs else jnp.arange(x.shape[-1])
        s = s or 128
        plan = BL.AtomPlan(order=order, s=s, lo_fmt=q.fmt, hi_fmt="mxfp8")
        return BL.atom_matmul(x.astype(jnp.float32), w.astype(jnp.float32), plan)
    if method == "arc":
        arrs, s = ctx.plan_for(name)
        if arrs is None:
            return _simulated_matmul(ctx, name, x, w, "rtn")
        return _arc_sim_matmul(x.astype(jnp.float32), w.astype(jnp.float32),
                               arrs["order"], s, q)
    raise ValueError(method)


def _act_amax(x: jax.Array, q: QuantConfig):
    """Tensor-scale granularity for online activation quantization.

    Returns the per-token absmax (``act_scale="token"``, batch-invariant
    serving numerics) or None to let ``Q.quantize`` reduce over the whole
    tensor (``act_scale="tensor"``, the calibration/eval default). Only
    NVFP4's e4m3+tensor scaling consumes it; other formats ignore it.
    ``act_scale="calibrated"`` normally never reaches this helper (the ARC
    deployed path consumes the plan's static scales directly); linears
    without calibrated scales fall back to the batch-invariant per-token
    granularity.
    """
    if q.act_scale in ("token", "calibrated"):
        return jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return None


def _arc_sim_matmul(x, w, order, s: int, q: QuantConfig):
    """ARC with a traced channel order (scan-friendly) — simulated GEMM.

    Augmented operands are laid out in the canonical interleaved channel
    order (Appendix D) — the same layout the offline weights and the Pallas
    pipeline use — so the simulated and deployed paths reduce over K+S in
    an identical column order (bit-equal accumulation).
    """
    fmt = q.fmt
    k = x.shape[-1]
    xr = jnp.take(x, order, axis=-1)
    wr = jnp.take(w, order, axis=-1)
    xq = Q.quantize(xr, fmt, _act_amax(xr, q))
    wq = Q.quantize(wr, fmt)
    if s == 0:
        return Q.qmatmul(xq, wq)
    g = xq.fmt.block_size
    r_o = xr[..., :s] - xq.dequantize()[..., :s]
    rq = Q.quantize(r_o, fmt, _act_amax(r_o, q))
    x_aug = ARC.to_interleaved(Q.concat_k(xq, rq), k, s)
    w_o = Q.QTensor(wq.elements[..., :s], wq.scales[..., : s // g],
                    wq.fmt_name, s, wq.tensor_scale)
    w_aug = ARC.to_interleaved(Q.concat_k(wq, w_o), k, s)
    return Q.qmatmul(x_aug, w_aug)


def _deployed_matmul(ctx: LayerCtx, name: str, x, w: Q.QTensor, method: str,
                     bias=None):
    """Weights are pre-quantized offline (QTensor); activations online.

    The ARC path routes through the selected kernel backend: "reference"
    emulates the unified GEMM with QTensor ops in the bf16 datapath;
    "pallas" launches ``arc_fused_quantize`` + ``nvfp4_gemm`` over the
    packed interleaved weights. Both consume the same canonical
    interleaved weight layout and (with ``act_scale="calibrated"``) the
    same calibration-time tensor scales, so they compute the same math.
    """
    q = ctx.quant
    xf = x.astype(jnp.float32)
    if method in ("none", "rtn"):
        xq = Q.quantize(xf, q.activation_fmt, _act_amax(xf, q))
        return Q.qmatmul(xq, w)
    if method == "arc":
        arrs, s = ctx.plan_for(name)
        gamma = (ctx.fused_gamma or {}).get(name)
        ts = None
        if q.act_scale == "calibrated" and arrs and "act_scales" in arrs:
            ts = arrs["act_scales"]                       # (2,) f32 traced
        if q.backend == "pallas":
            if q.activation_fmt != "nvfp4" or w.fmt_name != "nvfp4":
                raise ValueError(
                    "backend='pallas' supports nvfp4 operands only, got "
                    f"activation_fmt={q.activation_fmt!r} / "
                    f"weight fmt={w.fmt_name!r}")
            if ts is None:
                raise ValueError(
                    "backend='pallas' needs calibrated activation scales: "
                    "set QuantConfig.act_scale='calibrated' and build plans "
                    "with make_plan_bundle (act_scales entry)")
            return _arc_pallas_matmul(ctx, xf, w, arrs["order"], s, ts, gamma,
                                      bias=bias)
        return _arc_reference_matmul(ctx, xf, w, arrs["order"], s, ts, gamma)
    raise ValueError(f"deployed path supports rtn/arc, got {method}")


def _rmsnorm_f32(xf: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    """RMSNorm kept in f32 — the fused-kernel numerics (no bf16 round-trip)."""
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)


def _arc_reference_matmul(ctx: LayerCtx, xf, w: Q.QTensor, order, s: int,
                          ts, gamma):
    """Emulated unified GEMM over the interleaved augmented operands."""
    q = ctx.quant
    fmt = q.activation_fmt
    if gamma is not None:
        xf = _rmsnorm_f32(xf, gamma, ctx.cfg.norm_eps)
    k = xf.shape[-1]
    xr = jnp.take(xf, order, axis=-1)
    if ts is not None:
        xq = Q.quantize(xr, fmt, tensor_scale=ts[0])
    else:
        xq = Q.quantize(xr, fmt, _act_amax(xr, q))
    if s:
        r_o = xr[..., :s] - xq.dequantize()[..., :s]
        if ts is not None:
            rq = Q.quantize(r_o, fmt, tensor_scale=ts[1])
        else:
            rq = Q.quantize(r_o, fmt, _act_amax(r_o, q))
        xq = ARC.to_interleaved(Q.concat_k(xq, rq), k, s)
    return Q.qmatmul(xq, w)


def _arc_pallas_matmul(ctx: LayerCtx, xf, w: Q.QTensor, order, s: int,
                       ts, gamma, bias=None):
    """Fused Pallas pipeline: one quant launch over every row (all serving
    slots batched together), one unified NVFP4 GEMM over packed weights.
    ``bias`` (N,) rides into the GEMM's fused epilogue (f32 add on the
    accumulator at the out-tile store)."""
    q = ctx.quant
    lead, k = xf.shape[:-1], xf.shape[-1]
    x2 = xf.reshape(-1, k)
    if gamma is None:
        gamma_arr = jnp.ones((k,), jnp.float32)
        apply_norm = False
    else:
        gamma_arr = gamma
        apply_norm = True
    x_codes, x_scales = arc_fused_quantize(
        x2, gamma_arr, order, ts, s, eps=ctx.cfg.norm_eps,
        apply_norm=apply_norm, interpret=q.interpret)
    w_codes, w_scales, w_t, w_packed = KOPS.qtensor_gemm_operands(w)
    y = nvfp4_gemm(x_codes, x_scales, w_codes, w_scales,
                   w_tensor_scale=w_t, w_packed=w_packed,
                   interpret=q.interpret, bias=bias)
    return y.reshape(*lead, y.shape[-1])


def _can_fuse_swiglu(ctx: LayerCtx, gname: str, uname: str, wg, wu) -> bool:
    """True when a gate/up pair may run the fused swiglu GEMM epilogue.

    Requires the deployed pallas path (QTensor weights, arc method,
    calibrated activation scales) and a plan-time guarantee that both
    linears share one quantization plan — ``fused_pairs`` is only
    populated for pairs whose order/S/act_scales match exactly, so the
    single ``arc_fused_quantize`` launch feeds both weights the operands
    each would have quantized for itself (bit-identical to unfused)."""
    q = ctx.quant
    if not (q.method == "arc" and q.backend == "pallas" and q.fuse_epilogue):
        return False
    if ctx.capture is not None:          # calibration captures per-linear
        return False
    if not (isinstance(wg, Q.QTensor) and isinstance(wu, Q.QTensor)):
        return False
    if wg.packed != wu.packed:
        return False
    if (ctx.fused_pairs or {}).get(gname) != uname:
        return False
    arrs, _ = ctx.plan_for(gname)
    return bool(q.act_scale == "calibrated" and arrs
                and "act_scales" in arrs)


def _swiglu_pallas(ctx: LayerCtx, gname: str, x: jax.Array,
                   wg: Q.QTensor, wu: Q.QTensor) -> jax.Array:
    """Fused gate/up MLP on the pallas path.

    ONE quantization launch (gate's plan — the pair is guaranteed
    plan-identical by ``_can_fuse_swiglu``) and ONE dual-weight GEMM whose
    epilogue computes ``silu(g) * u`` on the VMEM accumulators, so the
    activations are read and quantized once and the (M, F) gate/up
    intermediates never round-trip HBM."""
    q = ctx.quant
    arrs, s = ctx.plan_for(gname)
    gamma = (ctx.fused_gamma or {}).get(gname)
    ts = arrs["act_scales"]
    xf = x.astype(jnp.float32)
    lead, k = xf.shape[:-1], xf.shape[-1]
    x2 = xf.reshape(-1, k)
    if gamma is None:
        gamma_arr = jnp.ones((k,), jnp.float32)
        apply_norm = False
    else:
        gamma_arr = gamma
        apply_norm = True
    x_codes, x_scales = arc_fused_quantize(
        x2, gamma_arr, arrs["order"], ts, s, eps=ctx.cfg.norm_eps,
        apply_norm=apply_norm, interpret=q.interpret)
    g_codes, g_scales, g_t, g_packed = KOPS.qtensor_gemm_operands(wg)
    u_codes, u_scales, u_t, _ = KOPS.qtensor_gemm_operands(wu)
    # out_dtype = the activation dtype: the in-kernel epilogue rounds the
    # f32 accumulators exactly like dense() does, computes silu in f32
    # (the canonical _swiglu definition) and rounds the product once
    h = nvfp4_gemm_swiglu(x_codes, x_scales, g_codes, g_scales,
                          u_codes, u_scales,
                          g_tensor_scale=g_t, u_tensor_scale=u_t,
                          w_packed=g_packed, out_dtype=x.dtype,
                          interpret=q.interpret)
    return h.reshape(*lead, h.shape[-1])


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) / half * np.log(theta))
    return positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)


def mrope_sections(head_dim: int):
    """Temporal/height/width split of the rotary half-dim (Qwen2-VL)."""
    half = head_dim // 2
    hw = (3 * half) // 8
    return (half - 2 * hw, hw, hw)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope: bool = False) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (B, S, 3) for M-RoPE."""
    d = x.shape[-1]
    if mrope:
        if positions.ndim == 2:     # text-only: all three streams identical
            positions = jnp.broadcast_to(positions[..., None], (*positions.shape, 3))
        secs = mrope_sections(d)
        angs = []
        ang_full = _rope_angles(positions[..., 0], d, theta)  # reuse freq table
        half = d // 2
        freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) / half * np.log(theta))
        start = 0
        for i, sec in enumerate(secs):
            p = positions[..., i].astype(jnp.float32)
            angs.append(p[..., None] * freqs[start:start + sec])
            start += sec
        ang = jnp.concatenate(angs, axis=-1)
    else:
        ang = _rope_angles(positions, d, theta)
    cos = jnp.cos(ang)[..., None, :]   # (B, S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention — never materializes (S, S); custom_vjp backward
# recomputes the probabilities blockwise (O(S) residuals per layer).
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _pad_to(x, axis, mult, value=0):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)


def _flash_fwd(q, k, v, q_pos, kv_pos, window, qc, kc):
    """Returns (out, lse) over padded shapes.

    q: (B, Sq, Hkv, rep, D) f32; k, v: (B, Skv, Hkv, D) f32.
    """
    B, Sq, Hkv, rep, D = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / np.sqrt(D)

    qb = q.reshape(B, nq, qc, Hkv, rep, D).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(B, nq, qc).transpose(1, 0, 2)
    kb = k.reshape(B, nk, kc, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kc, Hkv, D).transpose(1, 0, 2, 3, 4)
    kpb = kv_pos.reshape(B, nk, kc).transpose(1, 0, 2)

    def one_q_block(args):
        q_i, qp_i = args                      # (B, qc, Hkv, rep, D), (B, qc)

        def kv_step(carry, xs):
            m, l, acc = carry
            k_j, v_j, kp_j = xs               # (B, kc, Hkv, D), (B, kc)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", q_i, k_j) * scale
            # anti-hoist: tie the integer mask lineage to the data stream so
            # partial-eval cannot lift an all-block-pairs mask stack out of
            # the scans as saved residuals (runtime value is always 0).
            zero = (k_j[0, 0, 0, 0] * 0).astype(jnp.int32)
            kp_d = kp_j + zero
            mask = (kp_d[:, None, :] <= qp_i[:, :, None]) & (kp_d[:, None, :] >= 0)
            if window is not None:
                mask &= (qp_i[:, :, None] - kp_d[:, None, :]) < window
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhrqk,bkhd->bhrqd", p, v_j)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, rep, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, rep, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kpb))
        out = jnp.where(l[..., None] > 0,
                        acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))          # (B, Hkv, rep, qc)
        return out.transpose(0, 3, 1, 2, 4), lse

    outs, lses = jax.lax.map(one_q_block, (qb, qpb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, rep, D)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, rep, Sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash_attention(q, k, v, q_pos, kv_pos, window, qc, kc):
    out, _ = _flash_fwd(q, k, v, q_pos, kv_pos, window, qc, kc)
    return out


def _flash_attention_fwd(q, k, v, q_pos, kv_pos, window, qc, kc):
    out, lse = _flash_fwd(q, k, v, q_pos, kv_pos, window, qc, kc)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_attention_bwd(window, qc, kc, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, Sq, Hkv, rep, D = q.shape
    Skv = k.shape[1]
    nq = Sq // qc
    scale = 1.0 / np.sqrt(D)

    # delta_i = rowsum(dout * out)
    delta = jnp.einsum("bqhrd,bqhrd->bhrq", dout, out)      # (B,Hkv,rep,Sq)

    qb = q.reshape(B, nq, qc, Hkv, rep, D).transpose(1, 0, 2, 3, 4, 5)
    dob = dout.reshape(B, nq, qc, Hkv, rep, D).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(B, nq, qc).transpose(1, 0, 2)
    lseb = lse.reshape(B, Hkv, rep, nq, qc).transpose(3, 0, 1, 2, 4)
    deltab = delta.reshape(B, Hkv, rep, nq, qc).transpose(3, 0, 1, 2, 4)

    def q_block_step(carry, xs):
        dk, dv = carry
        q_i, do_i, qp_i, lse_i, dl_i = xs
        # full kv for this q block, chunked over kv inside for memory
        nk = Skv // kc

        def kv_step(carry2, xs2):
            dq_i, dk_acc, dv_acc, j = carry2
            del xs2
            k_j = jax.lax.dynamic_slice_in_dim(k, j * kc, kc, 1)
            v_j = jax.lax.dynamic_slice_in_dim(v, j * kc, kc, 1)
            kp_j = jax.lax.dynamic_slice_in_dim(kv_pos, j * kc, kc, 1)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", q_i, k_j) * scale
            # anti-hoist (see forward): masks must stay in the cotangent pass
            zero = (do_i[0, 0, 0, 0, 0] * 0).astype(jnp.int32)
            kp_d = kp_j + zero
            mask = (kp_d[:, None, :] <= qp_i[:, :, None]) & (kp_d[:, None, :] >= 0)
            if window is not None:
                mask &= (qp_i[:, :, None] - kp_d[:, None, :]) < window
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])               # (B,h,r,qc,kc)
            dv_j = jnp.einsum("bhrqk,bqhrd->bkhd", p, do_i)
            dp = jnp.einsum("bqhrd,bkhd->bhrqk", do_i, v_j)
            ds = p * (dp - dl_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhrqk,bkhd->bqhrd", ds, k_j)
            dk_j = jnp.einsum("bhrqk,bqhrd->bkhd", ds, q_i)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, j * kc, kc, 1) + dk_j,
                j * kc, 1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, j * kc, kc, 1) + dv_j,
                j * kc, 1)
            return (dq_i, dk_acc, dv_acc, j + 1), None

        dq0 = jnp.zeros_like(q_i)
        (dq_i, dk, dv, _), _ = jax.lax.scan(
            kv_step, (dq0, dk, dv, jnp.zeros((), jnp.int32)), None, length=nk)
        return (dk, dv), dq_i

    dk0 = jnp.zeros_like(k)
    dv0 = jnp.zeros_like(v)
    (dk, dv), dqb = jax.lax.scan(q_block_step, (dk0, dv0),
                                 (qb, dob, qpb, lseb, deltab))
    dq = dqb.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, rep, D)
    zero_pos = np.zeros(q_pos.shape, jax.dtypes.float0)
    zero_kpos = np.zeros(kv_pos.shape, jax.dtypes.float0)
    return dq, dk, dv, zero_pos, zero_kpos


_flash_attention.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, kv_pos: jax.Array,
                      window: Optional[int] = None,
                      q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    """Online-softmax attention (flash-style, differentiable).

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    q_pos: (B, Sq) and kv_pos: (B, Skv) absolute positions; kv_pos < 0
    marks invalid (unwritten cache) entries. Causal: kv_pos <= q_pos;
    sliding window additionally requires q_pos - kv_pos < window.
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    in_dtype = q.dtype

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    qf = _pad_to(q.astype(jnp.float32).reshape(B, Sq, Hkv, rep, D), 1, qc)
    qp = _pad_to(q_pos, 1, qc, value=-(2 ** 30))   # padded queries match nothing
    kf = _pad_to(k.astype(jnp.float32), 1, kc)
    vf = _pad_to(v.astype(jnp.float32), 1, kc)
    kp = _pad_to(kv_pos, 1, kc, value=-1)

    out = _flash_attention(qf, kf, vf, qp, kp, window, qc, kc)
    return out[:, :Sq].reshape(B, Sq, Hq, D).astype(in_dtype)


# ---------------------------------------------------------------------------
# Attention layer (GQA, optional qk-norm / bias / sliding window, KV cache)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (hq * hd, d), dtype) * std,
        "wk": jax.random.normal(k2, (hkv * hd, d), dtype) * std,
        "wv": jax.random.normal(k3, (hkv * hd, d), dtype) * std,
        "wo": jax.random.normal(k4, (d, hq * hd), dtype) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_layer(ctx: LayerCtx, name: str, params: Dict, x: jax.Array,
                    positions: jax.Array, cache: Optional[Dict] = None,
                    window: Optional[int] = None,
                    block_table: Optional[jax.Array] = None,
                    active_rows: Optional[jax.Array] = None):
    """x: (B, S, d); positions (B, S) or (B, S, 3) for M-RoPE.

    Returns (out, new_cache). With a slot cache ({"k","v","pos"}), k/v are
    written at ``positions % cache_len`` (ring buffer for windowed
    layers). With a paged cache ({"kp","vp","posp"} page pool +
    ``block_table`` (B, max_blocks)), the decode token scatters into the
    tail page named by the table and attention runs over the pool
    directly — the Pallas paged-attention kernel when
    ``ctx.quant.attn_kernel`` is set (block table walked in-kernel, no
    gathered K/V view), otherwise a page-wise jnp gather (the parity
    oracle); rows with position < 0 are inert (write dropped, mask
    empty), and ``active_rows`` (traced scalar) additionally zeroes
    packed-batch padding rows past the active-request count without
    retracing per count.
    """
    cfg = ctx.cfg
    B, S, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = dense(ctx, f"{name}.wq", x, params["wq"], params.get("bq"))
    k = dense(ctx, f"{name}.wk", x, params["wk"], params.get("bk"))
    v = dense(ctx, f"{name}.wv", x, params["wv"], params.get("bv"))
    q = q.reshape(B, S, hq, hd)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    q = maybe_shard(q, "batch", None, "heads", None)
    k = maybe_shard(k, "batch", None, "heads", None)

    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)

    pos1d = positions[..., 0] if positions.ndim == 3 else positions
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)

    if cache is None:
        k_all, v_all, kv_pos = k, v, pos1d
        new_cache = None
    elif "kp" in cache:
        # paged decode: the cache is a page pool shared across requests.
        # Prefill populates pages through write_cache_pages (a contiguous
        # batch-1 cache scattered at admission), so this path only ever
        # sees single-token decode steps.
        assert S == 1, "paged attention cache is decode-only"
        num_pages, bs = cache["posp"].shape
        nblocks = block_table.shape[1]
        p = pos1d[:, 0]                                  # (B,) absolute pos
        blk = p // bs
        page = jnp.take_along_axis(
            block_table, jnp.clip(blk, 0, nblocks - 1)[:, None], axis=1)[:, 0]
        # rows with p < 0 (inactive slots / ragged padding) and positions
        # past the table's capacity (blk >= nblocks: the scheduler failed
        # to grow the table) route the write out of bounds so the scatter
        # drops it — an overflowing token must never overwrite the last
        # allocated block's K/V
        page = jnp.where((p >= 0) & (blk < nblocks), page, num_pages)
        off = jnp.clip(p, 0, None) % bs
        ck = cache["kp"].at[page, off].set(
            k[:, 0].astype(cache["kp"].dtype), mode="drop")
        cv = cache["vp"].at[page, off].set(
            v[:, 0].astype(cache["vp"].dtype), mode="drop")
        cp = cache["posp"].at[page, off].set(p, mode="drop")
        new_cache = {"kp": ck, "vp": cv, "posp": cp}
        if ctx.quant.attn_kernel:
            # stream pages through the Pallas kernel: the block table is
            # a scalar-prefetch operand, so no (B, nblocks*bs) K/V view
            # is ever materialized
            out = KOPS.paged_attention(
                q[:, 0], ck, cv, cp, block_table, p, active_rows,
                window=window,
                interpret=True if ctx.quant.interpret else None)
            out = out[:, None].reshape(B, S, hq * hd)
        else:
            # gather fallback (parity oracle): unallocated table entries
            # point at the null page whose positions are -1 (masked out)
            k_all = ck[block_table].reshape(B, nblocks * bs, hkv, hd)
            v_all = cv[block_table].reshape(B, nblocks * bs, hkv, hd)
            kv_pos = cp[block_table].reshape(B, nblocks * bs)
            out = chunked_attention(q, k_all.astype(q.dtype),
                                    v_all.astype(q.dtype), pos1d, kv_pos,
                                    window=window, q_chunk=1)
            out = out.reshape(B, S, hq * hd)
        y = dense(ctx, f"{name}.wo", out, params["wo"])
        return maybe_shard(y, "batch", None, None), new_cache
    else:
        L = cache["k"].shape[1]
        # per-row scatter: continuous batching decodes slots at different
        # absolute positions, so each batch row writes its own ring index
        idx = pos1d % L                          # (B, S)
        rows = jnp.arange(B)[:, None]
        ck = cache["k"].at[rows, idx].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[rows, idx].set(v.astype(cache["v"].dtype))
        cp = cache["pos"].at[rows, idx].set(pos1d)
        new_cache = {"k": ck, "v": cv, "pos": cp}
        k_all, v_all, kv_pos = ck, cv, cp

    qc = 512 if S > 1 else 1
    out = chunked_attention(q, k_all.astype(q.dtype), v_all.astype(q.dtype),
                            pos1d, kv_pos, window=window, q_chunk=qc)
    out = out.reshape(B, S, hq * hd)
    y = dense(ctx, f"{name}.wo", out, params["wo"])
    return maybe_shard(y, "batch", None, None), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                         window: Optional[int], dtype=jnp.bfloat16) -> Dict:
    L = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),
    }


def init_attention_page_pool(cfg: ModelConfig, num_pages: int,
                             block_size: int, dtype=jnp.bfloat16) -> Dict:
    """Paged K/V pool: fixed-size pages shared by all requests.

    Page 0 is the null page — never allocated, so its positions stay -1
    and unallocated block-table entries gather nothing but masked slots.
    """
    return {
        "kp": jnp.zeros((num_pages, block_size, cfg.num_kv_heads,
                         cfg.head_dim), dtype),
        "vp": jnp.zeros((num_pages, block_size, cfg.num_kv_heads,
                         cfg.head_dim), dtype),
        "posp": jnp.full((num_pages, block_size), -1, jnp.int32),
    }


def pages_to_rows(leaf: jax.Array, table: jax.Array) -> jax.Array:
    """Materialize one block table's logical view of a page-pool leaf.

    ``leaf`` is a period-stacked pool array ``(P, num_pages, block_size,
    ...)``; ``table`` is ``(nblocks,)`` physical page ids (entries naming
    the null page 0 contribute its permanently-invalid rows). Returns the
    contiguous ``(P, nblocks * block_size, ...)`` row view — the gather
    the prefix cache uses to seed a batch-1 prefill cache from shared
    pages, and the same indexing the paged attention read performs
    per-request. ``table`` may be traced (one jitted gather serves every
    fork)."""
    num_periods, _, bs = leaf.shape[:3]
    nblocks = table.shape[0]
    return leaf[:, table].reshape(num_periods, nblocks * bs,
                                  *leaf.shape[3:])


def copy_page(leaf: jax.Array, src_page, dst_page) -> jax.Array:
    """Copy one physical page of a period-stacked pool leaf.

    The copy-on-write primitive: a request about to write into a page it
    shares duplicates the page first, then redirects its block-table
    entry to the private copy. ``src_page``/``dst_page`` are traced
    scalars, so one jitted copy serves every COW."""
    return leaf.at[:, dst_page].set(leaf[:, src_page])


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (f, d), dtype) * d ** -0.5,
        "w_up": jax.random.normal(k2, (f, d), dtype) * d ** -0.5,
        "w_down": jax.random.normal(k3, (d, f), dtype) * f ** -0.5,
    }


def _swiglu(g: jax.Array, u: jax.Array) -> jax.Array:
    """The canonical swiglu epilogue: silu computed in f32 on the (already
    rounded) GEMM outputs, product rounded once to the activation dtype.

    Spelled out explicitly — rather than ``silu(g) * u`` in bf16 — so the
    numerics are the same whether XLA compiles it (bf16 ops get per-op
    f32-compute-then-round legalization, and the final round can fold
    into an f32 consumer) or the Pallas swiglu kernel computes it on its
    VMEM accumulators: one definition, one rounding point, bit-stable
    across eager/jit/fused."""
    h = jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
    return h.astype(g.dtype)


def mlp_layer(ctx: LayerCtx, name: str, params: Dict, x: jax.Array) -> jax.Array:
    gname, uname = f"{name}.w_gate", f"{name}.w_up"
    wg, wu = params["w_gate"], params["w_up"]
    if _can_fuse_swiglu(ctx, gname, uname, wg, wu):
        h = _swiglu_pallas(ctx, gname, x, wg, wu)
    else:
        g = dense(ctx, gname, x, wg)
        u = dense(ctx, uname, x, wu)
        h = _swiglu(g, u)
    h = maybe_shard(h, "batch", None, "ff")
    y = dense(ctx, f"{name}.w_down", h, params["w_down"])
    return maybe_shard(y, "batch", None, None)


# ---------------------------------------------------------------------------
# Mixture of Experts — sort-based dispatch (no (S, E, C) one-hot)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, f, e = cfg.d_model, cfg.expert_ff(), cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(k1, (e, d), dtype) * d ** -0.5,
        "experts_gate": jax.random.normal(k2, (e, f, d), dtype) * d ** -0.5,
        "experts_up": jax.random.normal(k3, (e, f, d), dtype) * d ** -0.5,
        "experts_down": jax.random.normal(k4, (e, d, f), dtype) * f ** -0.5,
    }


def moe_layer(ctx: LayerCtx, name: str, params: Dict, x: jax.Array):
    """Returns (out, aux_loss).

    GShard-style *grouped* dispatch: each batch row is a dispatch group, so
    the argsort / capacity ranking / scatter are local to a data shard
    (vmapped over B). The dispatched tensor (B, E, cap, d) is sharded
    (data, model, ., .): building it needs no communication (tokens are
    replicated across the model axis), the expert FFN runs expert-parallel
    over the model axis, and only the combine gather crosses the model
    axis — GSPMD turns it into one activation-sized all-reduce per layer,
    the same wire cost as Megatron-style TP. (The previous global-token
    scatter/gather version made GSPMD materialize and all-reduce
    (T*K, d)-sized one-hot products — 13.7 TB/layer at the 235B scale.)
    """
    cfg = ctx.cfg
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token

    logits = dense(ctx, f"{name}.router", x, params["router"], quantize=False)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (B,S,E)
    gate, eidx = jax.lax.top_k(probs, K)                          # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(eidx[..., 0], E), axis=(0, 1))
    aux = jnp.sum(me * ce) * E * cfg.router_aux_loss

    if cfg.moe_dropless:
        # dropless dispatch: S*K slots per group hold every routed
        # (token, expert) assignment even if all tokens pick one expert —
        # rank < cap always, no token drops, and prefill numerics become
        # independent of the batch the token happens to share (which is
        # what re-enables prefix-cache sharing on MoE configs)
        cap = S * K
    else:
        cap = max(int(np.ceil(K * S / E * cfg.capacity_factor)), 1)

    def dispatch_group(xg, eg, gg):
        """xg: (S, d); eg/gg: (S, K) -> dispatched tokens + per-slot
        (destination token, gate) for the scatter-add combine."""
        e_flat = eg.reshape(-1)
        g_flat = gg.reshape(-1)
        tok_flat = jnp.repeat(jnp.arange(S), K)
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        tok_sorted = tok_flat[order]
        g_sorted = g_flat[order]
        counts = jnp.bincount(e_flat, length=E)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(S * K) - starts[e_sorted]
        slot_sorted = jnp.where(rank < cap, e_sorted * cap + rank, E * cap)
        gathered = jnp.zeros((E * cap, d), xg.dtype)
        gathered = gathered.at[slot_sorted].set(xg[tok_sorted], mode="drop")
        slot_tok = jnp.full((E * cap,), S, jnp.int32).at[slot_sorted].set(
            tok_sorted, mode="drop")
        slot_gate = jnp.zeros((E * cap,), jnp.float32).at[slot_sorted].set(
            g_sorted, mode="drop")
        return gathered.reshape(E, cap, d), slot_tok, slot_gate

    ge, slot_tok, slot_gate = jax.vmap(dispatch_group)(x, eidx, gate)
    ge = maybe_shard(ge, "batch", "experts", None, None)   # (B, E, cap, d)

    # expert FFN (E sharded over model axis — expert parallelism)
    wg, wu, wd = params["experts_gate"], params["experts_up"], params["experts_down"]
    if isinstance(wg, Q.QTensor) or ctx.quant.method != "none" or \
            ctx.capture is not None:
        # fold the group dim into capacity for the per-expert quantized path
        gb = ge.transpose(1, 0, 2, 3).reshape(E, B * cap, d)
        gname, uname = f"{name}.experts_gate", f"{name}.experts_up"
        if _can_fuse_swiglu(ctx, gname, uname, wg, wu):
            h = _expert_swiglu(ctx, gname, gb, wg, wu)
        else:
            h = _expert_dense(ctx, gname, gb, wg)
            u = _expert_dense(ctx, uname, gb, wu)
            h = _swiglu(h, u)
        h = maybe_shard(h, "experts", None, None)
        ye = _expert_dense(ctx, f"{name}.experts_down", h, wd)
        ye = ye.reshape(E, B, cap, d).transpose(1, 0, 2, 3)
    else:
        h = jax.nn.silu(jnp.einsum("becd,efd->becf", ge, wg)) * jnp.einsum(
            "becd,efd->becf", ge, wu)
        h = maybe_shard(h, "batch", "experts", None, None)
        ye = jnp.einsum("becf,edf->becd", h, wd)
    ye = maybe_shard(ye, "batch", "experts", None, None)
    ye = ye.reshape(B, E * cap, d)

    # combine: scatter-add from the expert-sharded slot dim into token
    # space — each model shard contributes partial sums from its local
    # experts and GSPMD emits ONE (S, d) all-reduce per layer (a K-wide
    # slot gather would move K x more wire).
    def combine_group(ye_g, tok_g, gate_g):
        contrib = (ye_g.astype(jnp.float32) * gate_g[:, None])
        y = jnp.zeros((S + 1, d), jnp.float32)      # row S = drop bucket
        y = y.at[tok_g].add(contrib)
        return y[:S]

    y = jax.vmap(combine_group)(ye, slot_tok, slot_gate)
    return y.astype(x.dtype), aux


def _expert_dense(ctx: LayerCtx, name: str, x: jax.Array, w: Any) -> jax.Array:
    """Per-expert linear via vmap over the expert dim (quantization-aware)."""
    if ctx.capture is not None:
        # capture stats on the flattened token stream (per-channel over all experts)
        flat = jnp.abs(x.reshape(-1, x.shape[-1]))
        stat = jnp.max(flat, axis=0)
        prev = ctx.capture.get(name)
        ctx.capture[name] = stat if prev is None else jnp.maximum(prev, stat)
        ctx = dataclasses.replace(ctx, capture=None)
    if isinstance(w, Q.QTensor):
        # map elements/scales (and the per-expert tensor scale) over experts
        ts_ax = 0 if (w.tensor_scale is not None and w.tensor_scale.ndim) else None
        w_axes = Q.QTensor(0, 0, w.fmt_name, w.valid_k, ts_ax, w.packed)
    else:
        w_axes = 0
    sub = ctx
    return jax.vmap(lambda xe, we: dense(sub, name, xe, we),
                    in_axes=(0, w_axes))(x, w)


def _expert_swiglu(ctx: LayerCtx, gname: str, x: jax.Array,
                   wg: Q.QTensor, wu: Q.QTensor) -> jax.Array:
    """Per-expert fused gate/up linear via vmap over the expert dim.

    The expert input is pre-normed by the caller (fused_gamma never names
    MoE linears), so each expert runs quantize-once + dual-weight swiglu
    GEMM exactly like the dense fused MLP."""
    def _axes(w: Q.QTensor):
        ts_ax = 0 if (w.tensor_scale is not None and w.tensor_scale.ndim) else None
        return Q.QTensor(0, 0, w.fmt_name, w.valid_k, ts_ax, w.packed)
    sub = ctx
    return jax.vmap(lambda xe, wge, wue: _swiglu_pallas(sub, gname, xe, wge, wue),
                    in_axes=(0, _axes(wg), _axes(wu)))(x, wg, wu)


# ---------------------------------------------------------------------------
# RWKV channel mix (used as the FFN for rwkv6)
# ---------------------------------------------------------------------------


def init_rwkv_cmix(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "cmix_k": jax.random.normal(k1, (f, d), dtype) * d ** -0.5,
        "cmix_v": jax.random.normal(k2, (d, f), dtype) * f ** -0.5,
        "cmix_r": jax.random.normal(k3, (d, d), dtype) * d ** -0.5,
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
    }


def rwkv_cmix_layer(ctx: LayerCtx, name: str, params: Dict, x: jax.Array,
                    shift_state: Optional[jax.Array] = None):
    """RWKV6 channel mix: k = relu(Wk lerp)^2, out = sigmoid(Wr lerp) * Wv k."""
    B, S, d = x.shape
    if shift_state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    new_shift = x[:, -1]
    xk = x + (prev - x) * params["mu_k"]
    xr = x + (prev - x) * params["mu_r"]
    k = jnp.square(jax.nn.relu(dense(ctx, f"{name}.cmix_k", xk, params["cmix_k"])))
    k = maybe_shard(k, "batch", None, "ff")
    v = dense(ctx, f"{name}.cmix_v", k, params["cmix_v"])
    r = jax.nn.sigmoid(dense(ctx, f"{name}.cmix_r", xr, params["cmix_r"]))
    return r * v, new_shift
