"""Generic decoder LM assembled from a ModelConfig.

Layers are stacked per *period* (the repeating heterogeneous pattern —
see configs/base.py) and executed with ``jax.lax.scan`` over periods so
that compile time and HLO size stay bounded for 28–94 layer models.

Params tree:
    embed:       (Vp, d)          vocab padded to a multiple of 512
    lm_head:     (Vp, d)          absent when tie_embeddings
    final_norm:  (d,)
    blocks:      [per-position dicts, each leaf stacked (num_periods, ...)]

Forward supports four modes:
  * train/eval:   tokens -> logits (no cache)
  * prefill:      tokens + cache -> logits, populated cache
  * decode:       1-token slice + cache -> next logits, updated cache
  * capture:      calibration pass, returns per-linear absmax stats
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (DENSE_FFN, FULL_ATTN, LOCAL_ATTN, MAMBA,
                                MOE_FFN, RWKV, ModelConfig, QuantConfig)
from repro.core import quant as Q
from repro.models import layers as L
from repro.models import ssm as S
from repro.parallel.sharding import maybe_shard

VOCAB_PAD = 512


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD


@dataclasses.dataclass
class PlanBundle:
    """Per-linear quantization plans, scan-ready.

    arrays: name -> {"order": (P, K) i32, "smooth": (P, K) f32} (traced)
    meta:   name -> S (static outlier count, shared across periods)
    fused:  gate-linear name -> up-linear name for pairs that share one
            quantization plan (same order/S/act_scales) and are therefore
            eligible for the fused swiglu GEMM epilogue (static strings)
    """

    arrays: Dict[str, Dict[str, jax.Array]]
    meta: Dict[str, int]
    fused: Dict[str, str] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block_position(key, cfg: ModelConfig, mixer: str, ffn: str, dtype):
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
    }
    if mixer in (FULL_ATTN, LOCAL_ATTN):
        p["attn"] = L.init_attention(k1, cfg, dtype)
    elif mixer == MAMBA:
        p["mamba"] = S.init_mamba(k1, cfg, dtype)
    elif mixer == RWKV:
        p["rwkv"] = S.init_rwkv_tmix(k1, cfg, dtype)
    else:
        raise ValueError(mixer)
    if ffn == MOE_FFN:
        p["moe"] = L.init_moe(k2, cfg, dtype)
    elif ffn == "rwkv_cmix":
        p["cmix"] = L.init_rwkv_cmix(k2, cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(k2, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Dict:
    vp = padded_vocab(cfg)
    keys = jax.random.split(key, cfg.period + 2)
    blocks = []
    for i, (mixer, ffn) in enumerate(zip(cfg.mixer_pattern, cfg.ffn_pattern)):
        ffn_kind = "rwkv_cmix" if cfg.family == "ssm" else ffn
        pos_keys = jax.random.split(keys[i], cfg.num_periods)
        stacked = jax.vmap(
            lambda k: _init_block_position(k, cfg, mixer, ffn_kind, dtype)
        )(pos_keys)
        blocks.append(stacked)
    params = {
        "embed": jax.random.normal(keys[-2], (vp, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[-1], (vp, cfg.d_model), dtype) * 0.02
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> list:
    """Per-position caches stacked over periods (scan xs)."""
    caches = []
    for mixer, _ in zip(cfg.mixer_pattern, cfg.ffn_pattern):
        if mixer == FULL_ATTN:
            c = L.init_attention_cache(cfg, batch, max_len, None, dtype)
        elif mixer == LOCAL_ATTN:
            c = L.init_attention_cache(cfg, batch, max_len, cfg.sliding_window, dtype)
        elif mixer == MAMBA:
            c = S.init_mamba_cache(cfg, batch, jnp.float32)
        elif mixer == RWKV:
            c = S.init_rwkv_cache(cfg, batch, jnp.float32)
        else:
            raise ValueError(mixer)
        if cfg.family == "ssm":
            c["cmix_shift"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_periods, *x.shape)), c)
        caches.append(stacked)
    return caches


# leaves of a paged attention cache dict that live in the shared page
# pool; any other leaf in the same dict (e.g. an ssm-family hybrid's
# cmix_shift riding a full-attention position) stays slot-resident
PAGE_KEYS = ("kp", "vp", "posp")


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                     block_size: int, max_len: int,
                     dtype=jnp.bfloat16) -> list:
    """Pool cache with *paged* full-attention K/V (scan xs).

    Full-attention layers store K/V in a shared pool of fixed-size pages
    ``(num_pages, block_size, heads, head_dim)`` plus per-page absolute
    positions ``posp`` (-1 = unwritten); requests map logical blocks to
    physical pages through a block table handed to ``forward`` at decode
    time. Physical page 0 is the permanent *null page* — never allocated,
    never written — so unallocated table entries gather positions of -1
    and fall out of the causal mask.

    State whose footprint does not grow with ``max_len`` stays
    slot-resident exactly as in :func:`init_cache`: sliding-window rings
    are already O(window) and SSM/RWKV recurrent state is O(1) per
    request, so paging them would add table indirection for zero memory
    reclaim. Only the O(max_len) full-attention tail is pooled.
    """
    assert max_len % block_size == 0, (max_len, block_size)
    caches = []
    for mixer, _ in zip(cfg.mixer_pattern, cfg.ffn_pattern):
        if mixer == FULL_ATTN:
            c = L.init_attention_page_pool(cfg, num_pages, block_size, dtype)
        elif mixer == LOCAL_ATTN:
            c = L.init_attention_cache(cfg, num_slots, max_len,
                                       cfg.sliding_window, dtype)
        elif mixer == MAMBA:
            c = S.init_mamba_cache(cfg, num_slots, jnp.float32)
        elif mixer == RWKV:
            c = S.init_rwkv_cache(cfg, num_slots, jnp.float32)
        else:
            raise ValueError(mixer)
        if cfg.family == "ssm":
            c["cmix_shift"] = jnp.zeros((num_slots, cfg.d_model), jnp.float32)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_periods, *x.shape)), c)
        caches.append(stacked)
    return caches


def write_cache_pages(cache: list, src: list, table, slot) -> list:
    """Install a prefilled batch-1 contiguous cache into a paged pool.

    ``src`` comes from prefilling one request into ``init_cache(cfg, 1,
    max_blocks * block_size)``; its attention rows are split into logical
    blocks and scattered to the physical pages named by ``table``
    (``(max_blocks,)`` int32; entries >= num_pages mark unallocated blocks
    and are dropped). Slot-resident leaves (rings, recurrent state) are
    row-overwritten at ``slot`` as in :func:`write_cache_slot`. Both
    ``table`` and ``slot`` may be traced, so one jitted write serves every
    admission.
    """
    out = []
    for c, s in zip(cache, src):
        if "kp" in c:
            num_periods, _, bs = c["posp"].shape
            nblocks = table.shape[0]
            nc = {}
            for name, sname in (("kp", "k"), ("vp", "v"), ("posp", "pos")):
                leaf = c[name]
                sv = s[sname][:, 0]                  # (P, nblocks*bs, ...)
                sv = sv.reshape(num_periods, nblocks, bs,
                                *sv.shape[2:]).astype(leaf.dtype)
                nc[name] = leaf.at[:, table].set(sv, mode="drop")
            for name, leaf in c.items():             # slot-resident riders
                if name not in PAGE_KEYS:
                    nc[name] = _write_slot_row(leaf, s[name], slot)
            out.append(nc)
        else:
            out.append({name: _write_slot_row(leaf, s[name], slot)
                        for name, leaf in c.items()})
    return out


def gather_cache_pages(dst: list, cache: list, table) -> list:
    """Seed a batch-1 contiguous cache from a paged pool's shared pages.

    The prefix-caching admission primitive: ``table`` is ``(max_blocks,)``
    int32 physical page ids covering the request's cached prefix (null
    page 0 beyond it). Each paged attention leaf is gathered block-wise
    into the matching contiguous rows of ``dst`` (a fresh
    ``init_cache(cfg, 1, max_blocks * block_size)``), so a suffix prefill
    resumed from the shared-prefix boundary attends over exactly the K/V
    a full prefill would have produced. Null-page rows carry position -1
    (never written), matching the fresh cache's unwritten rows.
    Slot-resident leaves (rings, recurrent state) pass through untouched —
    prefix skipping is only enabled on configs where every layer's state
    lives in pages. ``table`` may be traced: one jitted gather serves
    every admission.
    """
    out = []
    for d, c in zip(dst, cache):
        if "kp" in c:
            nd = dict(d)
            for name, sname in (("kp", "k"), ("vp", "v"), ("posp", "pos")):
                rows = L.pages_to_rows(c[name], table)   # (P, nb*bs, ...)
                nd[sname] = rows[:, None].astype(d[sname].dtype)
            out.append(nd)
        else:
            out.append(d)
    return out


def copy_cache_page(cache: list, src_page, dst_page) -> list:
    """Copy-on-write duplication of one physical page.

    Copies K/V and positions of ``src_page`` into ``dst_page`` across
    every paged layer; the caller then points the writing request's block
    table at ``dst_page`` so the shared original stays immutable. Both
    page ids may be traced scalars.
    """
    out = []
    for c in cache:
        if "kp" in c:
            nc = dict(c)
            for name in PAGE_KEYS:
                nc[name] = L.copy_page(c[name], src_page, dst_page)
            out.append(nc)
        else:
            out.append(c)
    return out


def invalidate_cache_pages(cache: list, pages) -> list:
    """Invalidate recycled pages' positions (pos -> -1).

    Applied when a content-cached page (ref count zero, retained for
    future prefix hits) is evicted for reuse: the stale positions must
    not be gathered as valid by the next tenant's block table. ``pages``
    is ``(n,)`` int32; entries >= num_pages are dropped. K/V bytes need no
    clearing — the causal mask hides pos < 0 rows and reallocation
    overwrites them.
    """
    out = []
    for c in cache:
        if "kp" in c:
            nc = dict(c)
            nc["posp"] = c["posp"].at[:, pages].set(-1, mode="drop")
            out.append(nc)
        else:
            out.append(c)
    return out


def release_cache_pages(cache: list, pages, slot) -> list:
    """Return a request's pages to the pool and clear its slot row.

    ``pages`` is ``(max_blocks,)`` int32 physical page ids (entries >=
    num_pages are dropped). Released pages only need their positions
    invalidated (pos -> -1): k/v bytes are masked out by the causal mask
    and fully overwritten when the page is reallocated. Slot-resident
    leaves reset exactly as :func:`reset_cache_slot`.
    """
    out = []
    for c in cache:
        if "kp" in c:
            nc = dict(c)
            nc["posp"] = c["posp"].at[:, pages].set(-1, mode="drop")
            for name, leaf in c.items():             # slot-resident riders
                if name not in PAGE_KEYS:
                    nc[name] = _reset_slot_row(name, leaf, slot)
            out.append(nc)
        else:
            out.append({name: _reset_slot_row(name, leaf, slot)
                        for name, leaf in c.items()})
    return out


def _write_slot_row(leaf, src_leaf, slot):
    """Overwrite batch row ``slot`` of a pooled leaf with row 0 of ``src``."""
    return leaf.at[:, slot].set(src_leaf[:, 0].astype(leaf.dtype))


def _reset_slot_row(name: str, leaf, slot):
    """Clear batch row ``slot``: ``pos`` entries become -1 (the invalid
    marker the causal mask respects); every other leaf zeroes."""
    fill = jnp.asarray(-1 if name == "pos" else 0, leaf.dtype)
    return leaf.at[:, slot].set(fill)


def reset_cache_slot(cache: list, slot) -> list:
    """Clear batch row ``slot`` of a pooled cache.

    ``slot`` may be a traced scalar, so one jitted reset serves all slots.
    """
    return [{name: _reset_slot_row(name, leaf, slot)
             for name, leaf in c.items()} for c in cache]


def write_cache_slot(cache: list, src: list, slot) -> list:
    """Overwrite batch row ``slot`` of a pooled cache with row 0 of ``src``.

    ``src`` is a batch-1 cache produced by prefilling one request (same cfg
    and max_len, so leaf shapes match row-wise). The batch-major layout
    makes admission of a new request into a freed slot a pure row
    overwrite — the continuous-batching primitive.
    """
    return [{name: _write_slot_row(leaf, s[name], slot)
             for name, leaf in c.items()} for c, s in zip(cache, src)]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(params: Dict, cfg: ModelConfig,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            cache: Optional[list] = None,
            quant: QuantConfig = QuantConfig(),
            plans: Optional[PlanBundle] = None,
            capture: bool = False,
            compute_logits: bool = True,
            remat: bool = False,
            block_tables: Optional[jax.Array] = None,
            slot_ids: Optional[jax.Array] = None,
            active_rows: Optional[jax.Array] = None):
    """Returns (logits, new_cache, aux) where aux = {"moe_loss", "capture"}.

    ``block_tables`` (B, max_blocks) int32 maps each batch row's logical
    blocks to physical pages of a paged cache (required when ``cache``
    came from :func:`init_paged_cache`; unallocated entries must point at
    the null page 0). ``slot_ids`` (B,) int32 optionally names the pool
    row each batch row occupies, letting a ragged decode batch (B = the
    active-request bucket, smaller than the pool) gather/scatter the
    slot-resident cache rows it touches; entries >= pool size are padding
    rows whose writes are dropped. ``active_rows`` (traced int32 scalar)
    marks batch rows at index >= active_rows as padding for the paged
    attention kernel — dynamic valid-row masking, so one trace serves
    every active-request count of a packed decode batch.
    """
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds
    # sequence parallelism on the residual stream (Megatron-SP style): the
    # scan carry — and therefore the per-period remat stack — is sharded
    # over the model axis along seq; GSPMD inserts the all-gather before
    # attention/MLP and the reduce-scatter after.
    x = maybe_shard(x.astype(jnp.bfloat16), "batch", "seq_model", None)
    B, Sq = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

    period = cfg.period
    plan_meta = plans.meta if plans is not None else {}
    plan_arrays = plans.arrays if plans is not None else {}
    plan_fused = (getattr(plans, "fused", None) or {}) if plans is not None else {}
    has_cache = cache is not None

    def body(x, xs):
        block_list, cache_list, plan_arrs = xs
        caps: Dict[str, jax.Array] = {}
        moe_loss = jnp.zeros((), jnp.float32)
        new_caches = []
        for i in range(period):
            mixer, ffn = cfg.mixer_pattern[i], cfg.ffn_pattern[i]
            ffn_kind = "rwkv_cmix" if cfg.family == "ssm" else ffn
            p = block_list[i]
            c_pool = cache_list[i] if has_cache else None
            paged = c_pool is not None and "kp" in c_pool
            if c_pool is not None and slot_ids is not None:
                # ragged decode: the batch is a bucket of active requests;
                # pull their slot-resident rows out of the pool (OOB padding
                # ids clamp — those rows compute garbage that is dropped on
                # the scatter back below). Page-pool leaves are row-agnostic
                # and pass through untouched.
                c = {name: (leaf if paged and name in PAGE_KEYS
                            else leaf[slot_ids])
                     for name, leaf in c_pool.items()}
            else:
                c = c_pool
            # per-period plan slices for this position's layers
            pref = f"b{i}."
            arrs = {k[len(pref):]: v for k, v in plan_arrs.items()
                    if k.startswith(pref)}
            meta = {k[len(pref):]: v for k, v in plan_meta.items()
                    if k.startswith(pref)}
            fpairs = {k[len(pref):]: v[len(pref):]
                      for k, v in plan_fused.items()
                      if k.startswith(pref) and v.startswith(pref)}
            caps_i: Dict[str, jax.Array] = {}
            # deployed fused-norm serving: when this position's linears are
            # offline-quantized QTensors on the ARC serving path
            # (backend="pallas", or the reference backend running the same
            # calibrated one-pass configuration), the residual-stream
            # RMSNorms fold into the per-linear quantization pass — the
            # attention qkv and MLP gate/up projections receive pre-norm x
            # and dense() applies the norm inside the (fused) quantizer.
            serving_fused = (quant.method == "arc" and arrs
                             and (quant.backend == "pallas"
                                  or quant.act_scale == "calibrated"))
            fuse_attn = (serving_fused and mixer in (FULL_ATTN, LOCAL_ATTN)
                         and "attn.wq" in arrs
                         and isinstance(p["attn"]["wq"], Q.QTensor))
            fuse_mlp = (serving_fused and ffn_kind == DENSE_FFN
                        and "mlp.w_gate" in arrs
                        and isinstance(p["mlp"]["w_gate"], Q.QTensor))
            fused_gamma: Dict[str, jax.Array] = {}
            if fuse_attn:
                fused_gamma.update({f"attn.{l}": p["norm1"]
                                    for l in ("wq", "wk", "wv")})
            if fuse_mlp:
                fused_gamma.update({f"mlp.{l}": p["norm2"]
                                    for l in ("w_gate", "w_up")})
            ctx = L.LayerCtx(cfg, quant, plan_arrays=arrs or None,
                             plan_meta=meta or None,
                             capture=caps_i if capture else None,
                             fused_gamma=fused_gamma or None,
                             fused_pairs=fpairs or None)

            h = x if fuse_attn else L.rmsnorm(x, p["norm1"], cfg.norm_eps)
            nc = {}
            if mixer in (FULL_ATTN, LOCAL_ATTN):
                window = cfg.sliding_window if mixer == LOCAL_ATTN else None
                if paged:
                    ac = {k: c[k] for k in ("kp", "vp", "posp")}
                    if block_tables is None:
                        raise ValueError("paged cache requires block_tables")
                elif c is not None:
                    ac = {k: c[k] for k in ("k", "v", "pos")}
                else:
                    ac = None
                out, nac = L.attention_layer(ctx, "attn", p["attn"], h,
                                             positions, ac, window,
                                             block_table=block_tables
                                             if paged else None,
                                             active_rows=active_rows
                                             if paged else None)
                if nac is not None:
                    nc.update(nac)
            elif mixer == MAMBA:
                mc = {k: c[k] for k in ("conv", "ssm")} if c is not None else None
                out, nmc = S.mamba_layer(ctx, "mamba", p["mamba"], h, mc)
                if nmc is not None:
                    nc.update(nmc)
            elif mixer == RWKV:
                rc = {k: c[k] for k in ("wkv", "shift")} if c is not None else None
                out, nrc = S.rwkv_tmix_layer(ctx, "rwkv", p["rwkv"], h, rc)
                if nrc is not None:
                    nc.update(nrc)
            else:
                raise ValueError(mixer)
            x = x + out.astype(x.dtype)

            h2 = x if fuse_mlp else L.rmsnorm(x, p["norm2"], cfg.norm_eps)
            if ffn_kind == MOE_FFN:
                out2, aux = L.moe_layer(ctx, "moe", p["moe"], h2)
                moe_loss = moe_loss + aux
            elif ffn_kind == "rwkv_cmix":
                shift = c["cmix_shift"] if c is not None else None
                out2, ns = L.rwkv_cmix_layer(ctx, "cmix", p["cmix"], h2, shift)
                if c is not None:
                    nc["cmix_shift"] = ns
            else:
                out2 = L.mlp_layer(ctx, "mlp", p["mlp"], h2)
            x = x + out2.astype(x.dtype)

            if capture:
                caps.update({f"b{i}.{k}": v for k, v in caps_i.items()})
            if c_pool is not None and slot_ids is not None:
                # scatter the bucket's updated rows back into the pool;
                # padding rows (slot_ids >= pool size) are dropped
                nc = {name: (v if paged and name in PAGE_KEYS
                             else c_pool[name].at[slot_ids].set(
                                 v.astype(c_pool[name].dtype), mode="drop"))
                      for name, v in nc.items()}
            new_caches.append(nc)

        x = maybe_shard(x, "batch", "seq_model", None)   # keep carry SP-sharded
        ys = (tuple(new_caches) if has_cache else (), caps, moe_loss)
        return x, ys

    xs = (params["blocks"],
          cache if has_cache else [()] * period,
          plan_arrays)
    body_fn = jax.checkpoint(body) if remat else body
    x, (new_cache, caps, moe_losses) = jax.lax.scan(body_fn, x, xs)

    aux = {"moe_loss": jnp.sum(moe_losses)}
    if capture:
        aux["capture"] = caps

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if not compute_logits:
        return x, (list(new_cache) if has_cache else None), aux
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    # bf16 logits: halves the dominant activation buffer; the loss upcasts
    # tile-wise inside the fused reduction (f32 accumulation preserved).
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.bfloat16),
                        head.astype(jnp.bfloat16),
                        preferred_element_type=jnp.bfloat16)
    vp = head.shape[0]
    if vp != cfg.vocab_size:
        mask = jnp.where(jnp.arange(vp) < cfg.vocab_size,
                         jnp.bfloat16(0), jnp.bfloat16(-1e30))
        logits = logits + mask
    logits = maybe_shard(logits, "batch", None, "vocab")
    return logits, (list(new_cache) if has_cache else None), aux


def prefill_chunk(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  positions: jax.Array, cache: list,
                  quant: QuantConfig = QuantConfig(),
                  plans: Optional[PlanBundle] = None) -> list:
    """Advance an in-progress prefill by one token chunk.

    Chunked-prefill entry: ``cache`` already holds positions
    ``[0, positions[0, 0])`` of the same sequence (attention K/V written
    per absolute position; SSM/RWKV recurrent state threaded through), so
    feeding the prompt in slices across calls builds exactly the cache a
    one-shot prefill would — attention reads mask on stored positions and
    the recurrent scans consume tokens in the same order. Skips the
    logits head (``compute_logits=False``): only the final chunk needs
    logits, via :func:`forward`.
    """
    _, cache, _ = forward(params, cfg, tokens=tokens, positions=positions,
                          cache=cache, quant=quant, plans=plans,
                          compute_logits=False)
    return cache


# ---------------------------------------------------------------------------
# Loss / eval helpers
# ---------------------------------------------------------------------------


def next_token_loss(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                    quant: QuantConfig = QuantConfig(),
                    plans: Optional[PlanBundle] = None,
                    embeds: Optional[jax.Array] = None,
                    positions: Optional[jax.Array] = None,
                    remat: bool = False) -> Tuple[jax.Array, Dict]:
    """Causal LM loss over ``tokens`` (B, S); predicts tokens[:, 1:]."""
    logits, _, aux = forward(params, cfg, tokens=tokens, embeds=embeds,
                             positions=positions, quant=quant, plans=plans,
                             remat=remat)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    # shard-friendly true-logit extraction: an iota-select reduction fuses
    # under GSPMD with vocab-sharded logits (take_along_axis would gather
    # across vocab shards and re-materialize the full logits).
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
    true = jnp.sum(jnp.where(iota == tgt[..., None], lg, 0.0), axis=-1)
    nll = jnp.mean(logz - true)
    return nll + aux["moe_loss"], {"nll": nll, **aux}


def perplexity(params: Dict, cfg: ModelConfig, token_batches,
               quant: QuantConfig = QuantConfig(),
               plans: Optional[PlanBundle] = None) -> float:
    """Corpus perplexity over an iterable of (B, S) token arrays."""
    tot, n = 0.0, 0
    for tokens in token_batches:
        _, aux = next_token_loss(params, cfg, tokens, quant, plans)
        tot += float(aux["nll"])
        n += 1
    return float(np.exp(tot / max(n, 1)))


# ---------------------------------------------------------------------------
# Calibration capture
# ---------------------------------------------------------------------------


def capture_stats(params: Dict, cfg: ModelConfig, tokens=None, embeds=None,
                  positions=None) -> Dict[str, jax.Array]:
    """One forward pass collecting per-linear input absmax.

    Returns {"b{i}.{layer}.{proj}": (num_periods, K)} — per-period stats,
    matching the paper's per-layer outlier counts (Fig. 7).
    """
    _, _, aux = forward(params, cfg, tokens=tokens, embeds=embeds,
                        positions=positions, capture=True,
                        compute_logits=False)
    # scan stacks ys over periods: leaves are (num_periods, K)
    return dict(aux["capture"])
