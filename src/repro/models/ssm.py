"""State-space mixers: Mamba (selective SSM) and RWKV6 "Finch" time-mix.

Both are implemented with ``jax.lax.scan`` over time carrying an O(1)
recurrent state, which is what makes the ``long_500k`` decode shape
feasible for the ssm/hybrid architectures. Linear projections route
through ``dense()`` so ARCQuant applies to them (DESIGN.md §4); the
recurrence parameters (decay, conv, gates) stay in bf16.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import LayerCtx, dense, rmsnorm
from repro.parallel.sharding import maybe_shard

TIME_CHUNK = 128


def _chunked_time_scan(step, carry, xs, chunk: int = TIME_CHUNK):
    """scan-over-time in rematerialized chunks.

    A flat ``lax.scan`` over S=4k..500k steps makes the backward pass save
    the recurrent state at *every* step (S x state bytes — 34 GB/layer for
    Jamba's Mamba blocks at train_4k). Chunking with ``jax.checkpoint``
    saves the carry only at chunk boundaries and recomputes inside, cutting
    residuals by the chunk factor. Padded steps carry a False mask so the
    step function leaves the state untouched.
    """
    s = jax.tree.leaves(xs)[0].shape[0]
    pad = (-s) % chunk
    mask = jnp.arange(s + pad) < s
    if pad:
        xs = jax.tree.map(
            lambda a: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1)), xs)
    nc = (s + pad) // chunk
    xs_c = jax.tree.map(lambda a: a.reshape(nc, chunk, *a.shape[1:]), xs)
    mask_c = mask.reshape(nc, chunk)

    @jax.checkpoint
    def chunk_body(c, xm):
        xc, mc = xm
        return jax.lax.scan(step, c, (xc, mc))

    carry, ys = jax.lax.scan(chunk_body, carry, (xs_c, mask_c))
    ys = jax.tree.map(lambda a: a.reshape(nc * chunk, *a.shape[2:])[:s], ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_in, dt_rank, cfg.mamba_d_state


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    d_in, dt_rank, n = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": jax.random.normal(ks[0], (2 * d_in, d), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (d_in, cfg.mamba_d_conv), dtype) * 0.1,
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": jax.random.normal(ks[2], (dt_rank + 2 * n, d_in), dtype) * d_in ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (d_in, dt_rank), dtype) * dt_rank ** -0.5,
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01, dtype))),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=dtype), (d_in, 1))),
        "D": jnp.ones((d_in,), dtype),
        "out_proj": jax.random.normal(ks[4], (d, d_in), dtype) * d_in ** -0.5,
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_in, _, n = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, n), dtype),
    }


def mamba_layer(ctx: LayerCtx, name: str, params: Dict, x: jax.Array,
                cache: Optional[Dict] = None):
    """x: (B, S, d) -> (out, new_cache)."""
    cfg = ctx.cfg
    B, S, d = x.shape
    d_in, dt_rank, n = mamba_dims(cfg)

    xz = dense(ctx, f"{name}.in_proj", x, params["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = maybe_shard(x_in, "batch", None, "ff")

    # causal depthwise conv over time (kernel d_conv)
    dc = cfg.mamba_d_conv
    state = cache["conv"] if cache is not None else jnp.zeros((B, dc - 1, d_in), x_in.dtype)
    padded = jnp.concatenate([state.astype(x_in.dtype), x_in], axis=1)
    conv = sum(padded[:, i:i + S] * params["conv_w"][:, i] for i in range(dc))
    conv = conv + params["conv_b"]
    new_conv_state = padded[:, -(dc - 1):]
    xc = jax.nn.silu(conv)

    dbc = dense(ctx, f"{name}.x_proj", xc, params["x_proj"])
    dt_raw, b_ssm, c_ssm = jnp.split(dbc, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        dense(ctx, f"{name}.dt_proj", dt_raw, params["dt_proj"], quantize=False)
        + params["dt_bias"])
    a = -jnp.exp(params["A_log"].astype(jnp.float32))      # (d_in, n)

    def step(h, xs_m):
        (xc_t, dt_t, b_t, c_t), m = xs_m                   # (B,d_in),(B,d_in),(B,n),(B,n)
        da = jnp.exp(dt_t[..., None] * a[None])            # (B, d_in, n)
        h_new = da * h + (dt_t * xc_t)[..., None] * b_t[:, None, :]
        h = jnp.where(m, h_new, h)                         # padded steps: no-op
        y = jnp.sum(h * c_t[:, None, :], axis=-1)          # (B, d_in)
        return h, y

    h0 = (cache["ssm"] if cache is not None
          else jnp.zeros((B, d_in, n), jnp.float32)).astype(jnp.float32)
    h0 = maybe_shard(h0, "batch", "ff", None)
    # scan inputs: (S, B, d_in) with d_in sharded over model — the time
    # scan only slices the leading dim, so each rank integrates its own
    # d_in/16 slice locally (the SSM recurrence is elementwise over d_in).
    # bf16 carriers halve the materialized stacks; the recurrence itself
    # (step) stays f32.
    xs = (maybe_shard(xc.transpose(1, 0, 2).astype(jnp.bfloat16),
                      None, "batch", "ff"),
          maybe_shard(delta.transpose(1, 0, 2).astype(jnp.bfloat16),
                      None, "batch", "ff"),
          b_ssm.transpose(1, 0, 2).astype(jnp.bfloat16),
          c_ssm.transpose(1, 0, 2).astype(jnp.bfloat16))

    def step_f32(h, xs_m):
        (a1, a2, a3, a4), m = xs_m
        return step(h, ((a1.astype(jnp.float32), a2.astype(jnp.float32),
                         a3.astype(jnp.float32), a4.astype(jnp.float32)), m))

    h_last, ys = _chunked_time_scan(step_f32, h0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype) + xc * params["D"]
    y = y * jax.nn.silu(z)
    out = dense(ctx, f"{name}.out_proj", y, params["out_proj"])
    new_cache = {"conv": new_conv_state, "ssm": h_last} if cache is not None else None
    return maybe_shard(out, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# RWKV6 time mix ("Finch": data-dependent decay)
# ---------------------------------------------------------------------------

DECAY_RANK = 32


def rwkv_heads(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd


def init_rwkv_tmix(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    h, hd = rwkv_heads(cfg)
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    p = {f"tmix_{nm}": jax.random.normal(k, (d, d), dtype) * std
         for nm, k in zip(("r", "k", "v", "g", "o"), ks[:5])}
    p.update({
        "decay_w1": jax.random.normal(ks[5], (DECAY_RANK, d), dtype) * std,
        "decay_w2": jax.random.normal(ks[6], (d, DECAY_RANK), dtype) * DECAY_RANK ** -0.5,
        "decay_base": jnp.full((d,), -6.0, dtype),   # w0: slow baseline decay
        "bonus_u": jax.random.normal(ks[7], (h, hd), dtype) * 0.1,
        "ln_x": jnp.ones((h, hd), dtype),
    })
    for nm in ("r", "k", "v", "g", "w"):
        p[f"mu_{nm}"] = jnp.full((d,), 0.5, dtype)
    return p


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    h, hd = rwkv_heads(cfg)
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), dtype),
        "shift": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv_tmix_layer(ctx: LayerCtx, name: str, params: Dict, x: jax.Array,
                    cache: Optional[Dict] = None):
    """RWKV6 time mix. x: (B, S, d) -> (out, new_cache)."""
    cfg = ctx.cfg
    B, S, d = x.shape
    h, hd = rwkv_heads(cfg)

    if cache is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([cache["shift"][:, None].astype(x.dtype),
                                x[:, :-1]], axis=1)
    new_shift = x[:, -1]
    dx = prev - x

    def lerp(nm):
        return x + dx * params[f"mu_{nm}"]

    r = dense(ctx, f"{name}.tmix_r", lerp("r"), params["tmix_r"])
    k = dense(ctx, f"{name}.tmix_k", lerp("k"), params["tmix_k"])
    v = dense(ctx, f"{name}.tmix_v", lerp("v"), params["tmix_v"])
    g = dense(ctx, f"{name}.tmix_g", lerp("g"), params["tmix_g"])

    # data-dependent decay (low-rank): w_t = exp(-exp(w0 + tanh(xw W1^T) W2^T))
    xw = lerp("w").astype(jnp.float32)
    dd = jnp.tanh(jnp.einsum("bsd,rd->bsr", xw, params["decay_w1"].astype(jnp.float32)))
    dd = jnp.einsum("bsr,dr->bsd", dd, params["decay_w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(params["decay_base"].astype(jnp.float32) + dd))  # (B,S,d) in (0,1)

    rh = r.reshape(B, S, h, hd).astype(jnp.float32)
    kh = k.reshape(B, S, h, hd).astype(jnp.float32)
    vh = v.reshape(B, S, h, hd).astype(jnp.float32)
    wh = w.reshape(B, S, h, hd)
    u = params["bonus_u"].astype(jnp.float32)

    def step(state, xs_m):
        (r_t, k_t, v_t, w_t), m = xs_m                       # (B, h, hd)
        kv = jnp.einsum("bhd,bhe->bhde", k_t, v_t)
        y = jnp.einsum("bhd,bhde->bhe", r_t, state + u[None, :, :, None] * kv)
        state = jnp.where(m, w_t[..., None] * state + kv, state)
        return state, y

    s0 = (cache["wkv"] if cache is not None
          else jnp.zeros((B, h, hd, hd), jnp.float32)).astype(jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rh, kh, vh, wh))
    s_last, ys = _chunked_time_scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3)                             # (B, S, h, hd)

    # per-head normalization, gate, output projection
    y = rmsnorm(y, params["ln_x"], cfg.norm_eps)
    y = (y.reshape(B, S, d) * jax.nn.silu(g)).astype(x.dtype)
    out = dense(ctx, f"{name}.tmix_o", y, params["tmix_o"])
    new_cache = ({"wkv": s_last, "shift": new_shift}
                 if cache is not None else None)
    return maybe_shard(out, "batch", None, None), new_cache
