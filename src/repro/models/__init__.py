from repro.models import layers, lm, ssm
from repro.models.lm import (PlanBundle, capture_stats, forward, init_cache,
                             init_params, next_token_loss, padded_vocab,
                             prefill_chunk,
                             perplexity, reset_cache_slot, write_cache_slot)

__all__ = ["layers", "lm", "ssm", "PlanBundle", "capture_stats", "forward",
           "init_cache", "init_params", "next_token_loss", "padded_vocab",
           "prefill_chunk",
           "perplexity", "reset_cache_slot", "write_cache_slot"]
