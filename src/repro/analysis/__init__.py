"""Compiled-artifact static analysis: HLO/jaxpr rule engine, retrace
guard, VMEM budgets, collective lint (see ``analysis.rules`` for the
rule catalogue; ``launch/analyze.py`` for the CLI; README "Static
analysis" for how to add a rule)."""
from repro.analysis.collectives import COLLECTIVE_OPS, parse_collectives
from repro.analysis.entrypoints import (EntryArtifact, analyze_engine,
                                        build_artifact, engine_entrypoints,
                                        lint_engine)
from repro.analysis.hlo import HloInstr, HloModule, parse_hlo
from repro.analysis.retrace import TraceGuard
from repro.analysis.rules import (ERROR, INFO, RULES, WARNING, Finding,
                                  RuleContext, max_severity, run_rules)
from repro.analysis.vmem import DEFAULT_VMEM_LIMIT, entry_vmem_reports

__all__ = [
    "COLLECTIVE_OPS", "DEFAULT_VMEM_LIMIT", "ERROR", "EntryArtifact",
    "Finding", "HloInstr", "HloModule", "INFO", "RULES", "RuleContext",
    "TraceGuard", "WARNING", "analyze_engine", "build_artifact",
    "engine_entrypoints", "entry_vmem_reports", "lint_engine",
    "max_severity", "parse_collectives", "parse_hlo", "run_rules",
]
