"""Per-kernel VMEM budget estimation (rule R6).

A Pallas launch whose double-buffered blocks + scratch exceed the
per-core VMEM (~16 MiB on current TPUs) fails to lower on hardware —
but CI runs the kernels in interpret mode, where any block size "works".
This module re-derives every kernel launch an entry point will make from
static metadata (the quantized weight pytree, the engine geometry, and
the plans' outlier counts) and prices it with the estimators the kernels
themselves export (``gemm_vmem_bytes``, ``fused_quant_plan``,
``paged_attention_plan``) — the estimators live next to the BlockSpecs
they mirror, so a kernel schedule change updates both or fails R6.

Reports deduplicate by launch geometry: a 28-layer model has 28
identical ``wq`` launches, which is one row with a site count.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.quant import QTensor
from repro.kernels.arc_fused_quant import fused_quant_plan
from repro.kernels.nvfp4_gemm import gemm_plan, gemm_vmem_bytes, swiglu_plan
from repro.kernels.paged_attention import paged_attention_plan
from repro.quant.apply import QUANTIZABLE

DEFAULT_VMEM_LIMIT = 16 * 2**20     # per-core VMEM (pallas_guide.md)

# entry point -> how many activation rows one launch flattens together
_ENTRY_ROWS = {
    "prefill": "max_len",           # one-shot prefill: up to max_len tokens
    "prefill_chunk": "chunk",       # chunk width (max_len when unchunked)
    "decode": "slots",              # one token per slot
    "decode_paged": "slots",
}


def _quantized_sites(qparams: Dict) -> List[Tuple[str, int, int]]:
    """(site name, N, Ka) for every packed-QTensor linear weight."""
    sites = []
    for i, block in enumerate(qparams.get("blocks", [])):
        for module, leaves in QUANTIZABLE.items():
            if module not in block:
                continue
            for leaf in leaves:
                qt = block[module].get(leaf)
                if isinstance(qt, QTensor) and qt.packed:
                    sites.append((f"b{i}.{module}.{leaf}",
                                  int(qt.shape[-2]), int(qt.valid_k)))
    return sites


def entry_rows(engine, entry: str) -> int:
    """Conservative activation-row count for one launch of ``entry``."""
    kind = _ENTRY_ROWS.get(entry, "slots")
    if kind == "max_len":
        return engine.max_len
    if kind == "chunk":
        return engine.prefill_chunk or engine.max_len
    return engine.batch_size


def entry_vmem_reports(engine, entry: str) -> List[dict]:
    """Estimated VMEM per unique kernel launch ``entry`` makes.

    Each report: ``{kernel, site, count, grid, blocks, vmem_bytes}``.
    GEMM + fused-quantize launches exist only on the deployed pallas
    path (packed QTensor weights); the paged-attention launch exists on
    decode_paged whenever the attention kernel is enabled — including
    unquantized engines.
    """
    reports: List[dict] = []
    m = entry_rows(engine, entry)

    if engine.quant.backend == "pallas":
        plans = getattr(engine, "plans", None)
        meta = plans.meta if plans is not None else {}
        # fused swiglu epilogue: the up projection of each fused gate/up
        # pair is decoded inside the gate's dual-weight launch, so it is
        # not a launch of its own — price one nvfp4_gemm_swiglu instead
        fused = ((getattr(plans, "fused", None) or {})
                 if engine.quant.fuse_epilogue else {})
        fused_up = set(fused.values())
        seen: Dict[tuple, dict] = {}
        for site, n, ka in _quantized_sites(engine.qparams):
            s = meta.get(site, 0)
            if site in fused_up:
                continue
            if site in fused:
                gp = swiglu_plan(m, n, ka, out_bytes=2)   # bf16 epilogue out
                key = ("nvfp4_gemm_swiglu", m, n, ka)
            else:
                gp = gemm_plan(m, n, ka)
                key = ("nvfp4_gemm", m, n, ka)
            if key in seen:
                seen[key]["count"] += 1
                continue
            seen[key] = {
                "kernel": gp["kernel"], "site": site, "count": 1,
                "grid": gp["grid"],
                "blocks": (gp["bm"], gp["bn"], gp["bk"]),
                "vmem_bytes": gemm_vmem_bytes(gp, w_packed=True),
            }
            qkey = ("arc_fused_quantize", m, ka - s, s)
            if qkey not in seen:
                fp = fused_quant_plan(m, ka - s, s)
                seen[qkey] = {
                    "kernel": "arc_fused_quantize", "site": site,
                    "count": 1, "grid": fp["grid"],
                    "blocks": (fp["bm"], ka - s),
                    "vmem_bytes": fp["vmem_bytes"],
                }
            else:
                seen[qkey]["count"] += 1
        reports.extend(seen.values())

    if entry == "decode_paged" and engine.quant.attn_kernel:
        cfg = engine.cfg
        bs = getattr(engine, "block_size", 16)
        nblocks = -(-engine.max_len // bs)
        pp = paged_attention_plan(engine.batch_size, cfg.num_heads,
                                  cfg.head_dim, cfg.num_kv_heads, bs,
                                  nblocks)
        reports.append({
            "kernel": "paged_attention_decode", "site": "attn.decode",
            "count": sum(mix in ("full", "local")
                         for mix in cfg.mixer_pattern) * cfg.num_periods,
            "grid": pp["grid"],
            "blocks": (cfg.num_heads, cfg.head_dim, bs),
            "vmem_bytes": pp["vmem_bytes"],
        })
    return reports
