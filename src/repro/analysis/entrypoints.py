"""Build analyzable artifacts from a serving engine's jitted entry points.

For each entry point a facade engine serves with (slot engines:
``prefill``/``prefill_chunk``/``decode``; paged engines swap ``decode``
for ``decode_paged``), this module lowers the jit with representative
dummy arguments (the same idiom ``benchmarks/paged_attention.py`` uses
for its decode tick), compiles it, and packages:

  * the post-optimization HLO (parsed, ``analysis.hlo``),
  * the StableHLO lowering text,
  * the jaxpr text,
  * per-entry rule metadata — the forbidden augmented-weight shapes
    (R1), the gathered K/V view shapes (R2), donation expectations (R3),
    VMEM launch estimates (R6), and device counts (R7)

into an :class:`EntryArtifact` whose ``context()`` feeds
:func:`analysis.rules.run_rules` directly.

The R1 forbidden set deliberately excludes augmented-weight shapes that
fit inside a single GEMM tile: interpret-mode Pallas emulation
materializes each decoded *tile* as a real HLO tensor, and on reduced
configs one tile covers the whole weight — the healthy path would trip a
naive full-shape scan. A weight that exceeds one tile can only appear
whole in the HLO if something outside the kernel dequantized it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.hlo import HloModule, parse_hlo
from repro.analysis.rules import Finding, RuleContext, run_rules
from repro.analysis.vmem import (DEFAULT_VMEM_LIMIT, _quantized_sites,
                                 entry_rows, entry_vmem_reports)
from repro.kernels.nvfp4_gemm import gemm_plan


@dataclasses.dataclass
class EntryArtifact:
    """One entry point's compiled artifacts + rule metadata."""

    entry: str
    compiled_text: str
    lowered_text: str
    jaxpr_text: str
    hlo: HloModule
    meta: Dict

    def context(self) -> RuleContext:
        return RuleContext(entry=self.entry, hlo=self.hlo,
                           lowered_text=self.lowered_text,
                           jaxpr_text=self.jaxpr_text, meta=self.meta)


def engine_entrypoints(engine) -> List[str]:
    decode = engine.cache_backend.decode_fn
    return ["prefill", "prefill_chunk", decode]


# ---------------------------------------------------------------------------
# dummy arguments per entry point
# ---------------------------------------------------------------------------


def _prefill_args(engine, core, width: int):
    cache = core.pool.fresh_prefill_cache()
    toks = jnp.zeros((1, width), jnp.int32)
    pos = jnp.arange(width, dtype=jnp.int32)[None]
    return (engine.qparams, cache, toks, pos, jnp.int32(width - 1))


def _prefill_chunk_args(engine, core, width: int):
    cache = core.pool.fresh_prefill_cache()
    toks = jnp.zeros((1, width), jnp.int32)
    pos = jnp.arange(width, dtype=jnp.int32)[None]
    return (engine.qparams, cache, toks, pos)


def _decode_args(engine, core):
    m = engine.batch_size
    return (engine.qparams, core.pool.cache,
            jnp.zeros((m, 1), jnp.int32), jnp.zeros((m, 1), jnp.int32),
            jnp.zeros((m,), jnp.float32), jnp.zeros((m,), jnp.int32),
            jnp.zeros((m,), jnp.int32), jax.random.PRNGKey(0))


def _decode_paged_args(engine, core):
    m = engine.batch_size
    pool = core.pool
    return (engine.qparams, pool.cache,
            jnp.zeros((m, 1), jnp.int32), jnp.zeros((m, 1), jnp.int32),
            jnp.zeros((m, pool.max_blocks), jnp.int32),
            jnp.zeros((m,), jnp.int32), jnp.int32(m),
            jnp.zeros((m,), jnp.float32), jnp.zeros((m,), jnp.int32),
            jnp.zeros((m,), jnp.int32), jax.random.PRNGKey(0))


def entry_args(engine, core, entry: str) -> tuple:
    if entry == "prefill":
        return _prefill_args(engine, core, min(16, engine.max_len))
    if entry == "prefill_chunk":
        return _prefill_chunk_args(engine, core,
                                   engine.prefill_chunk or
                                   min(16, engine.max_len))
    if entry == "decode":
        return _decode_args(engine, core)
    if entry == "decode_paged":
        return _decode_paged_args(engine, core)
    raise ValueError(f"unknown entry point {entry!r}")


# ---------------------------------------------------------------------------
# rule metadata
# ---------------------------------------------------------------------------


def _forbidden_weight_shapes(engine, entry: str) -> Dict[Tuple[int, ...], str]:
    """Full augmented-weight shapes (any stacking prefix) whose wide
    materialization R1 forbids — only weights larger than one GEMM tile
    (see module docstring for the interpret-mode tile caveat)."""
    m = entry_rows(engine, entry)
    out: Dict[Tuple[int, ...], str] = {}
    for site, n, ka in _quantized_sites(engine.qparams):
        gp = gemm_plan(m, n, ka)
        if n <= gp["bn"] and ka <= gp["bk"]:
            continue                        # one tile covers the weight
        out[(n, ka)] = site
        out[(ka, n)] = site                 # transposed materialization
    return out


def _cache_meta(cache) -> Tuple[int, set]:
    leaves = jax.tree_util.tree_leaves(cache)
    return len(leaves), {tuple(leaf.shape) for leaf in leaves}


def build_meta(engine, core, entry: str, cache,
               vmem_limit: int = DEFAULT_VMEM_LIMIT) -> Dict:
    cfg = engine.cfg
    n_leaves, pool_shapes = _cache_meta(cache)
    meta = {
        "deployed": engine.quant.backend == "pallas"
        and bool(_quantized_sites(engine.qparams)),
        "step_loop": True,
        "expect_aliased": n_leaves,
        "pool_leaf_shapes": pool_shapes,
        "num_devices": jax.device_count(),
        "vmem_limit": vmem_limit,
        "vmem_reports": entry_vmem_reports(engine, entry),
        "forbidden_weight_shapes": _forbidden_weight_shapes(engine, entry),
    }
    if entry == "decode_paged":
        pool = core.pool
        view = (engine.batch_size, pool.max_blocks * pool.block_size,
                cfg.num_kv_heads, cfg.head_dim)
        meta["gathered_view_shapes"] = {view: "paged K/V logical view"}
    return meta


# ---------------------------------------------------------------------------
# artifact construction / linting
# ---------------------------------------------------------------------------


def build_artifact(engine, entry: str, core=None,
                   vmem_limit: int = DEFAULT_VMEM_LIMIT,
                   include_jaxpr: bool = True) -> EntryArtifact:
    """Lower + compile one entry point and package it for the rules."""
    core = core or engine.make_core()
    args = entry_args(engine, core, entry)
    fn = getattr(engine.fns, entry)
    lowered = fn.lower(*args)
    compiled_text = lowered.compile().as_text()
    jaxpr_text = ""
    if include_jaxpr:
        jaxpr_text = str(jax.make_jaxpr(fn)(*args))
    meta = build_meta(engine, core, entry, cache=args[1],
                      vmem_limit=vmem_limit)
    return EntryArtifact(entry=entry, compiled_text=compiled_text,
                         lowered_text=lowered.as_text(),
                         jaxpr_text=jaxpr_text,
                         hlo=parse_hlo(compiled_text), meta=meta)


def analyze_engine(engine, entries: Optional[List[str]] = None,
                   vmem_limit: int = DEFAULT_VMEM_LIMIT,
                   include_jaxpr: bool = True) -> Dict[str, EntryArtifact]:
    """Artifacts for every (requested) entry point of one engine. One
    core (pool) is shared across entries so pool buffers are built once."""
    core = engine.make_core()
    return {entry: build_artifact(engine, entry, core=core,
                                  vmem_limit=vmem_limit,
                                  include_jaxpr=include_jaxpr)
            for entry in (entries or engine_entrypoints(engine))}


def lint_engine(engine, entries: Optional[List[str]] = None,
                vmem_limit: int = DEFAULT_VMEM_LIMIT,
                only: Optional[List[str]] = None,
                exclude: tuple = ()) -> Tuple[Dict[str, EntryArtifact],
                                              List[Finding]]:
    """Run the full rule suite over an engine; returns (artifacts,
    findings across all entry points)."""
    artifacts = analyze_engine(engine, entries=entries,
                               vmem_limit=vmem_limit)
    findings: List[Finding] = []
    for art in artifacts.values():
        findings.extend(run_rules(art.context(), only=only, exclude=exclude))
    return artifacts, findings
