"""Compiled-artifact invariant rules over jaxprs and post-optimization HLO.

Every serving invariant that lives in the *compiled* artifact — not in
any Python-visible state — is encoded here as a rule: a function from a
:class:`RuleContext` (parsed HLO module + lowered MLIR + jaxpr text +
per-entry metadata) to a list of typed :class:`Finding`. The test suite,
the ``launch/analyze.py`` CLI, and the CI ``lint-hlo`` gate all run the
same rules, so the gathered-view regex that used to live inline in
``benchmarks/paged_attention.py`` has one source of truth.

Shipped rules:

    R1 no-dequant-materialization  deployed pallas path must not hold a
       f32/bf16 tensor of a full augmented-weight shape ``(N, K_aug)``
       larger than one kernel tile (in-kernel tile decodes are the
       *point*; a full-shape dequant means the ~4.5 bit/value HBM story
       is gone)
    R2 no-gathered-kv-view         decode must not materialize the
       ``(B, max_blocks*block_size, Hkv, D)`` logical K/V view the jnp
       gather fallback builds
    R3 donation-aliasing           cache-pool arguments must appear in
       the module's ``input_output_alias`` map (donated buffers), and no
       per-tick full-pool ``copy`` may survive optimization
    R4 no-host-callback            nothing in the step loop may host-
       transfer or call back into Python (infeed/outfeed/send/recv,
       ``xla_python_*callback`` custom-calls, jaxpr callback primitives)
    R5 retrace-guard               (dynamic; see ``analysis.retrace``)
       each entry point compiles at most once per declared shape bucket
       across a full serving run
    R6 vmem-budget                 per-kernel VMEM estimates from the
       exported BlockSpec plans must stay under the configured budget
    R7 collective-lint             a single-device serving lowering must
       contain no collectives; sharded lowerings get wire-byte reporting

Rules degrade to no-ops when their metadata is absent, so partial
contexts (e.g. a bare HLO string in a unit test) lint cleanly with just
the rules their inputs support.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.analysis.collectives import COLLECTIVE_OPS, parse_collectives
from repro.analysis.hlo import HloModule, parse_hlo

ERROR = "error"
WARNING = "warning"
INFO = "info"
_SEV_ORDER = {ERROR: 0, WARNING: 1, INFO: 2}

# dtypes that count as a "materialized dequant" / wide-value tensor
WIDE_DTYPES = ("f32", "bf16", "f16", "f64")

# custom-call targets that reach back into the host Python runtime
_CALLBACK_TARGET_RE = re.compile(r"callback|py_func|host", re.IGNORECASE)
_HOST_OPCODES = ("infeed", "outfeed", "send", "recv", "send-done",
                 "recv-done")
# jaxpr primitives that imply a host round trip per launch
_JAXPR_CALLBACK_RE = re.compile(
    r"\b(pure_callback|io_callback|debug_callback|host_callback)\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or report) anchored to an op site."""

    rule: str                       # "R2"
    name: str                       # "no-gathered-kv-view"
    severity: str                   # error | warning | info
    message: str
    entry: str = ""                 # entry-point name ("decode_paged")
    op: str = ""                    # HLO instruction name, when known
    computation: str = ""
    line: int = 0

    def __str__(self) -> str:
        site = f" @{self.computation}%{self.op}" if self.op else ""
        return (f"[{self.severity.upper():7s}] {self.rule} {self.name} "
                f"({self.entry}){site}: {self.message}")


@dataclasses.dataclass
class RuleContext:
    """Everything one entry point exposes to the rules.

    ``meta`` keys (each rule no-ops when its keys are missing):
      deployed                bool  — packed-weight pallas path (R1)
      forbidden_weight_shapes {dims: site} — full augmented-weight shapes
                              exceeding one kernel tile (R1)
      gathered_view_shapes    {dims: site} — logical K/V view shapes (R2)
      expect_aliased          int   — cache leaves that must alias (R3)
      pool_leaf_shapes        {dims} — pool buffer shapes (R3 copy scan)
      step_loop               bool  — entry runs per tick (R4)
      vmem_reports            [dict] — kernel VMEM plans (R6)
      vmem_limit              int   — VMEM budget in bytes (R6)
      num_devices             int   — devices the lowering targets (R7)
    """

    entry: str
    hlo: Optional[HloModule] = None
    hlo_text: str = ""
    lowered_text: str = ""
    jaxpr_text: str = ""
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.hlo is None and self.hlo_text:
            self.hlo = parse_hlo(self.hlo_text)


@dataclasses.dataclass(frozen=True)
class RuleSpec:
    rule: str
    name: str
    fn: Callable[[RuleContext], List[Finding]]
    doc: str


RULES: Dict[str, RuleSpec] = {}


def rule(rid: str, name: str):
    def deco(fn):
        RULES[rid] = RuleSpec(rid, name, fn, (fn.__doc__ or "").strip())
        return fn
    return deco


def run_rules(ctx: RuleContext, only: Optional[Iterable[str]] = None,
              exclude: Iterable[str] = ()) -> List[Finding]:
    """Run the rule suite over one entry point's context; findings are
    ordered most-severe first, then by rule id."""
    findings: List[Finding] = []
    for rid, spec in sorted(RULES.items()):
        if only is not None and rid not in only:
            continue
        if rid in exclude:
            continue
        findings.extend(spec.fn(ctx))
    return sorted(findings, key=lambda f: (_SEV_ORDER[f.severity], f.rule,
                                           f.line))


def max_severity(findings: Iterable[Finding]) -> Optional[str]:
    worst = None
    for f in findings:
        if worst is None or _SEV_ORDER[f.severity] < _SEV_ORDER[worst]:
            worst = f.severity
    return worst


def _fmt_dims(dims: Tuple[int, ...]) -> str:
    return "(" + ",".join(str(d) for d in dims) + ")"


# ---------------------------------------------------------------------------
# R1: no dequantized full-weight materialization on the deployed path
# ---------------------------------------------------------------------------


@rule("R1", "no-dequant-materialization")
def no_dequant_materialization(ctx: RuleContext) -> List[Finding]:
    """The deployed pallas path decodes packed E2M1/E4M3 weight *tiles*
    in-kernel; a wide (f32/bf16) tensor of a full augmented-weight shape
    ``(N, K_aug)`` bigger than one tile means some refactor reintroduced
    a whole-weight dequantization — the ~4.5 bits/value HBM traffic story
    silently becomes 16-32 bits/value."""
    forbidden = ctx.meta.get("forbidden_weight_shapes") or {}
    if not (ctx.hlo and forbidden and ctx.meta.get("deployed")):
        return []
    out = []
    for instr in ctx.hlo.instructions():
        for dt, dims in instr.shapes:
            site = forbidden.get(dims)
            if site is None or dt not in WIDE_DTYPES:
                continue
            out.append(Finding(
                "R1", "no-dequant-materialization", ERROR,
                f"{dt}{_fmt_dims(dims)} materializes the full augmented "
                f"weight of {site} (op {instr.opcode}) — dequant must stay "
                f"in-kernel at tile granularity",
                entry=ctx.entry, op=instr.name,
                computation=instr.computation, line=instr.line))
            break
    return out


# ---------------------------------------------------------------------------
# R2: no gathered logical K/V view on the decode path
# ---------------------------------------------------------------------------


@rule("R2", "no-gathered-kv-view")
def no_gathered_kv_view(ctx: RuleContext) -> List[Finding]:
    """The paged-attention kernel streams K/V pages through the block
    table inside the launch; a ``(B, max_blocks*block_size, Hkv, D)``
    tensor in the decode HLO is the jnp gather fallback's full logical
    view — O(pool) HBM traffic per tick instead of O(resident tokens)."""
    views = ctx.meta.get("gathered_view_shapes") or {}
    if not (ctx.hlo and views):
        return []
    out = []
    for instr in ctx.hlo.instructions():
        for dt, dims in instr.shapes:
            site = views.get(dims)
            if site is None:
                continue
            out.append(Finding(
                "R2", "no-gathered-kv-view", ERROR,
                f"{dt}{_fmt_dims(dims)} materializes the gathered K/V "
                f"view ({site}; op {instr.opcode}) — the block table must "
                f"be walked in-kernel, not gathered into a logical view",
                entry=ctx.entry, op=instr.name,
                computation=instr.computation, line=instr.line))
            break
    return out


# ---------------------------------------------------------------------------
# R3: cache-pool donation / aliasing
# ---------------------------------------------------------------------------


@rule("R3", "donation-aliasing")
def donation_aliasing(ctx: RuleContext) -> List[Finding]:
    """Cache-pool buffers are donated into every step-loop entry point;
    the compiled module must alias them input->output
    (``input_output_alias``) or every tick pays a full pool copy. When
    aliasing is deficient, pool-shaped ``copy`` ops are listed as the
    corroborating op sites (a fully aliased module legitimately keeps a
    few pool-shaped copies feeding fused in-place updates, so the copy
    scan alone is not evidence)."""
    expect = ctx.meta.get("expect_aliased")
    if not (ctx.hlo and expect):
        return []
    out = []
    aliased = len(ctx.hlo.input_output_alias)
    if aliased == 0:
        out.append(Finding(
            "R3", "donation-aliasing", ERROR,
            f"no input_output_alias in the compiled module but "
            f"{expect} cache leaves are donated — the pool is copied "
            f"every tick (donate_argnums lost?)", entry=ctx.entry))
    elif aliased < expect:
        out.append(Finding(
            "R3", "donation-aliasing", WARNING,
            f"only {aliased} of {expect} donated cache leaves alias "
            f"input->output; the rest are copied per tick",
            entry=ctx.entry))
    if aliased >= expect:
        return out
    pool_shapes = ctx.meta.get("pool_leaf_shapes") or set()
    for instr in ctx.hlo.instructions():
        if instr.opcode != "copy":
            continue
        for dt, dims in instr.shapes:
            if dims in pool_shapes:
                out.append(Finding(
                    "R3", "donation-aliasing", WARNING,
                    f"full pool-buffer copy {dt}{_fmt_dims(dims)} "
                    f"survives optimization — the per-tick pool copy a "
                    f"lost donation pays", entry=ctx.entry, op=instr.name,
                    computation=instr.computation, line=instr.line))
                break
    return out


# ---------------------------------------------------------------------------
# R4: no host transfer / Python callback in the step loop
# ---------------------------------------------------------------------------


@rule("R4", "no-host-callback")
def no_host_callback(ctx: RuleContext) -> List[Finding]:
    """A step-loop entry point runs every tick; an infeed/outfeed/send/
    recv or a Python-callback custom-call inside it serializes the loop
    on host round trips (and breaks donation). Debug prints count."""
    if not ctx.meta.get("step_loop"):
        return []
    out = []
    if ctx.hlo is not None:
        for instr in ctx.hlo.instructions():
            if instr.opcode in _HOST_OPCODES:
                out.append(Finding(
                    "R4", "no-host-callback", ERROR,
                    f"host-transfer op '{instr.opcode}' in the step loop",
                    entry=ctx.entry, op=instr.name,
                    computation=instr.computation, line=instr.line))
            elif (instr.opcode == "custom-call"
                  and _CALLBACK_TARGET_RE.search(instr.custom_call_target)):
                out.append(Finding(
                    "R4", "no-host-callback", ERROR,
                    f"Python callback custom-call "
                    f"'{instr.custom_call_target}' in the step loop",
                    entry=ctx.entry, op=instr.name,
                    computation=instr.computation, line=instr.line))
    if ctx.jaxpr_text:
        m = _JAXPR_CALLBACK_RE.search(ctx.jaxpr_text)
        if m:
            out.append(Finding(
                "R4", "no-host-callback", ERROR,
                f"jaxpr contains callback primitive '{m.group(1)}' — a "
                f"host round trip per launch", entry=ctx.entry))
    return out


# ---------------------------------------------------------------------------
# R6: Pallas VMEM budget
# ---------------------------------------------------------------------------


@rule("R6", "vmem-budget")
def vmem_budget(ctx: RuleContext) -> List[Finding]:
    """Per-kernel VMEM residency (double-buffered BlockSpec blocks +
    scratch, from the kernels' exported plans) must stay under the
    budget — an over-budget launch fails to lower on real TPUs or forces
    the compiler to spill the pipeline."""
    reports = ctx.meta.get("vmem_reports") or []
    limit = ctx.meta.get("vmem_limit")
    if not (reports and limit):
        return []
    out = []
    for rep in reports:
        used = rep["vmem_bytes"]
        if used > limit:
            out.append(Finding(
                "R6", "vmem-budget", ERROR,
                f"{rep['kernel']} at {rep['site']}: estimated VMEM "
                f"{used / 2**20:.2f} MiB > budget {limit / 2**20:.2f} MiB "
                f"(grid={rep.get('grid')}, blocks={rep.get('blocks')})",
                entry=ctx.entry))
    return out


# ---------------------------------------------------------------------------
# R7: collective lint
# ---------------------------------------------------------------------------


@rule("R7", "collective-lint")
def collective_lint(ctx: RuleContext) -> List[Finding]:
    """A single-device serving lowering must contain no collectives (one
    would mean sharding constraints leaked into the unsharded path);
    multi-device lowerings get an informational wire-byte report."""
    if ctx.hlo is None or "num_devices" not in ctx.meta:
        return []
    coll = parse_collectives(ctx.hlo.text)
    if coll["count"] == 0:
        return []
    detail = ", ".join(f"{op}={coll[op]:.0f}B" for op in COLLECTIVE_OPS
                       if coll[op])
    if ctx.meta["num_devices"] <= 1:
        return [Finding(
            "R7", "collective-lint", ERROR,
            f"{int(coll['count'])} collective(s) in a single-device "
            f"lowering ({detail}) — sharding constraints leaked into the "
            f"serving path", entry=ctx.entry)]
    return [Finding(
        "R7", "collective-lint", INFO,
        f"{int(coll['count'])} collective(s), wire bytes/device: {detail}",
        entry=ctx.entry)]
