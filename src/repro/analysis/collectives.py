"""Collective lint: per-device wire bytes parsed from (post-SPMD) HLO.

This is the canonical home of the collective parser (rule R7 and the
dry-run roofline both consume it); ``repro.launch.dryrun`` re-exports
``parse_collectives`` for compatibility. Wire bytes use the standard
ring-algorithm model, replica-group aware:

    all-reduce       2 * (n-1)/n * result bytes
    all-gather       (n-1)/n * result bytes  (result is the gathered size)
    reduce-scatter   (n-1)   * result bytes  (input is n * result)
    all-to-all       (n-1)/n * result bytes
    collective-permute   result bytes
"""
from __future__ import annotations

import re

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e8m0fnu": 1,
    "s4": 0.5, "u4": 0.5,
}


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Per-device wire bytes by collective op (ring-algorithm model)."""
    out = {op: 0.0 for op in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("shapes"))
        if rb == 0:
            continue
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            n = len(gl.group(1).split(",")) if gl else 2
        n = max(n, 2)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * rb
        elif op == "all-gather":
            wire = (n - 1) / n * rb
        elif op == "reduce-scatter":
            wire = (n - 1.0) * rb
        elif op == "all-to-all":
            wire = (n - 1) / n * rb
        else:                               # collective-permute
            wire = rb
        out[op] += wire
        out["count"] += 1
    return out
