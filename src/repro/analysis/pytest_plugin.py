"""Pytest fixtures for inline rule assertions.

Registered via ``pytest_plugins`` in ``tests/conftest.py``; tests use
them as:

    def test_decode_is_clean(hlo_lint, assert_no_findings):
        engine = ...
        _, findings = hlo_lint(engine)
        assert_no_findings(findings, max_severity="warning")

    def test_no_retrace(trace_guard):
        core = engine.make_core(trace_guard=trace_guard)
        ...serve...
        assert not [f for f in trace_guard.findings()
                    if f.severity == "error"]
"""
from __future__ import annotations

from typing import Iterable, List, Optional

import pytest

from repro.analysis.entrypoints import lint_engine
from repro.analysis.retrace import TraceGuard
from repro.analysis.rules import _SEV_ORDER, Finding


@pytest.fixture
def trace_guard() -> TraceGuard:
    """A fresh R5 trace counter to thread into ``make_core``."""
    return TraceGuard()


@pytest.fixture
def hlo_lint():
    """``hlo_lint(engine, **kw) -> (artifacts, findings)`` — the full
    rule suite over every entry point of one engine."""
    return lint_engine


@pytest.fixture
def assert_no_findings():
    """Fail the test (with the offending findings listed) when any
    finding at or above ``max_severity`` survives."""

    def check(findings: Iterable[Finding], max_severity: str = "error",
              exclude_rules: Optional[List[str]] = None) -> None:
        bar = _SEV_ORDER[max_severity]
        bad = [f for f in findings
               if _SEV_ORDER[f.severity] <= bar
               and f.rule not in (exclude_rules or [])]
        assert not bad, "rule violations:\n" + "\n".join(
            str(f) for f in bad)

    return check
