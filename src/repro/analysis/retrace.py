"""Retrace guard (rule R5): count jit traces per serving entry point.

The serving contract is that scheduling state never enters a trace: a
full serving run — admissions, chunked prefill, preemption + resume,
aborts, and every active-request count — must compile each entry point
at most once per *declared* shape bucket (one for the fixed-width decode
launch; one per pow2 prompt bucket for one-shot prefill; one per pow2
width when the legacy ``decode_buckets`` knob is on). A retrace on the
hot path is a silent multi-second stall per occurrence, invisible to
correctness tests.

:class:`TraceGuard` wraps an :class:`~repro.serving.core.EngineFns`
with counting shims that fingerprint every call's argument tree by
(structure, leaf shapes, leaf dtypes) — exactly the signature jit keys
its trace cache on for array arguments — and cross-checks the count of
distinct fingerprints against the jitted functions' own ``_cache_size``
where the runtime exposes it. ``EngineCore(..., trace_guard=...)`` (or
``ServingEngine.make_core(trace_guard=...)``) threads the guard under a
core without touching the shared engine fns.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax

from repro.analysis.rules import ERROR, INFO, Finding

ENTRY_NAMES = ("prefill", "prefill_chunk", "decode", "decode_paged",
               "sample")


def _fingerprint(args, kwargs) -> tuple:
    """Trace-cache key proxy: pytree structure + per-leaf (shape, dtype).

    Weak types and non-array leaves hash by type name — close enough for
    the serving entry points, whose leaves are all committed arrays.
    """
    leaves, treedef = jax.tree.flatten((args, kwargs))
    sig = tuple(
        (tuple(getattr(leaf, "shape", ())),
         str(getattr(leaf, "dtype", type(leaf).__name__)))
        for leaf in leaves)
    return (hash(treedef), sig)


class TraceGuard:
    """Counts calls and distinct argument signatures per entry point."""

    def __init__(self):
        self.calls: Dict[str, int] = {}
        self.signatures: Dict[str, Dict[tuple, int]] = {}
        self._jitted: Dict[str, Callable] = {}
        self._baseline: Dict[str, int] = {}

    # -- wrapping ----------------------------------------------------------

    def wrap_fns(self, fns):
        """A copy of ``fns`` whose entry points count through this guard.

        Wrapping records each jitted function's current ``_cache_size``
        as the baseline, so a guard installed on an engine whose fns
        already carry traces (shared across cores) still measures only
        the traces *this* run adds.
        """
        wrapped = {}
        for name in ENTRY_NAMES:
            fn = getattr(fns, name)
            self._jitted[name] = fn
            self._baseline[name] = self._cache_size(fn) or 0
            wrapped[name] = self._wrap(name, fn)
        return dataclasses.replace(fns, **wrapped)

    def _wrap(self, name: str, fn: Callable) -> Callable:
        def shim(*args, **kwargs):
            self.calls[name] = self.calls.get(name, 0) + 1
            sigs = self.signatures.setdefault(name, {})
            key = _fingerprint(args, kwargs)
            sigs[key] = sigs.get(key, 0) + 1
            return fn(*args, **kwargs)
        shim.__name__ = f"traced_{name}"
        return shim

    @staticmethod
    def _cache_size(fn) -> Optional[int]:
        getter = getattr(fn, "_cache_size", None)
        if getter is None:
            return None
        try:
            return int(getter())
        except Exception:       # noqa: BLE001 — diagnostic only
            return None

    # -- reporting ---------------------------------------------------------

    def trace_counts(self) -> Dict[str, int]:
        """Distinct argument signatures seen per called entry point."""
        return {name: len(sigs) for name, sigs in self.signatures.items()}

    def compile_counts(self) -> Dict[str, Optional[int]]:
        """Traces the jit caches actually added since wrapping (None when
        the runtime does not expose cache sizes)."""
        out = {}
        for name, fn in self._jitted.items():
            if self.calls.get(name, 0) == 0:
                continue
            size = self._cache_size(fn)
            out[name] = (None if size is None
                         else max(size - self._baseline[name], 0))
        return out

    def findings(self, declared: Optional[Dict[str, int]] = None
                 ) -> List[Finding]:
        """R5 findings: entry points that traced more than their declared
        shape-bucket allowance (default: one bucket each)."""
        declared = declared or {}
        compiled = self.compile_counts()
        out = []
        for name, sigs in sorted(self.signatures.items()):
            allowance = declared.get(name, 1)
            distinct = len(sigs)
            actual = compiled.get(name)
            observed = distinct if actual is None else actual
            detail = (f"{self.calls[name]} calls, {distinct} distinct "
                      f"signatures"
                      + (f", {actual} traces compiled" if actual is not None
                         else ""))
            if observed > allowance:
                out.append(Finding(
                    "R5", "retrace-guard", ERROR,
                    f"{observed} traces but only {allowance} shape "
                    f"bucket(s) declared ({detail}) — scheduling state "
                    f"leaked into a trace", entry=name))
            else:
                out.append(Finding(
                    "R5", "retrace-guard", INFO,
                    f"within budget: {detail}, allowance {allowance}",
                    entry=name))
        return out
