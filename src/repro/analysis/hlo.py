"""Structured parser for post-optimization HLO text.

The rule engine (``analysis.rules``) needs more than the cost totals
``launch.hlo_analysis.analyze_hlo`` produces: it asks *which* ops
materialize *which* shapes, whether the module aliases its pool inputs
to outputs, and what custom-call targets the step loop reaches. This
module parses the ``compiled.as_text()`` dump into a light object model:

    HloModule
      .computations: {name: HloComputation}
      .entry: the ENTRY computation (when marked)
      .input_output_alias: [(output_index, parameter_number), ...]
      .instructions(): iterator over every HloInstr in the module

    HloInstr
      .name / .opcode / .shapes / .computation / .line / .text
      .custom_call_target (custom-call ops only)

Parsing is line-oriented and regex-based like the cost analyzer — HLO
text is stable enough for that across XLA versions, and the rules only
depend on opcode names, result shapes, and a few header attributes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Tuple

# dtype[d0,d1,...] possibly followed by a layout annotation {...}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')
_ALIAS_PAIR_RE = re.compile(r"\{([0-9,\s]*)\}\s*:\s*\((\d+)")

Shape = Tuple[str, Tuple[int, ...]]     # (dtype, dims)


@dataclasses.dataclass
class HloInstr:
    """One HLO instruction (one ``%name = ...`` line)."""

    name: str
    opcode: str
    shapes: List[Shape]                 # result shape(s); tuples flattened
    computation: str
    line: int                           # 1-based line number in the dump
    text: str
    is_root: bool = False

    @property
    def custom_call_target(self) -> str:
        if self.opcode != "custom-call":
            return ""
        m = _TARGET_RE.search(self.text)
        return m.group(1) if m else ""


@dataclasses.dataclass
class HloComputation:
    name: str
    instrs: List[HloInstr]
    is_entry: bool = False


@dataclasses.dataclass
class HloModule:
    text: str
    computations: Dict[str, HloComputation]
    # header input_output_alias pairs: (output tuple-index path, param no.)
    input_output_alias: List[Tuple[Tuple[int, ...], int]]

    @property
    def entry(self) -> Optional[HloComputation]:
        for comp in self.computations.values():
            if comp.is_entry:
                return comp
        return None

    def instructions(self) -> Iterator[HloInstr]:
        for comp in self.computations.values():
            yield from comp.instrs

    def find_shape(self, dims: Tuple[int, ...],
                   dtypes: Optional[Tuple[str, ...]] = None
                   ) -> List[HloInstr]:
        """Instructions producing a result of exactly ``dims`` (any dtype
        unless ``dtypes`` restricts)."""
        out = []
        for instr in self.instructions():
            for dt, d in instr.shapes:
                if d == dims and (dtypes is None or dt in dtypes):
                    out.append(instr)
                    break
        return out


def _result_shapes(rhs: str) -> Tuple[List[Shape], str]:
    """Split an instruction rhs into (result shapes, rest-after-shapes).

    The rhs looks like ``f32[8,16]{1,0} add(%a, %b), meta=...`` or, for
    tuple results, ``(f32[4]{0}, s32[]) tuple(%a, %b)``. Returns the
    parsed shapes and the remainder starting at the opcode.
    """
    s = rhs.lstrip()
    if s.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(s):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        head, rest = s[: i + 1], s[i + 1:]
    else:
        # consume "dtype[dims]{layout}" tokens up to the opcode
        m = re.match(r"^(\w+\[[0-9,]*\](?:\{[^}]*\})?\s*)+", s)
        if not m:
            return [], s
        head, rest = m.group(0), s[m.end():]
    shapes = [(dt, tuple(int(d) for d in dims.split(",")) if dims else ())
              for dt, dims in _SHAPE_RE.findall(head)]
    return shapes, rest.lstrip()


def _parse_alias_header(text: str) -> List[Tuple[Tuple[int, ...], int]]:
    """``input_output_alias={ {0}: (1, {}, may-alias), ... }`` from the
    ``HloModule`` header line; empty when the module aliases nothing."""
    key = "input_output_alias={"
    start = text.find(key)
    if start < 0:
        return []
    i = start + len(key) - 1
    depth = 0
    for j in range(i, min(len(text), i + 100_000)):
        depth += text[j] == "{"
        depth -= text[j] == "}"
        if depth == 0:
            body = text[i + 1: j]
            break
    else:
        return []
    pairs = []
    for out_idx, param in _ALIAS_PAIR_RE.findall(body):
        idx = tuple(int(x) for x in out_idx.replace(" ", "").split(",")
                    if x != "")
        pairs.append((idx, int(param)))
    return pairs


def parse_hlo(text: str) -> HloModule:
    """Parse one post-optimization HLO module dump."""
    comps: Dict[str, HloComputation] = {}
    cur: Optional[HloComputation] = None
    header = text.splitlines()[0] if text else ""
    alias = _parse_alias_header(header if "input_output_alias" in header
                                else text)
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip()
        stripped = line.strip()
        if "{" in line and "->" in line:
            mc = _COMP_RE.match(stripped)
            if mc and not stripped.startswith("%param"):
                cur = HloComputation(mc.group(1), [],
                                     is_entry=stripped.startswith("ENTRY"))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md or "=" not in line:
            continue
        is_root, name, rhs = bool(md.group(1)), md.group(2), md.group(3)
        shapes, rest = _result_shapes(rhs)
        mo = re.match(r"([\w\-]+)", rest)
        if not mo:
            continue
        cur.instrs.append(HloInstr(name=name, opcode=mo.group(1),
                                   shapes=shapes, computation=cur.name,
                                   line=lineno, text=line, is_root=is_root))
    return HloModule(text=text, computations=comps,
                     input_output_alias=alias)
