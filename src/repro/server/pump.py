"""The engine pump: one asyncio task that owns the ``EngineCore``.

Concurrency model (the part worth getting right):

  * **One pump task, one step thread.** ``core.step()`` blocks (jitted
    device launches), so the pump runs it on a single-worker executor
    via ``run_in_executor`` — the event loop stays responsive while a
    tick is in flight, and ticks never overlap.
  * **Submission is synchronous on the loop thread.** ``submit()`` calls
    the core's thread-safe ``add_request`` directly instead of routing
    through the pump. That keeps backpressure *deterministic*: a full
    bounded queue raises ``QueueFullError`` on the spot (HTTP 429), even
    while a tick is stalled — the core takes its injected-fault stall
    outside the submission lock for exactly this reason.
  * **Aborts apply between ticks.** ``abort()`` only records the rid;
    the pump task calls ``core.abort_request`` after the in-flight tick
    returns, so slot/page release never races the step that is using
    them. The freed request's ABORTED output flushes on the next tick
    (``has_pending_outputs`` forces one even when nothing else runs).
  * **Fanout on the loop thread.** Each submitted request gets an
    ``asyncio.Queue`` of ``RequestOutput`` deltas, terminated by a
    ``None`` sentinel. Registration happens in the same synchronous
    block as ``add_request``, so no delta can be fanned out before its
    subscriber exists. Finished requests are ``pop_request``-ed so a
    long-lived core's state map stays bounded.
  * **Idle is free.** With nothing unfinished, no pending flush, and no
    queued commands, the pump parks on an event — zero ticks, zero
    device launches (the core's idle guard backstops this anyway).
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import logging
from collections import deque
from typing import Deque, Dict, Optional

from repro.serving.core import EngineCore
from repro.serving.request import GenerationRequest, RequestOutput

log = logging.getLogger("repro.server")

TRIM_EVERY_TICKS = 4096             # histogram-trim cadence (pump ticks)
HIST_KEEP = 10000                   # observations retained per histogram


class EnginePump:
    """Owns an :class:`EngineCore` for the server: admissions in, ticks
    through a worker thread, per-request delta queues out."""

    def __init__(self, core: EngineCore):
        self.core = core
        self._subs: Dict[int, asyncio.Queue] = {}
        self._aborts: Deque[int] = deque()
        self._wake = asyncio.Event()
        self._stopping = False
        self._task: Optional[asyncio.Task] = None
        self._ticks = 0
        # tunables (tests shrink them to exercise the trim path)
        self.trim_every = TRIM_EVERY_TICKS
        self.hist_keep = HIST_KEEP
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine-step")

    # -- handler-facing API (event-loop thread only) ------------------------

    def submit(self, request: GenerationRequest) -> "tuple[int, asyncio.Queue]":
        """Admit ``request``; returns ``(rid, delta queue)``.

        Synchronous and atomic with subscriber registration. Raises
        ``QueueFullError`` (bounded queue full -> 429), ``CapacityError``
        (can never fit -> 400), or ``ValueError`` (duplicate pinned
        ``request_id`` -> 400) — nothing is enqueued on a raise.
        """
        rid = self.core.add_request(request)
        q: asyncio.Queue = asyncio.Queue()
        self._subs[rid] = q
        self._wake.set()
        return rid, q

    def abort(self, rid: int) -> None:
        """Request cancellation of ``rid`` (client disconnect). Applied
        by the pump between ticks; the subscriber queue still receives
        the final ABORTED delta and its ``None`` sentinel. An abort that
        races completion — the rid finished and was popped before it
        applied — is a no-op."""
        self._aborts.append(rid)
        self._wake.set()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        self._task = asyncio.get_running_loop().create_task(
            self._run(), name="engine-pump")

    async def stop(self) -> None:
        """Stop the pump and abort anything still in flight.

        After the pump task has quiesced (no step running), leftover
        requests are aborted directly — pages release immediately — and
        every surviving subscriber gets its sentinel so streaming
        handlers unwind cleanly.
        """
        self._stopping = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        for rid, q in list(self._subs.items()):
            try:
                if self.core.abort_request(rid):
                    log.info("request %d aborted at shutdown", rid)
                self.core.pop_request(rid)
            except KeyError:
                pass        # finished and popped before shutdown applied
            q.put_nowait(None)
        self._subs.clear()
        self._executor.shutdown(wait=True)

    # -- pump loop ----------------------------------------------------------

    def _idle(self) -> bool:
        return (not self._aborts
                and not self.core.has_unfinished()
                and not self.core.has_pending_outputs())

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while not self._stopping:
            if self._idle():
                self._wake.clear()
                if self._idle() and not self._stopping:   # recheck post-clear
                    await self._wake.wait()
                continue
            while self._aborts:                  # between ticks, by design
                rid = self._aborts.popleft()
                try:
                    if self.core.abort_request(rid):
                        log.info("request %d aborted (client disconnect)",
                                 rid)
                except KeyError:
                    pass    # abort raced completion: the rid finished and
                    # was popped before the abort applied — a no-op, not
                    # a pump-killing error
            try:
                out = await loop.run_in_executor(self._executor,
                                                 self.core.step)
            except Exception:                    # noqa: BLE001 — keep serving
                log.exception("engine step raised; pump continues")
                self._sweep_lost_finishes()
                continue
            self._fanout(out.outputs)
            self._ticks += 1
            if self._ticks % self.trim_every == 0:
                self.core.stats.trim_histograms(self.hist_keep)

    def _sweep_lost_finishes(self) -> None:
        """A step that raised may have finished requests (watchdog, fault
        containment) before dying — their final deltas died with it.
        Deliver a synthesized final ``RequestOutput`` and the sentinel to
        every subscriber whose request is done (or gone), so handlers
        unwind instead of awaiting ``deltas.get()`` forever."""
        for rid, q in list(self._subs.items()):
            st = self.core.states.get(rid)
            if st is not None and not st.done:
                continue
            if st is not None:
                q.put_nowait(RequestOutput(
                    request_id=rid, new_tokens=[],
                    num_generated=len(st.out_tokens), finished=True,
                    finish_reason=st.finish_reason, error=st.error))
                self.core.pop_request(rid)
            q.put_nowait(None)
            del self._subs[rid]

    def _fanout(self, outputs: "list[RequestOutput]") -> None:
        for ro in outputs:
            q = self._subs.get(ro.request_id)
            if q is not None:
                q.put_nowait(ro)
            if ro.finished:
                try:
                    self.core.pop_request(ro.request_id)
                except KeyError:
                    pass    # already popped by a failed-step sweep
                if q is not None:
                    q.put_nowait(None)
                    del self._subs[ro.request_id]
