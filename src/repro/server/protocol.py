"""OpenAI-compatible protocol layer: request parsing, response shapes,
and the engine-error -> HTTP mapping.

Parsing is strict where it protects the engine (token ids in range,
``n == 1``, positive ``max_tokens``) and lenient everywhere else
(unknown fields are ignored, as OpenAI servers do). Engine extensions
ride as extra request fields: ``request_id`` pins the PRNG stream
(slot-invariant sampling makes pinned-id traces reproducible across
batch compositions), ``deadline_steps``/``queue_timeout_steps`` set the
per-request watchdog bounds.

The error contract (also rendered in the README's mapping table):

  ============================  ======  ================================
  engine condition              status  wire shape
  ============================  ======  ================================
  malformed request             400     ``{"error": {...}}``
  ``CapacityError``             400     can never fit this pool
  duplicate ``request_id``      400
  unknown route                 404
  ``QueueFullError``            429     + ``Retry-After`` header
  handler crash                 500
  watchdog expiry               200     ``finish_reason: "timeout"`` +
                                        ``finish_details``
  NaN-isolated / step failure   200     ``finish_reason: "error"`` +
                                        ``finish_details.message``
  client disconnect mid-stream  —       ``EngineCore.abort_request``
  ============================  ======  ================================
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.request import (FinishReason, GenerationRequest,
                                   SamplingParams)
from repro.server.chat import ByteTokenizer, render_chat


class ProtocolError(ValueError):
    """A malformed or unsupported request body -> HTTP 400."""

    def __init__(self, message: str, code: str = "invalid_request"):
        super().__init__(message)
        self.code = code


def error_json(message: str, etype: str = "invalid_request_error",
               code: Optional[str] = None) -> dict:
    return {"error": {"message": message, "type": etype, "code": code}}


@dataclasses.dataclass(frozen=True)
class ServerDefaults:
    """Server-side defaults applied when a request omits the field —
    the robustness knobs the CLI exposes (``--deadline-steps`` etc.)."""

    max_new_tokens: int = 64
    deadline_steps: Optional[int] = None
    queue_timeout_steps: Optional[int] = None


def _field(obj: dict, key: str, kind, default=None):
    v = obj.get(key, default)
    if v is default:
        return default
    if kind is float and isinstance(v, int) and not isinstance(v, bool):
        v = float(v)
    if not isinstance(v, kind) or isinstance(v, bool):
        raise ProtocolError(f"'{key}' must be a {kind.__name__}")
    return v


def _prompt_tokens(obj: dict, tokenizer: ByteTokenizer) -> np.ndarray:
    prompt = obj.get("prompt")
    if isinstance(prompt, str):
        toks = tokenizer.encode(prompt)
    elif isinstance(prompt, list):
        if not all(isinstance(t, int) and not isinstance(t, bool)
                   for t in prompt):
            raise ProtocolError("'prompt' list must contain token ids "
                                "(integers)")
        if any(t < 0 or t >= tokenizer.vocab_size for t in prompt):
            raise ProtocolError(
                f"'prompt' token ids must be in [0, {tokenizer.vocab_size})")
        toks = np.asarray(prompt, dtype=np.int32)
    else:
        raise ProtocolError("'prompt' must be a string or a token-id list")
    if len(toks) == 0:
        raise ProtocolError("'prompt' must not be empty")
    return toks


def _sampling(obj: dict, defaults: ServerDefaults) -> SamplingParams:
    if obj.get("n", 1) != 1:
        raise ProtocolError("'n' != 1 is not supported")
    max_tokens = _field(obj, "max_tokens", int, defaults.max_new_tokens)
    if max_tokens < 1:
        raise ProtocolError("'max_tokens' must be >= 1")
    temperature = _field(obj, "temperature", float, 0.0)
    if temperature < 0:
        raise ProtocolError("'temperature' must be >= 0")
    deadline = _field(obj, "deadline_steps", int, defaults.deadline_steps)
    queue_to = _field(obj, "queue_timeout_steps", int,
                      defaults.queue_timeout_steps)
    for name, v in (("deadline_steps", deadline),
                    ("queue_timeout_steps", queue_to)):
        if v is not None and v < 1:
            raise ProtocolError(f"'{name}' must be >= 1")
    return SamplingParams(max_new_tokens=max_tokens, temperature=temperature,
                          deadline_steps=deadline,
                          queue_timeout_steps=queue_to)


def parse_completion(obj: dict, tokenizer: ByteTokenizer,
                     defaults: ServerDefaults
                     ) -> Tuple[GenerationRequest, bool]:
    """Build the engine request for ``POST /v1/completions``.

    Returns ``(request, stream)``."""
    if not isinstance(obj, dict):
        raise ProtocolError("request body must be a JSON object")
    toks = _prompt_tokens(obj, tokenizer)
    rid = _field(obj, "request_id", int)
    stream = bool(obj.get("stream", False))
    return (GenerationRequest(prompt=toks,
                              sampling=_sampling(obj, defaults),
                              request_id=rid),
            stream)


def parse_chat(obj: dict, tokenizer: ByteTokenizer, defaults: ServerDefaults
               ) -> Tuple[GenerationRequest, bool]:
    """Build the engine request for ``POST /v1/chat/completions``:
    messages are flattened through the chat template, then tokenized."""
    if not isinstance(obj, dict):
        raise ProtocolError("request body must be a JSON object")
    try:
        text = render_chat(obj.get("messages"))
    except ValueError as e:
        raise ProtocolError(str(e)) from None
    toks = tokenizer.encode(text)
    rid = _field(obj, "request_id", int)
    stream = bool(obj.get("stream", False))
    return (GenerationRequest(prompt=toks,
                              sampling=_sampling(obj, defaults),
                              request_id=rid),
            stream)


# -- response shapes --------------------------------------------------------


def finish_fields(reason: Optional[FinishReason],
                  error: Optional[str] = None
                  ) -> Tuple[Optional[str], Optional[dict]]:
    """(openai finish_reason, finish_details) for one finish.

    ``finish_details`` carries the engine-level reason (which watchdog
    fired, the NaN-guard/step-failure message) that the coarse OpenAI
    strings collapse away."""
    if reason is None:
        return None, None
    wire = reason.to_openai()
    details: Optional[dict] = None
    if wire not in ("stop", "length"):
        details = {"type": wire, "reason": str(reason)}
        if error:
            details["message"] = error
    return wire, details


def _choice(text: str, token_ids: List[int], reason: Optional[FinishReason],
            error: Optional[str], chat: bool, chunk: bool,
            first: bool) -> dict:
    wire, details = finish_fields(reason, error)
    c: dict = {"index": 0, "finish_reason": wire}
    if details is not None:
        c["finish_details"] = details
    if chat:
        body = {"role": "assistant", "content": text} if (first or not chunk) \
            else {"content": text}
        c["delta" if chunk else "message"] = body
    else:
        c["text"] = text
    c["token_ids"] = token_ids
    return c


def completion_json(req_id: str, model: str, created: int, text: str,
                    token_ids: List[int], reason: Optional[FinishReason],
                    error: Optional[str], prompt_tokens: int,
                    chat: bool) -> dict:
    return {
        "id": req_id,
        "object": "chat.completion" if chat else "text_completion",
        "created": created,
        "model": model,
        "choices": [_choice(text, token_ids, reason, error, chat,
                            chunk=False, first=True)],
        "usage": {"prompt_tokens": prompt_tokens,
                  "completion_tokens": len(token_ids),
                  "total_tokens": prompt_tokens + len(token_ids)},
    }


def chunk_json(req_id: str, model: str, created: int, text: str,
               token_ids: List[int], reason: Optional[FinishReason],
               error: Optional[str], chat: bool, first: bool) -> dict:
    return {
        "id": req_id,
        "object": "chat.completion.chunk" if chat else "text_completion",
        "created": created,
        "model": model,
        "choices": [_choice(text, token_ids, reason, error, chat,
                            chunk=True, first=first)],
    }


def models_json(model_id: str, created: int) -> dict:
    return {"object": "list",
            "data": [{"id": model_id, "object": "model",
                      "created": created, "owned_by": "repro"}]}
