"""Chat templating + the byte-level serving tokenizer.

The engine speaks int32 token ids; the OpenAI wire format speaks text.
The repo's models are synthetic proxies with no trained vocabulary, so
the server ships :class:`ByteTokenizer` — a deterministic, stateless
byte-level codec: UTF-8 bytes map one-to-one onto token ids (reduced
configs have vocab >= 512 >= 256, so every byte is a valid id), and each
generated id renders independently of its neighbors. That per-token
independence is what makes SSE delta framing exact: concatenating the
streamed text deltas is *bit-identical* to detokenizing the full token
sequence at once, which the server tests assert against
``EngineCore.stream()``.

Clients that want token-exact control (parity tests, replay) can bypass
text entirely: ``/v1/completions`` accepts ``prompt`` as a raw token-id
list, and every response carries the generated ``token_ids``.

:func:`render_chat` is the chat template — a fixed ChatML-style
flattening of ``messages`` into one prompt string, so identical
conversations always produce identical token sequences (prefix-cache
hits across requests sharing a system prompt come for free).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

CHAT_ROLES = ("system", "user", "assistant")


class ByteTokenizer:
    """Reversible-enough byte codec between text and engine token ids.

    ``encode`` maps UTF-8 bytes to ids (mod vocab, for pathological
    sub-256 vocabs); ``decode_token`` renders printable ASCII ids as
    their character and everything else as the explicit ``<id>`` escape,
    so decoding is a pure per-token function (see module docstring).
    """

    def __init__(self, vocab_size: int):
        if vocab_size < 2:
            raise ValueError("vocab_size must be >= 2")
        self.vocab_size = vocab_size

    def encode(self, text: str) -> np.ndarray:
        data = text.encode("utf-8")
        toks = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
        if self.vocab_size < 256:
            toks = toks % self.vocab_size
        return toks

    def decode_token(self, token: int) -> str:
        if 32 <= token < 127:
            return chr(token)
        if token == 10:
            return "\n"
        return f"<{int(token)}>"

    def decode(self, tokens: Sequence[int]) -> str:
        return "".join(self.decode_token(int(t)) for t in tokens)


def render_chat(messages: List[dict]) -> str:
    """Flatten OpenAI ``messages`` into the serving prompt string.

    ChatML-style framing with a trailing assistant header the model
    "completes". Raises ``ValueError`` on malformed messages — the
    protocol layer maps that onto HTTP 400.
    """
    if not isinstance(messages, list) or not messages:
        raise ValueError("messages must be a non-empty list")
    parts = []
    for i, m in enumerate(messages):
        if not isinstance(m, dict):
            raise ValueError(f"messages[{i}] must be an object")
        role = m.get("role")
        content = m.get("content")
        if role not in CHAT_ROLES:
            raise ValueError(
                f"messages[{i}].role must be one of {CHAT_ROLES}, "
                f"got {role!r}")
        if not isinstance(content, str):
            raise ValueError(f"messages[{i}].content must be a string")
        parts.append(f"<|im_start|>{role}\n{content}<|im_end|>\n")
    parts.append("<|im_start|>assistant\n")
    return "".join(parts)
