"""Async OpenAI-compatible serving front end.

Stdlib-only (asyncio + json): :class:`ServerApp` binds an HTTP listener
over one :class:`EnginePump`, which owns an ``EngineCore`` and bridges
the event loop to the blocking step thread. See ``repro.server.http``
for the endpoint and error-mapping contract.
"""
from repro.server.chat import ByteTokenizer, render_chat
from repro.server.http import ServerApp
from repro.server.metrics import render_metrics
from repro.server.protocol import (ProtocolError, ServerDefaults,
                                   completion_json, chunk_json, error_json,
                                   models_json, parse_chat, parse_completion)
from repro.server.pump import EnginePump
from repro.server.sse import DONE_PAYLOAD, SSE_DONE, SSEParser, sse_event

__all__ = [
    "ByteTokenizer", "render_chat", "ServerApp", "render_metrics",
    "ProtocolError", "ServerDefaults", "completion_json", "chunk_json",
    "error_json", "models_json", "parse_chat", "parse_completion",
    "EnginePump", "DONE_PAYLOAD", "SSE_DONE", "SSEParser", "sse_event",
]
