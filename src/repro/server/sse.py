"""Server-sent-events framing: the OpenAI streaming wire format.

One event per engine delta, ``data: {json}\\n\\n``, terminated by the
literal ``data: [DONE]\\n\\n`` sentinel — exactly what OpenAI client
libraries parse. :class:`SSEParser` is the incremental decoder the
tests (and any raw-socket client) use to round-trip the framing: feed
it arbitrary byte chunks, get back complete event payload strings.
"""
from __future__ import annotations

import json
from typing import List, Union

DONE_PAYLOAD = "[DONE]"
SSE_DONE = b"data: [DONE]\n\n"


def sse_event(data: Union[dict, str]) -> bytes:
    """Frame one event. Dicts are JSON-encoded; strings pass through."""
    if not isinstance(data, str):
        data = json.dumps(data, separators=(",", ":"))
    return b"data: " + data.encode("utf-8") + b"\n\n"


class SSEParser:
    """Incremental SSE decoder over an arbitrary byte-chunk stream.

    Follows the event-stream grammar: events are separated by blank
    lines; each ``data:`` line contributes one line of the event's
    payload (multiple ``data:`` lines join with ``\\n``); comment lines
    (``:``) and unknown fields are ignored. ``feed`` returns the
    payloads of every event completed by the chunk.
    """

    def __init__(self):
        self._buf = b""

    def feed(self, chunk: bytes) -> List[str]:
        self._buf += chunk
        out = []
        while True:
            # events end at the first blank line (\n\n, tolerating \r\n)
            sep = self._buf.find(b"\n\n")
            sep_crlf = self._buf.find(b"\r\n\r\n")
            if sep_crlf != -1 and (sep == -1 or sep_crlf < sep):
                raw, self._buf = (self._buf[:sep_crlf],
                                  self._buf[sep_crlf + 4:])
            elif sep != -1:
                raw, self._buf = self._buf[:sep], self._buf[sep + 2:]
            else:
                return out
            datas = []
            for line in raw.decode("utf-8").splitlines():
                if line.startswith("data:"):
                    datas.append(line[5:].lstrip(" "))
            if datas:
                out.append("\n".join(datas))
