"""Stdlib-only asyncio HTTP/1.1 front end over one :class:`EnginePump`.

Deliberately minimal: ``asyncio.start_server``, hand-parsed request
head, ``Connection: close`` on every response (streaming bodies are
EOF-delimited, so no chunked-encoding machinery). What it is *not*
minimal about is the serving contract:

  * ``POST /v1/completions`` and ``POST /v1/chat/completions`` — OpenAI
    wire shapes, JSON or ``stream=true`` SSE (``data: {...}`` frames,
    ``data: [DONE]`` terminator).
  * Client disconnect mid-stream -> ``abort_request``: the handler races
    each delta against a socket-EOF watch, and a vanished client frees
    its slot and pages within one tick instead of generating to a dead
    socket.
  * Backpressure is status-coded: ``QueueFullError`` -> 429 with
    ``Retry-After``, ``CapacityError``/malformed bodies -> 400, watchdog
    expiries -> 200 with ``finish_reason: "timeout"``.
  * ``GET /metrics`` (Prometheus text), ``GET /v1/models``,
    ``GET /health``.

Every request is logged under its engine request id (``cmpl-{rid}``),
which is also the response ``id`` — one join key across client logs,
server logs, and engine traces.
"""
from __future__ import annotations

import asyncio
import json
import logging
import time
from typing import Optional, Tuple

from repro.serving.core import EngineCore
from repro.serving.request import CapacityError, QueueFullError
from repro.server.chat import ByteTokenizer
from repro.server.metrics import render_metrics
from repro.server.protocol import (ProtocolError, ServerDefaults, chunk_json,
                                   completion_json, error_json, models_json,
                                   parse_chat, parse_completion)
from repro.server.pump import EnginePump
from repro.server.sse import SSE_DONE, sse_event

log = logging.getLogger("repro.server")

MAX_BODY_BYTES = 1 << 20            # request bodies past 1 MiB -> 413
MAX_HEAD_BYTES = 16 << 10
RETRY_AFTER_S = 1                   # hint on 429; one tick is plenty


def _http_response(status: int, body: bytes,
                   content_type: str = "application/json",
                   extra_headers: Tuple[Tuple[str, str], ...] = ()) -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              405: "Method Not Allowed", 413: "Payload Too Large",
              429: "Too Many Requests", 500: "Internal Server Error"}
    head = [f"HTTP/1.1 {status} {reason.get(status, 'Error')}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(body)}",
            "Connection: close"]
    head += [f"{k}: {v}" for k, v in extra_headers]
    return ("\r\n".join(head) + "\r\n\r\n").encode("ascii") + body


def _json_response(status: int, obj: dict, **kw) -> bytes:
    return _http_response(status, json.dumps(obj).encode("utf-8"), **kw)


async def _watch_eof(reader: asyncio.StreamReader) -> None:
    """Resolve only when the client's end is truly gone (EOF or reset).

    Stray bytes after the request body — a trailing newline, a pipelined
    request the client will never get an answer to (every response is
    ``Connection: close``) — are read and discarded, NOT treated as a
    disconnect, so a healthy in-flight request is never aborted over
    them."""
    try:
        while await reader.read(256):
            pass
    except (ConnectionResetError, BrokenPipeError):
        pass


class ServerApp:
    """The OpenAI-compatible server: routes HTTP onto one engine core."""

    def __init__(self, core: EngineCore, model_id: str = "repro",
                 defaults: Optional[ServerDefaults] = None):
        self.core = core
        self.model_id = model_id
        self.defaults = defaults or ServerDefaults()
        self.tokenizer = ByteTokenizer(core.cfg.vocab_size)
        self.pump = EnginePump(core)
        self._server: Optional[asyncio.base_events.Server] = None

    # -- lifecycle ----------------------------------------------------------

    async def start(self, host: str = "127.0.0.1", port: int = 0) -> None:
        """Bind and start serving (``port=0`` -> ephemeral, see
        :attr:`port`). The pump starts with the listener so queued
        admissions begin ticking immediately."""
        self.pump.start()
        self._server = await asyncio.start_server(self._handle, host, port)
        log.info("serving %s on %s:%d", self.model_id, host, self.port)

    @property
    def port(self) -> int:
        assert self._server is not None
        return self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    async def stop(self) -> None:
        """Stop accepting, then drain: the pump aborts anything still in
        flight so shutdown never leaks pages."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        await self.pump.stop()

    # -- connection handling ------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await self._read_request(reader)
            if isinstance(parsed, bytes):       # pre-baked error response
                writer.write(parsed)
            else:
                method, path, body = parsed
                await self._route(method, path, body, reader, writer)
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError, asyncio.TimeoutError):
            pass                                # client went away; routes
            # that own a request already aborted it
        except Exception:                       # noqa: BLE001
            log.exception("unhandled error in connection handler")
            try:
                writer.write(_json_response(
                    500, error_json("internal server error", "server_error")))
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        """Parse one request; returns ``(method, path, body)`` or a
        ready-to-send error response."""
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except asyncio.LimitOverrunError:
            return _json_response(413, error_json("headers too large"))
        except asyncio.IncompleteReadError:
            raise ConnectionResetError from None
        if len(head) > MAX_HEAD_BYTES:
            return _json_response(413, error_json("headers too large"))
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) != 3:
            return _json_response(400, error_json("malformed request line"))
        method, path = parts[0].upper(), parts[1].split("?")[0]
        length = 0
        for line in lines[1:]:
            if line.lower().startswith("content-length:"):
                try:
                    length = int(line.split(":", 1)[1].strip())
                except ValueError:
                    return _json_response(
                        400, error_json("bad Content-Length"))
        if length > MAX_BODY_BYTES:
            return _json_response(413, error_json("request body too large"))
        body = await reader.readexactly(length) if length else b""
        return method, path, body

    async def _route(self, method: str, path: str, body: bytes,
                     reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        if path == "/health":
            writer.write(self._guard_method(method, "GET") or _json_response(
                200, {"status": "ok",
                      "unfinished": self.core.has_unfinished()}))
        elif path == "/v1/models":
            writer.write(self._guard_method(method, "GET") or _json_response(
                200, models_json(self.model_id, int(time.time()))))
        elif path == "/metrics":
            err = self._guard_method(method, "GET")
            writer.write(err or _http_response(
                200, render_metrics(self.core, self.model_id).encode("utf-8"),
                content_type="text/plain; version=0.0.4; charset=utf-8"))
        elif path in ("/v1/completions", "/v1/chat/completions"):
            err = self._guard_method(method, "POST")
            if err:
                writer.write(err)
            else:
                await self._completions(path.startswith("/v1/chat"), body,
                                        reader, writer)
        else:
            writer.write(_json_response(
                404, error_json(f"no route for {path}", code="not_found")))

    @staticmethod
    def _guard_method(method: str, want: str) -> Optional[bytes]:
        if method == want:
            return None
        return _json_response(
            405, error_json(f"method {method} not allowed"),
            extra_headers=(("Allow", want),))

    # -- the generation endpoints -------------------------------------------

    async def _completions(self, chat: bool, body: bytes,
                           reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        parse = parse_chat if chat else parse_completion
        try:
            obj = json.loads(body.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            writer.write(_json_response(
                400, error_json("request body is not valid JSON")))
            return
        try:
            request, stream = parse(obj, self.tokenizer, self.defaults)
            rid, deltas = self.pump.submit(request)
        except ProtocolError as e:
            writer.write(_json_response(400, error_json(str(e), code=e.code)))
            return
        except QueueFullError as e:
            log.warning("admission rejected (queue full): %s", e)
            writer.write(_json_response(
                429, error_json(str(e), "rate_limit_error", "queue_full"),
                extra_headers=(("Retry-After", str(RETRY_AFTER_S)),)))
            return
        except CapacityError as e:
            log.warning("admission rejected (capacity): %s", e)
            writer.write(_json_response(
                400, error_json(str(e), code="capacity")))
            return
        except ValueError as e:                 # e.g. duplicate request_id
            writer.write(_json_response(400, error_json(str(e))))
            return

        req_id = f"{'chatcmpl' if chat else 'cmpl'}-{rid}"
        created = int(time.time())
        log.info("%s: %d prompt tokens, stream=%s", req_id,
                 request.prompt_len, stream)
        if stream:
            await self._stream_response(req_id, rid, created, chat, deltas,
                                        reader, writer)
        else:
            await self._collect_response(req_id, rid, created, chat,
                                         request.prompt_len, deltas,
                                         reader, writer)

    async def _collect_response(self, req_id: str, rid: int, created: int,
                                chat: bool, prompt_tokens: int,
                                deltas: asyncio.Queue,
                                reader: asyncio.StreamReader,
                                writer: asyncio.StreamWriter) -> None:
        # same disconnect contract as the streaming path: a client that
        # vanishes mid-generation aborts within one tick instead of
        # holding its slot and pages until the response it will never
        # read completes
        eof = asyncio.ensure_future(_watch_eof(reader))
        tokens, reason, error = [], None, None
        try:
            while True:
                getter = asyncio.ensure_future(deltas.get())
                done, _ = await asyncio.wait(
                    {getter, eof}, return_when=asyncio.FIRST_COMPLETED)
                if getter not in done:          # disconnect won the race
                    getter.cancel()
                    log.info("%s: client disconnected, aborting", req_id)
                    self.pump.abort(rid)
                    return
                ro = getter.result()
                if ro is None:
                    break
                tokens.extend(ro.new_tokens)
                if ro.finished:
                    reason, error = ro.finish_reason, ro.error
        finally:
            eof.cancel()
        text = self.tokenizer.decode(tokens)
        log.info("%s: finished %s, %d tokens", req_id, reason, len(tokens))
        writer.write(_json_response(200, completion_json(
            req_id, self.model_id, created, text, tokens, reason, error,
            prompt_tokens, chat)))

    async def _stream_response(self, req_id: str, rid: int, created: int,
                               chat: bool, deltas: asyncio.Queue,
                               reader: asyncio.StreamReader,
                               writer: asyncio.StreamWriter) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        # Socket-EOF watch: resolves only on a real close/reset — stray
        # client bytes after the body are discarded, not misread as a
        # disconnect.
        eof = asyncio.ensure_future(_watch_eof(reader))
        first = True
        try:
            while True:
                getter = asyncio.ensure_future(deltas.get())
                done, _ = await asyncio.wait(
                    {getter, eof}, return_when=asyncio.FIRST_COMPLETED)
                if getter not in done:          # disconnect won the race
                    getter.cancel()
                    log.info("%s: client disconnected, aborting", req_id)
                    self.pump.abort(rid)
                    return
                ro = getter.result()
                if ro is None:
                    writer.write(SSE_DONE)
                    await writer.drain()
                    return
                text = self.tokenizer.decode(ro.new_tokens)
                writer.write(sse_event(chunk_json(
                    req_id, self.model_id, created, text,
                    list(ro.new_tokens), ro.finish_reason, ro.error, chat,
                    first)))
                first = False
                await writer.drain()
                if ro.finished:
                    log.info("%s: finished %s, %d tokens", req_id,
                             ro.finish_reason, ro.num_generated)
        except (ConnectionResetError, BrokenPipeError):
            # write-side detection of the same disconnect
            log.info("%s: connection reset, aborting", req_id)
            self.pump.abort(rid)
        finally:
            eof.cancel()
