"""Prometheus text-format rendering of :class:`EngineStats`.

One flat exposition (text format 0.0.4) over the core's live stats
object — the stats are mutated in place by the step thread, so a scrape
always sees current values with no snapshotting machinery. Counters
carry the robustness story (aborted / expired / rejected / nan_isolated
/ preemption_retries / step_failures); the TTFT and request-latency
summaries export p50/p95 over the per-finish tick histograms, because a
mean hides exactly the tail a serving dashboard exists to show.

All durations are in *engine ticks* (one ``step()`` each), matching the
engine's deterministic clock; ``repro_engine_wall_seconds`` anchors
ticks to wall time.
"""
from __future__ import annotations

from typing import List, Optional

from repro.serving.core import EngineCore


def _metric(lines: List[str], name: str, help_: str, mtype: str,
            value, labels: str = "") -> None:
    lines.append(f"# HELP {name} {help_}")
    lines.append(f"# TYPE {name} {mtype}")
    lines.append(f"{name}{labels} {_fmt(value)}")


def _fmt(value) -> str:
    if isinstance(value, bool):
        return "1" if value else "0"
    if isinstance(value, int):
        return str(value)
    return repr(float(value))


def render_metrics(core: EngineCore,
                   model_id: Optional[str] = None) -> str:
    """The full ``/metrics`` payload for one engine core."""
    s = core.stats
    out: List[str] = []
    if model_id is not None:
        out.append("# HELP repro_build_info Serving front end identity.")
        out.append("# TYPE repro_build_info gauge")
        out.append('repro_build_info{model="%s"} 1' % model_id)

    # throughput counters
    _metric(out, "repro_engine_decode_steps_total",
            "Batched decode ticks executed.", "counter", s.decode_steps)
    _metric(out, "repro_engine_generated_tokens_total",
            "Tokens generated (prefill-sampled + decode).", "counter",
            s.generated_tokens)
    _metric(out, "repro_engine_prefill_tokens_total",
            "Prompt tokens actually prefilled (cache hits excluded).",
            "counter", s.prefill_tokens)
    _metric(out, "repro_engine_cached_prefix_tokens_total",
            "Prompt tokens served from the prefix cache.", "counter",
            s.cached_prefix_tokens)

    # robustness counters (the PR 7 hardening story)
    _metric(out, "repro_requests_aborted_total",
            "Requests cancelled by the caller (incl. client disconnects).",
            "counter", s.aborted)
    _metric(out, "repro_requests_expired_total",
            "Requests terminated by a deadline/queue-timeout watchdog.",
            "counter", s.expired)
    _metric(out, "repro_requests_rejected_total",
            "Admissions refused (bounded queue full, capacity fail-fast).",
            "counter", s.rejected)
    _metric(out, "repro_requests_nan_isolated_total",
            "Requests finished ERROR by the non-finite-logit guard.",
            "counter", s.nan_isolated)
    _metric(out, "repro_preemption_retries_total",
            "Re-admissions of previously preempted requests.", "counter",
            s.preemption_retries)
    _metric(out, "repro_step_failures_total",
            "Decode launches that raised (batch finished ERROR).", "counter",
            s.step_failures)
    _metric(out, "repro_preemptions_total",
            "Requests evicted to free cache pages.", "counter",
            s.preemptions)

    # capacity gauges
    _metric(out, "repro_pages", "Page-pool size (0 on the slot backend).",
            "gauge", s.num_pages)
    _metric(out, "repro_pages_in_use",
            "Pages currently allocated from the pool.", "gauge",
            int(getattr(core.pool, "pages_in_use", 0)))
    _metric(out, "repro_page_utilization",
            "Mean fraction of the page pool in use across decode steps.",
            "gauge", s.page_utilization)
    _metric(out, "repro_peak_pages",
            "High-water mark of pages in use.", "gauge", s.peak_pages)
    denom = s.cached_prefix_tokens + s.prefill_tokens
    _metric(out, "repro_prefix_hit_ratio",
            "Fraction of prompt tokens served from the prefix cache.",
            "gauge", (s.cached_prefix_tokens / denom) if denom else 0.0)
    _metric(out, "repro_max_prefill_tokens_per_step",
            "Most prefill tokens one tick computed (admission-stall bound).",
            "gauge", s.max_prefill_tokens_per_step)
    _metric(out, "repro_engine_wall_seconds",
            "Wall-clock seconds the engine has spent ticking.", "gauge",
            s.wall_seconds)

    # latency summaries, in engine ticks
    _summary(out, "repro_ttft_steps",
             "Submit-to-first-token, in engine ticks.",
             s.ttft_hist, s.ttft_p50, s.ttft_p95)
    _summary(out, "repro_request_latency_steps",
             "Submit-to-finish, in engine ticks.",
             s.latency_hist, s.latency_p50, s.latency_p95)
    return "\n".join(out) + "\n"


def _summary(lines: List[str], name: str, help_: str, hist: List[int],
             p50: float, p95: float) -> None:
    lines.append(f"# HELP {name} {help_}")
    lines.append(f"# TYPE {name} summary")
    lines.append('%s{quantile="0.5"} %s' % (name, _fmt(p50)))
    lines.append('%s{quantile="0.95"} %s' % (name, _fmt(p95)))
    lines.append(f"{name}_sum {_fmt(float(sum(hist)))}")
    lines.append(f"{name}_count {len(hist)}")
