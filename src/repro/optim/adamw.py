"""AdamW + LR schedules in pure JAX (no optax dependency).

Includes the WSD (Warmup-Stable-Decay) schedule from MiniCPM
(arXiv:2404.06395) — that architecture's training-side contribution —
alongside the standard cosine schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any

    def tree_flatten(self):
        return (self.step, self.m, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    clip = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, stable: int, decay: int,
                 min_frac: float = 0.1) -> Callable:
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat plateau, then a
    short exponential-style decay to min_frac * base_lr."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = base_lr * (min_frac ** prog)
        return jnp.where(step < warmup, warm,
                         jnp.where(step < warmup + stable, base_lr, dec))
    return lr
