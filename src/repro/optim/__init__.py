from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               cosine_schedule, wsd_schedule)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "wsd_schedule"]
