from repro.distributed.fault_tolerance import (FaultTolerantRunner,
                                               Preemption, RunnerConfig)

__all__ = ["FaultTolerantRunner", "Preemption", "RunnerConfig"]
