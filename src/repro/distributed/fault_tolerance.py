"""Fault tolerance & elasticity for the training loop.

What this module implements (and how it maps to a 1000+-node cluster):

  * **Checkpoint/restart** — the runner wraps the train loop; any failure
    (preemption signal, worker exception, NaN loss) rolls back to the last
    committed checkpoint and resumes, including the data-stream cursor and
    the LR-schedule step. On a real cluster the same loop runs under
    ``jax.distributed`` with a coordinator; restart re-runs the launcher
    which re-executes ``train.py --resume``.
  * **Preemption handling** — SIGTERM triggers an immediate out-of-cadence
    checkpoint before exit (GCP/TPU preemption gives ~30s notice).
  * **NaN/divergence quarantine** — a NaN or exploding loss is treated as a
    soft failure: roll back one checkpoint and continue with a fresh data
    shard order (skip_batches), the standard mitigation for data-induced
    spikes at scale.
  * **Elastic scaling** — checkpoints are mesh-agnostic (host-sharded npz
    keyed by tree path; see checkpoint/store.py), so resuming on a larger
    or smaller ``data`` axis works: the runner recomputes shardings from
    the new mesh and ``device_put``s accordingly. The ``pod`` axis extends
    DP, so pod loss = DP-degree change, not a topology change.
  * **Straggler mitigation** — synchronous SPMD cannot drop stragglers
    mid-step; the production posture is (a) per-step watchdog timing,
    (b) replace-and-restart from checkpoint when a host is persistently
    slow, and (c) the dry-run's collective schedule keeps cross-pod
    traffic to one gradient all-reduce per step so slow DCN links bound
    only that phase. The watchdog hook below records step-time outliers.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class Preemption(Exception):
    """Raised co-operatively when a preemption signal arrives."""


@dataclasses.dataclass
class RunnerConfig:
    max_steps: int = 1000
    checkpoint_interval: int = 100
    nan_patience: int = 1          # rollbacks before giving up
    loss_explosion: float = 1e4
    watchdog_factor: float = 3.0   # step slower than factor x median = straggler


class FaultTolerantRunner:
    """Wraps (train_step, state, stream) with checkpoint/restart semantics."""

    def __init__(self, manager: CheckpointManager, cfg: RunnerConfig):
        self.manager = manager
        self.cfg = cfg
        self.preempted = False
        self.step_times: List[float] = []
        self.events: List[Dict] = []
        self._old_handler = None

    # -- signal handling -------------------------------------------------
    def install_signal_handler(self) -> None:
        def _handler(signum, frame):
            self.preempted = True
        self._old_handler = signal.signal(signal.SIGTERM, _handler)

    # -- watchdog ----------------------------------------------------------
    def record_step_time(self, dt: float) -> Optional[str]:
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = float(np.median(self.step_times[-50:]))
            if dt > self.cfg.watchdog_factor * med:
                self.events.append({"kind": "straggler", "dt": dt, "median": med})
                return f"straggler step: {dt:.3f}s vs median {med:.3f}s"
        return None

    # -- main loop ---------------------------------------------------------
    def run(self, train_step: Callable, params: Any, opt_state: Any,
            stream, batch_fn: Callable[[Any], Dict],
            inject_failure_at: Optional[int] = None) -> Dict:
        """Run to max_steps with checkpoint/restart. ``batch_fn(stream)``
        pulls the next batch; ``inject_failure_at`` is for tests."""
        cfg = self.cfg
        start = self.manager.latest_step() or 0
        if start:
            params, opt_state, meta = self.manager.restore(params, opt_state)
            stream.load_state(meta["extra"]["stream"])
            self.events.append({"kind": "resume", "step": start})
        step = start
        nan_budget = cfg.nan_patience
        losses = []
        while step < cfg.max_steps:
            if self.preempted:
                self.manager.save(step, params, opt_state,
                                  extra={"stream": stream.state_dict()})
                self.events.append({"kind": "preempt-save", "step": step})
                raise Preemption(f"preempted at step {step}")
            t0 = time.time()
            batch = batch_fn(stream)
            try:
                if inject_failure_at is not None and step == inject_failure_at:
                    inject_failure_at = None
                    raise RuntimeError("injected worker failure")
                params, opt_state, metrics = train_step(params, opt_state, batch)
                loss = float(metrics["loss"])
            except (FloatingPointError, RuntimeError) as e:
                self.events.append({"kind": "failure", "step": step,
                                    "error": str(e)})
                last = self.manager.latest_step()
                if last is None:
                    raise
                params, opt_state, meta = self.manager.restore(params, opt_state)
                stream.load_state(meta["extra"]["stream"])
                step = last
                continue
            if not np.isfinite(loss) or loss > cfg.loss_explosion:
                self.events.append({"kind": "nan", "step": step, "loss": loss})
                if nan_budget <= 0:
                    raise FloatingPointError(f"divergence at step {step}")
                nan_budget -= 1
                last = self.manager.latest_step()
                if last is not None:
                    params, opt_state, meta = self.manager.restore(params, opt_state)
                    st = meta["extra"]["stream"]
                    st = {**st, "step": st["step"] + 1}   # skip the bad batch
                    stream.load_state(st)
                    step = last
                    continue
            losses.append(loss)
            step += 1
            warn = self.record_step_time(time.time() - t0)
            if self.manager.should_save(step):
                self.manager.save(step, params, opt_state,
                                  extra={"stream": stream.state_dict()})
        return {"params": params, "opt_state": opt_state, "losses": losses,
                "events": self.events, "final_step": step}
