# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness. Run everything: PYTHONPATH=src python -m benchmarks.run

Paper artifact -> module map:
  Tables 1/2 (methods x models)     accuracy (nvfp4)
  Table 6 (INT4/MXFP4 generality)   accuracy (--full adds int4, mxfp4)
  Figures 2/3 (Hadamard vs ARC MSE) layerwise_mse
  Table 4 (quantization overhead)   quant_overhead
  Table 5 (calibration robustness)  calibration_robustness
  Figure 7 (S per layer)            outlier_stats
  Figure 8a (latency vs S)          latency_vs_s
  Table 8 / Fig 6 (prefill)         prefill_model (TPU roofline translation)
  Section 3.4 (error bounds)        error_bounds
  Figure 5 (deployment/serving)     continuous_batching (vs static batching)
  Deployed kernels (fused epilogue, deployed_serving (interpret-mode A/B)
    residency, backend parity)
  Prefix caching + dropless MoE     prefix_caching
  Dry-run roofline (deliverable g)  roofline (reads results/dryrun)
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run int4/mxfp4 accuracy sweeps (slow)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (accuracy, calibration_robustness,
                            continuous_batching, deployed_serving,
                            error_bounds, latency_vs_s, layerwise_mse,
                            outlier_stats, prefill_model, prefix_caching,
                            quant_overhead, robustness, roofline)

    jobs = [
        ("continuous_batching", lambda: continuous_batching.run()),
        ("deployed_serving", lambda: deployed_serving.run(interpret=True,
                                                          smoke=True)),
        ("prefix_caching", lambda: (prefix_caching.run(),
                                    prefix_caching.run_moe())),
        ("robustness", lambda: robustness.run()),
        ("error_bounds", lambda: error_bounds.run()),
        ("latency_vs_s", lambda: latency_vs_s.run()),
        ("prefill_model", lambda: prefill_model.run()),
        ("accuracy", lambda: accuracy.run(
            formats=("nvfp4", "mxfp4", "int4") if args.full else ("nvfp4",))),
        ("layerwise_mse", lambda: layerwise_mse.run()),
        ("outlier_stats", lambda: outlier_stats.run()),
        ("calibration_robustness", lambda: calibration_robustness.run()),
        ("quant_overhead", lambda: quant_overhead.run()),
        ("roofline", lambda: roofline.run()),
    ]
    failed = []
    for name, fn in jobs:
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == '__main__':
    main()
