"""Accuracy impact of activation-scale granularity in deployed serving.

The serving engine quantizes activations online; ``QuantConfig.act_scale``
picks the FP32 scale granularity (paper App. D):

  * ``"token"``      — per-token absmax, computed on the fly. Batch-
                       invariant, but each token re-derives its scale.
  * ``"calibrated"`` — static per-layer tensor scales captured at
                       calibration time (the one-pass deployed config the
                       fused Pallas kernel consumes).

This measures what that choice costs on tiny trained proxies:

  * **logit error** vs the unquantized model on held-out batches (mean
    absolute error over the vocab + top-1 next-token agreement);
  * **greedy divergence** between the two granularities when serving the
    same workload (fraction of requests whose full greedy trace is
    identical, and the mean first-divergence index among requests that
    do diverge).

The numbers are recorded in the README's serving notes.

Run: PYTHONPATH=src python -m benchmarks.act_scale_accuracy [--smoke]
"""
from __future__ import annotations

import argparse
import copy

import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuantConfig
from repro.models import forward
from repro.quant import quantize_weights_for_serving
from repro.serving import Request, ServingEngine
from benchmarks.common import emit, plans_for, trained_proxy


def logit_metrics(cfg, params, qparams, plans, data, act_scale: str,
                  n_batches: int = 3):
    """Mean |logit error| and top-1 next-token agreement vs unquantized."""
    quant = QuantConfig(method="arc", act_scale=act_scale)
    errs, agree, n = [], 0, 0
    for toks in data.eval_batches(2, 48, n_batches):
        t = jnp.asarray(toks)
        ref, _, _ = forward(params, cfg, tokens=t)
        got, _, _ = forward(qparams, cfg, tokens=t, quant=quant, plans=plans)
        ref = np.asarray(ref[..., : cfg.vocab_size], np.float32)
        got = np.asarray(got[..., : cfg.vocab_size], np.float32)
        errs.append(np.mean(np.abs(got - ref)))
        agree += int(np.sum(got.argmax(-1) == ref.argmax(-1)))
        n += ref.shape[0] * ref.shape[1]
    return float(np.mean(errs)), agree / n


def greedy_divergence(cfg, qparams, quant, plans, n_requests: int = 8,
                      seed: int = 0):
    """Serve one workload under both granularities; compare traces."""
    rng = np.random.default_rng(seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(6, 20)))
                    .astype(np.int32),
                    max_new_tokens=12) for _ in range(n_requests)]
    traces = {}
    for act_scale in ("token", "calibrated"):
        eng = ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                            max_len=48, act_scale=act_scale)
        served = eng.run(copy.deepcopy(reqs))
        traces[act_scale] = [r.out_tokens for r in served]
    same = [a == b for a, b in zip(traces["token"], traces["calibrated"])]
    first_div = []
    for a, b in zip(traces["token"], traces["calibrated"]):
        for i, (x, y) in enumerate(zip(a, b)):
            if x != y:
                first_div.append(i)
                break
    return sum(same) / len(same), first_div


def run(arch: str = "qwen2-1.5b", layers: int = 2, n_requests: int = 8):
    cfg, params, data = trained_proxy(arch, layers=layers)
    quant = QuantConfig(method="arc")
    plans = plans_for(cfg, params, data, quant)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)

    out = {}
    for act_scale in ("token", "calibrated"):
        mae, top1 = logit_metrics(cfg, params, qparams, plans, data,
                                  act_scale)
        emit(f"act_scale_{act_scale}", 0.0,
             f"logit_mae={mae:.4f} top1_agreement={top1:.4f}")
        out[act_scale] = (mae, top1)

    frac_same, first_div = greedy_divergence(cfg, qparams, quant, plans,
                                             n_requests=n_requests)
    div = (f" first_divergence_mean={np.mean(first_div):.1f}"
           if first_div else "")
    emit("act_scale_greedy_divergence", 0.0,
         f"identical_traces={frac_same:.2f}{div} "
         f"(token vs calibrated, {n_requests} requests x 12 tokens)")
    return out, frac_same


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    if args.smoke:
        args.requests = 4
    run(arch=args.arch, n_requests=args.requests)


if __name__ == "__main__":
    main()
