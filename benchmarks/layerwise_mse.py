"""Paper Figures 2/3: per-layer quantization MSE — RTN vs Hadamard vs ARC.

Reproduces the motivation result: the Hadamard rotation *spreads* outlier
magnitude into every 16-element block (raising quiet-block dynamic range),
so on NVFP4 it fails to beat RTN, while ARC's targeted residual
compensation suppresses the error on every layer.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import arc as ARC, baselines as BL, quant as Q
from repro.models import capture_stats, forward
from benchmarks.common import emit, trained_proxy


def collect_linear_inputs(cfg, params, toks):
    """Per-layer activation matrices via the capture plumbing + a manual
    forward that records the actual inputs (absmax only is not enough for
    MSE, so we re-run the layer inputs here for the mlp projections)."""
    # use embeddings output as a representative activation + capture stats
    stats = capture_stats(params, cfg, tokens=toks)
    return stats


def run():
    cfg, params, data = trained_proxy()
    toks = jnp.asarray(data.eval_batches(2, 64, 1)[0])

    # real activations at the o_proj input (paper Fig. 2 uses o_proj):
    # reconstruct by running the model and grabbing hidden states as proxy.
    hidden, _, _ = forward(params, cfg, tokens=toks, compute_logits=False)
    x = np.asarray(hidden.reshape(-1, cfg.d_model), np.float32)
    w = np.asarray(params["blocks"][0]["mlp"]["w_gate"][0], np.float32)

    y_fp = x @ w.T
    h = BL.hadamard_matrix(x.shape[-1])

    def mse(y):
        return float(np.mean((np.asarray(y) - y_fp) ** 2))

    rtn = mse(BL.rtn_matmul(jnp.asarray(x), jnp.asarray(w)))
    had = mse(BL.quarot_matmul(jnp.asarray(x), jnp.asarray(w)))
    plan = ARC.select_outliers(np.abs(x).max(0))
    arc = mse(ARC.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan))
    emit("layerwise_mse/rtn", 0.0, f"mse={rtn:.5f}")
    emit("layerwise_mse/hadamard", 0.0, f"mse={had:.5f}")
    emit("layerwise_mse/arc", 0.0, f"mse={arc:.5f}")

    # block dynamic-range spreading (Fig. 2): median quiet-block amax
    def med_block_amax(z):
        zb = np.abs(z.reshape(z.shape[0], -1, 16)).max(-1)
        return float(np.median(zb))
    emit("blockrange/original", 0.0, f"median_amax={med_block_amax(x):.4f}")
    emit("blockrange/hadamard", 0.0,
         f"median_amax={med_block_amax(x @ h):.4f}")

    # --- the paper's regime: activations with strong outlier channels ----
    # (full-size LLMs develop these; the tiny proxy does not, so inject the
    # documented structure and show QuaRot's regression vs RTN — Table 2)
    xo = x.copy()
    cols = np.random.default_rng(0).choice(x.shape[-1], 6, replace=False)
    xo[:, cols] *= 30.0
    y_fp_o = xo @ w.T

    def mse_o(y):
        return float(np.mean((np.asarray(y) - y_fp_o) ** 2))
    rtn_o = mse_o(BL.rtn_matmul(jnp.asarray(xo), jnp.asarray(w)))
    had_o = mse_o(BL.quarot_matmul(jnp.asarray(xo), jnp.asarray(w)))
    plan_o = ARC.select_outliers(np.abs(xo).max(0))
    arc_o = mse_o(ARC.fake_quant_matmul(jnp.asarray(xo), jnp.asarray(w), plan_o))
    emit("layerwise_mse_outlier/rtn", 0.0, f"mse={rtn_o:.5f}")
    emit("layerwise_mse_outlier/hadamard", 0.0, f"mse={had_o:.5f}")
    emit("layerwise_mse_outlier/arc", 0.0, f"mse={arc_o:.5f}")
    emit("blockrange_outlier/original", 0.0,
         f"median_amax={med_block_amax(xo):.4f}")
    emit("blockrange_outlier/hadamard", 0.0,
         f"median_amax={med_block_amax(xo @ h):.4f}")
    return {"rtn": rtn, "hadamard": had, "arc": arc,
            "rtn_outlier": rtn_o, "hadamard_outlier": had_o,
            "arc_outlier": arc_o}


if __name__ == "__main__":
    run()
