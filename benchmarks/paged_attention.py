"""Paged-attention decode: Pallas kernel vs the jnp gather fallback.

The gather path materializes each decode tick's logical K/V view —
``ck[block_table]`` builds a ``(B, max_blocks * block_size)`` copy of
every resident token before a single score is computed. The kernel walks
the block table *inside* the Pallas launch (scalar-prefetch index maps),
so per tick it streams exactly the pages the tables name.

Two engine variants (``attn_kernel=True`` / ``False``) serve identical
workloads at 1 / 8 / 32 concurrently-decoding residents:

  * decode tokens/s per variant (greedy parity asserted at every
    residency — the kernel is a pure dataflow change);
  * HBM K/V bytes per decode tick: the gather path touches the full
    ``B * max_blocks`` logical view every tick regardless of residency,
    the kernel streams only the pages the tables actually name —
    asserted strictly smaller whenever the pool is not fully packed;
  * the compiled ``decode_paged`` HLO is checked (rule R2 of
    ``repro.analysis``) to contain *no* ``(B, nblocks*block_size, Hkv,
    D)`` tensor on the kernel path — the materialization the gather
    path demonstrably builds.

Timing numbers on a CPU host run the kernel in interpret mode (a jnp
emulation of the grid — also why the whole-tick ``analyze_hlo`` byte
totals are emitted as informational only: the emulation loop re-charges
the pool per grid cell), so wall-clock speedup is only meaningful on
TPU; the view-bytes comparison and the HLO shape check are
backend-independent and are what this benchmark asserts.

Run: PYTHONPATH=src python -m benchmarks.paged_attention [--smoke]
"""
from __future__ import annotations

import argparse
import copy

import numpy as np

from repro.analysis import build_artifact, run_rules
from repro.configs.base import FULL_ATTN, LOCAL_ATTN, QuantConfig
from repro.launch.hlo_analysis import analyze_hlo
from repro.quant import quantize_weights_for_serving
from repro.serving import PagedServingEngine, Request
from benchmarks.common import emit, plans_for, trained_proxy

BLOCK_SIZE = 16


def lockstep_workload(vocab: int, n: int, gen: int, seed: int = 0):
    """n same-shape requests: every decode tick has exactly n residents."""
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab, 8).astype(np.int32),
                    max_new_tokens=gen) for _ in range(n)]


def decode_tick_artifact(engine):
    """One decode tick (the ``decode_paged`` jit) lowered, compiled, and
    packaged with rule metadata by ``repro.analysis``."""
    return build_artifact(engine, "decode_paged", include_jaxpr=False)


def kv_tick_bytes(cfg, positions: int) -> int:
    """bf16 K+V bytes one decode tick reads for ``positions`` cache
    positions across the paged attention layers."""
    n = sum(1 for m in cfg.mixer_pattern if m in (FULL_ATTN, LOCAL_ATTN))
    n *= cfg.num_periods
    return positions * cfg.num_kv_heads * cfg.head_dim * 2 * 2 * n


def gathered_view_findings(artifact):
    """R2 (no-gathered-kv-view) findings for one decode-tick artifact —
    the single source of truth for the view-shape check, shared with the
    test suite and the CI lint gate."""
    return run_rules(artifact.context(), only=["R2"])


def run(residents=(1, 8, 32), gen: int = 16, seed: int = 0):
    cfg, params, data = trained_proxy("qwen2-1.5b", layers=2)
    quant = QuantConfig(method="arc")
    plans = plans_for(cfg, params, data, quant)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    slots = max(residents)
    engines = {
        "kernel": PagedServingEngine(qparams, cfg, quant, plans,
                                     batch_size=slots, max_len=64,
                                     block_size=BLOCK_SIZE),
        "gather": PagedServingEngine(qparams, cfg, quant, plans,
                                     batch_size=slots, max_len=64,
                                     block_size=BLOCK_SIZE,
                                     attn_kernel=False),
    }

    # --- decode throughput, parity, and per-tick K/V traffic -------------
    max_blocks = 64 // BLOCK_SIZE
    # the gather path builds the full logical view every tick no matter
    # how few requests are resident
    view_bytes = kv_tick_bytes(cfg, slots * max_blocks * BLOCK_SIZE)
    for n in residents:
        tokens = {}
        for name, eng in engines.items():
            reqs = lockstep_workload(cfg.vocab_size, n, gen, seed)
            served = eng.run(copy.deepcopy(reqs))
            s = eng.last_stats
            tps = s.decode_tokens / max(s.wall_seconds, 1e-9)
            emit(f"paged_attn_{name}_r{n}", s.wall_seconds * 1e6,
                 f"residents={n} decode_tokens={s.decode_tokens} "
                 f"steps={s.decode_steps} tokens_per_s={tps:.1f}")
            tokens[name] = [r.out_tokens for r in served]
        assert tokens["kernel"] == tokens["gather"], \
            f"kernel changed greedy tokens at {n} residents"
        # the kernel streams only the pages the n residents' tables name
        # (their final-tick footprint: prompt + full generation)
        blocks = -(-(8 + gen) // BLOCK_SIZE)
        stream_bytes = kv_tick_bytes(cfg, n * blocks * BLOCK_SIZE)
        assert stream_bytes <= view_bytes
        if n * blocks < slots * max_blocks:
            assert stream_bytes < view_bytes, \
                "partially-resident pool should stream fewer bytes"
        emit(f"paged_attn_tick_kv_bytes_r{n}", 0.0,
             f"kernel={stream_bytes} gather={view_bytes} "
             f"({view_bytes / stream_bytes:.2f}x less per-tick K/V "
             f"traffic at {n}/{slots} residents)")

    # --- HLO shape check: the kernel tick never materializes the view ---
    arts = {name: decode_tick_artifact(eng) for name, eng in engines.items()}
    assert gathered_view_findings(arts["gather"]), \
        "gather path no longer materializes the logical K/V view?"
    kernel_findings = gathered_view_findings(arts["kernel"])
    assert not kernel_findings, \
        "kernel decode tick materializes the gathered K/V view:\n" + \
        "\n".join(str(f) for f in kernel_findings)
    analyzed = {name: analyze_hlo(art.compiled_text)["bytes"]
                for name, art in arts.items()}
    emit("paged_attn_hlo", 0.0,
         f"no (B,{max_blocks * BLOCK_SIZE},Hkv,D) view in the kernel "
         f"tick HLO; analyze_hlo totals "
         f"kernel={analyzed['kernel']:.0f} gather={analyzed['gather']:.0f} "
         f"(informational: CPU interpret emulation re-charges the pool "
         f"per grid cell)")
    return view_bytes / kv_tick_bytes(
        cfg, max(residents) * -(-(8 + gen) // BLOCK_SIZE) * BLOCK_SIZE)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal workload for the CI time budget")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    residents = (1, 4) if args.smoke else (1, 8, 32)
    run(residents=residents, gen=4 if args.smoke else args.gen)


if __name__ == "__main__":
    main()
