"""Deployed serving path: reference (emulated) vs pallas (fused kernels).

Measures, for the continuous-batching engine over ARC-quantized packed
NVFP4 weights:

  * per-layer GEMM latency at the two serving shapes — prefill (M=512)
    and decode (M=active slots) — for both backends
  * end-to-end engine throughput (tokens/sec, per-decode-step latency)
  * the decode fast path's weight-decode saving: `gemm_plan` reports how
    many (bn, bk) weight tiles each schedule dequantizes, and the same
    GEMM is timed on the fast schedule vs forced onto the generic one
    (small block_m => multiple i tiles => per-i re-decode)
  * the fused swiglu epilogue: one dual-weight `nvfp4_gemm_swiglu`
    launch vs two GEMMs + an XLA-level silu(g)*u — per-shape latency,
    plan-derived HBM bytes and weight/activation decode counts, bitwise
    parity asserted
  * decode weight-tile residency: the resident schedule (activation
    decoded once, tiles held across (j, k)) vs the streamed schedule at
    the same decode shape
  * engine A/B with ``fuse_epilogue`` on vs off (greedy tokens must
    match bitwise)

    PYTHONPATH=src python -m benchmarks.deployed_serving --interpret
    PYTHONPATH=src python -m benchmarks.deployed_serving --interpret --smoke

On a CPU box ``--interpret`` runs the Pallas kernels bit-faithfully
(slowly); on a TPU drop it for compiled kernel timings. Results emit via
benchmarks.common.emit so the perf trajectory is tracked.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.kernels import ops as KOPS
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import (gemm_plan, nvfp4_gemm,
                                      nvfp4_gemm_swiglu, swiglu_plan)
from repro.models import capture_stats, init_params
from repro.models.layers import _swiglu
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import Request, ServingEngine

from benchmarks.common import emit, timeit


def build(arch: str, layers: int, seed: int = 0):
    cfg = ARCHS[arch].reduced(layers=layers)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (2, 64), 0,
                              cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


def bench_layer_gemm(plans, qparams, interpret: bool, shapes, iters: int):
    """One ARC linear (mlp.w_gate) at serving M shapes, both backends."""
    name = "b0.mlp.w_gate"
    w = qparams["blocks"][0]["mlp"]["w_gate"]
    # period-0 slice of the stacked plan arrays
    order = plans.arrays[name]["order"][0]
    ts = plans.arrays[name]["act_scales"][0]
    s = plans.meta[name]
    k = int(order.shape[-1])
    w0 = jax.tree.map(lambda l: l[0], w)
    wc, ws, wt, packed = KOPS.qtensor_gemm_operands(w0)
    gamma = jnp.ones((k,), jnp.float32)
    rng = np.random.default_rng(0)

    from repro.core import quant as Q
    from repro.core import arc as ARC

    for label, m in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))

        def pallas_fn(xv):
            return KOPS.arc_linear(xv, gamma, order, wc, ws, ts, s,
                                   w_tensor_scale=wt, w_packed=packed,
                                   apply_norm=False, interpret=interpret)

        @jax.jit
        def ref_fn(xv):
            xr = jnp.take(xv, order, axis=-1)
            xq = Q.quantize(xr, "nvfp4", tensor_scale=ts[0])
            if s:
                r_o = xr[..., :s] - xq.dequantize()[..., :s]
                rq = Q.quantize(r_o, "nvfp4", tensor_scale=ts[1])
                xq = ARC.to_interleaved(Q.concat_k(xq, rq), k, s)
            return Q.qmatmul(xq, w0)

        us_p = timeit(pallas_fn, x, iters=iters)
        us_r = timeit(ref_fn, x, iters=iters)
        emit(f"deployed_gemm_{label}_pallas", us_p,
             f"M={m} K={k} S={s} packed={packed}")
        emit(f"deployed_gemm_{label}_reference", us_r, f"M={m} K={k} S={s}")

    return wc, ws, wt, packed, order, ts, s, k


def bench_decode_fast_path(wc, ws, wt, packed, order, ts, s, k,
                           interpret: bool, slots: int, iters: int):
    """Decode-shape GEMM: fast schedule vs forced-generic schedule.

    The forced-generic run shrinks block_m below M so the grid grows an i
    dimension and every weight tile is re-decoded once per i — the cost
    the fast path removes. Weight-tile decode counts come from gemm_plan.
    """
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(slots, k)).astype(np.float32))
    xc, xs = arc_fused_quantize(x, jnp.ones((k,), jnp.float32), order, ts,
                                s, apply_norm=False, interpret=interpret)
    ka = k + s
    n = wc.shape[0]
    plan_fast = gemm_plan(slots, n, ka)
    assert plan_fast["path"] == "decode_fast"
    emit("decode_gemm_weight_tile_decodes_fast",
         float(plan_fast["weight_tile_decodes"]),
         f"M={slots} grid={plan_fast['grid']}")
    # ragged-M padding waste: the tile rule pads at sublane granularity,
    # not up to a full block (M=257 used to compute 512 rows)
    ragged = gemm_plan(257, n, ka)
    emit("prefill_gemm_ragged_padding_waste",
         float(ragged["padding_waste"]),
         f"M=257 bm={ragged['bm']} mp={ragged['mp']} "
         f"flops={ragged['flops']} useful={ragged['useful_flops']}")

    def fast(a, b):
        return nvfp4_gemm(a, b, wc, ws, w_tensor_scale=wt, w_packed=packed,
                          interpret=interpret)

    us_fast = timeit(fast, xc, xs, iters=iters)
    emit("decode_gemm_fast_path", us_fast,
         f"M={slots} decode schedule, {plan_fast['weight_tile_decodes']} "
         "weight tile decodes")

    # same-M schedule comparison: M=16 runs as one tile on the fast path
    # (weights decoded once per (j, k)) but as two i tiles when forced onto
    # the generic schedule with block_m=8 — every weight tile re-decoded
    # per i. The latency delta is the re-decode cost the fast path avoids.
    bm_forced = 8
    m_cmp = 2 * bm_forced
    reps = -(-m_cmp // slots)
    xcc = jnp.tile(xc, (reps, 1))[:m_cmp]
    xcs = jnp.tile(xs, (reps, 1))[:m_cmp]
    p_one = gemm_plan(m_cmp, n, ka)
    p_two = gemm_plan(m_cmp, n, ka, block_m=bm_forced)
    assert p_one["path"] == "decode_fast" and p_two["path"] == "generic"

    def generic(a, b):
        return nvfp4_gemm(a, b, wc, ws, w_tensor_scale=wt, w_packed=packed,
                          block_m=bm_forced, interpret=interpret)

    us_one = timeit(fast, xcc, xcs, iters=iters)
    us_two = timeit(generic, xcc, xcs, iters=iters)
    emit("decode_gemm_m16_single_decode", us_one,
         f"M={m_cmp} fast schedule, {p_one['weight_tile_decodes']} "
         "weight tile decodes")
    emit("decode_gemm_m16_per_i_redecode", us_two,
         f"M={m_cmp} forced generic (block_m={bm_forced}), "
         f"{p_two['weight_tile_decodes']} weight tile decodes")


def bench_fused_epilogue(plans, qparams, interpret: bool, shapes, iters: int):
    """Fused gate/up swiglu launch vs two GEMMs + XLA epilogue.

    Emits the latency pair and the plan-derived HBM/decode deltas (the
    fused launch reads the quantized activation once and writes one
    (M, F) output instead of two), and asserts bitwise parity with the
    canonical unfused epilogue."""
    gname = next((g for g in plans.fused if g.endswith("mlp.w_gate")), None)
    if gname is None:
        emit("fused_epilogue_skipped", 0.0, "no fusable mlp gate/up pair")
        return
    uname = plans.fused[gname]
    blk = qparams["blocks"][0]["mlp"]
    wg = jax.tree.map(lambda l: l[0], blk["w_gate"])
    wu = jax.tree.map(lambda l: l[0], blk["w_up"])
    order = plans.arrays[gname]["order"][0]
    ts = plans.arrays[gname]["act_scales"][0]
    s = plans.meta[gname]
    k = int(order.shape[-1])
    ka = k + s
    gc, gs, gt, packed = KOPS.qtensor_gemm_operands(wg)
    uc, us, ut, _ = KOPS.qtensor_gemm_operands(wu)
    n = gc.shape[0]
    rng = np.random.default_rng(2)

    for label, m in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        xc, xs = arc_fused_quantize(x, jnp.ones((k,), jnp.float32), order,
                                    ts, s, apply_norm=False,
                                    interpret=interpret)

        @jax.jit
        def unfused(a, b):
            yg = nvfp4_gemm(a, b, gc, gs, w_tensor_scale=gt, w_packed=packed,
                            interpret=interpret)
            yu = nvfp4_gemm(a, b, uc, us, w_tensor_scale=ut, w_packed=packed,
                            interpret=interpret)
            return _swiglu(yg.astype(jnp.bfloat16), yu.astype(jnp.bfloat16))

        def fused(a, b):
            return nvfp4_gemm_swiglu(a, b, gc, gs, uc, us, g_tensor_scale=gt,
                                     u_tensor_scale=ut, w_packed=packed,
                                     out_dtype=jnp.bfloat16,
                                     interpret=interpret)

        h_u, h_f = unfused(xc, xs), fused(xc, xs)
        if not (np.asarray(h_u) == np.asarray(h_f)).all():
            raise SystemExit(f"fused epilogue parity violated at {label}")
        gp = gemm_plan(m, n, ka)
        fp = swiglu_plan(m, n, ka, out_bytes=2)
        us_u = timeit(unfused, xc, xs, iters=iters)
        us_f = timeit(fused, xc, xs, iters=iters)
        emit(f"swiglu_{label}_unfused", us_u,
             f"M={m} 2x nvfp4_gemm + XLA silu*u, "
             f"hbm_rd={2 * gp['hbm_read_bytes']} "
             f"hbm_wr={2 * gp['hbm_write_bytes']} "
             f"w_decodes={2 * gp['weight_tile_decodes']} "
             f"x_decodes={2 * gp['x_tile_decodes']}")
        emit(f"swiglu_{label}_fused", us_f,
             f"M={m} nvfp4_gemm_swiglu ({fp['path']}), "
             f"hbm_rd={fp['hbm_read_bytes']} hbm_wr={fp['hbm_write_bytes']} "
             f"w_decodes={fp['weight_tile_decodes']} "
             f"x_decodes={fp['x_tile_decodes']}, bitwise == unfused")


def bench_decode_residency(plans, qparams, interpret: bool, slots: int,
                           iters: int):
    """Decode-shape GEMM: VMEM-resident schedule vs streamed schedule."""
    name = "b0.mlp.w_gate"
    w = jax.tree.map(lambda l: l[0], qparams["blocks"][0]["mlp"]["w_gate"])
    order = plans.arrays[name]["order"][0]
    ts = plans.arrays[name]["act_scales"][0]
    s = plans.meta[name]
    k = int(order.shape[-1])
    wc, ws, wt, packed = KOPS.qtensor_gemm_operands(w)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(slots, k)).astype(np.float32))
    xc, xs = arc_fused_quantize(x, jnp.ones((k,), jnp.float32), order, ts,
                                s, apply_norm=False, interpret=interpret)
    plan = gemm_plan(slots, wc.shape[0], k + s)
    if not plan["residency"]:
        emit("decode_residency_skipped", 0.0,
             f"launch exceeds the resident VMEM budget at M={slots}")
        return

    def run(resident):
        return nvfp4_gemm(xc, xs, wc, ws, w_tensor_scale=wt,
                          w_packed=packed, interpret=interpret,
                          resident=resident)

    y_r, y_s = run(True), run(False)
    if not (np.asarray(y_r) == np.asarray(y_s)).all():
        raise SystemExit("resident schedule parity violated")
    us_r = timeit(lambda: run(True), iters=iters)
    us_s = timeit(lambda: run(False), iters=iters)
    emit("decode_gemm_resident", us_r,
         f"M={slots} resident schedule: x decoded once "
         f"(x_decodes={plan['x_tile_decodes']}, "
         f"hbm_rd={plan['hbm_read_bytes']}), bitwise == streamed")
    emit("decode_gemm_streamed", us_s,
         f"M={slots} streamed schedule: x re-fetched per (j, k)")


def bench_engine(cfg, quant, plans, qparams, backend: str, interpret: bool,
                 requests: int, new_tokens: int, slots: int,
                 tag: str | None = None):
    tag = tag or backend
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 13))
                                        ).astype(np.int32),
                    max_new_tokens=new_tokens)
            for _ in range(requests)]
    eng = ServingEngine(qparams, cfg, quant, plans, batch_size=slots,
                        max_len=12 + new_tokens + 1, backend=backend,
                        interpret=interpret)
    eng.run(reqs)
    st = eng.last_stats
    summ = st.summary()
    emit(f"engine_{tag}_tokens_per_s",
         float(summ["wall_tokens_per_s"]),
         f"{st.generated_tokens} tokens ({st.decode_tokens} decode + "
         f"{st.prefill_sampled_tokens} prefill-sampled), "
         f"{st.decode_steps} steps")
    if st.decode_steps:
        emit(f"engine_{tag}_us_per_decode_step",
             1e6 * st.wall_seconds / st.decode_steps,
             f"batch={slots} decode_tok_per_step={st.tokens_per_step:.3f} "
             "(wall time incl. prefills)")
    return [r.out_tokens for r in reqs]


def run(arch: str = "llama31-8b", layers: int = 2, interpret: bool = True,
        smoke: bool = True, requests: int = 6, new_tokens: int = 6,
        slots: int = 4):
    if smoke:
        requests, new_tokens, slots = 3, 3, 2
    iters = 2 if smoke else 5
    prefill_m = 128 if smoke else 512

    cfg, quant, plans, qparams = build(arch, layers)
    print(f"# deployed_serving arch={arch} layers={layers} "
          f"interpret={interpret}", flush=True)

    shapes = [("prefill", prefill_m), ("decode", slots)]
    ops = bench_layer_gemm(plans, qparams, interpret, shapes, iters)
    bench_decode_fast_path(*ops, interpret=interpret, slots=slots,
                           iters=iters)
    bench_fused_epilogue(plans, qparams, interpret, shapes, iters)
    bench_decode_residency(plans, qparams, interpret, slots, iters)

    toks_ref = bench_engine(cfg, quant, plans, qparams, "reference",
                            interpret, requests, new_tokens, slots)
    toks_pal = bench_engine(cfg, quant, plans, qparams, "pallas",
                            interpret, requests, new_tokens, slots)
    quant_nf = dataclasses.replace(quant, fuse_epilogue=False)
    toks_nf = bench_engine(cfg, quant_nf, plans, qparams, "pallas",
                           interpret, requests, new_tokens, slots,
                           tag="pallas_unfused")
    match = toks_ref == toks_pal == toks_nf
    emit("engine_backend_greedy_parity", 1.0 if match else 0.0,
         "pallas (fused and unfused epilogue) tokens == reference tokens")
    if not match:
        raise SystemExit("backend parity violated: "
                         f"{toks_ref} != {toks_pal} != {toks_nf}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (CPU CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal workload for the CI time budget")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    run(arch=args.arch, layers=args.layers, interpret=args.interpret,
        smoke=args.smoke, requests=args.requests,
        new_tokens=args.new_tokens, slots=args.slots)


if __name__ == "__main__":
    main()
