"""Deployed serving path: reference (emulated) vs pallas (fused kernels).

Measures, for the continuous-batching engine over ARC-quantized packed
NVFP4 weights:

  * per-layer GEMM latency at the two serving shapes — prefill (M=512)
    and decode (M=active slots) — for both backends
  * end-to-end engine throughput (tokens/sec, per-decode-step latency)
  * the decode fast path's weight-decode saving: `gemm_plan` reports how
    many (bn, bk) weight tiles each schedule dequantizes, and the same
    GEMM is timed on the fast schedule vs forced onto the generic one
    (small block_m => multiple i tiles => per-i re-decode)

    PYTHONPATH=src python -m benchmarks.deployed_serving --interpret
    PYTHONPATH=src python -m benchmarks.deployed_serving --interpret --smoke

On a CPU box ``--interpret`` runs the Pallas kernels bit-faithfully
(slowly); on a TPU drop it for compiled kernel timings. Results emit via
benchmarks.common.emit so the perf trajectory is tracked.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.kernels import ops as KOPS
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import gemm_plan, nvfp4_gemm
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import Request, ServingEngine

from benchmarks.common import emit, timeit


def build(arch: str, layers: int, seed: int = 0):
    cfg = ARCHS[arch].reduced(layers=layers)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (2, 64), 0,
                              cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


def bench_layer_gemm(plans, qparams, interpret: bool, shapes, iters: int):
    """One ARC linear (mlp.w_gate) at serving M shapes, both backends."""
    name = "b0.mlp.w_gate"
    w = qparams["blocks"][0]["mlp"]["w_gate"]
    # period-0 slice of the stacked plan arrays
    order = plans.arrays[name]["order"][0]
    ts = plans.arrays[name]["act_scales"][0]
    s = plans.meta[name]
    k = int(order.shape[-1])
    w0 = jax.tree.map(lambda l: l[0], w)
    wc, ws, wt, packed = KOPS.qtensor_gemm_operands(w0)
    gamma = jnp.ones((k,), jnp.float32)
    rng = np.random.default_rng(0)

    from repro.core import quant as Q
    from repro.core import arc as ARC

    for label, m in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))

        def pallas_fn(xv):
            return KOPS.arc_linear(xv, gamma, order, wc, ws, ts, s,
                                   w_tensor_scale=wt, w_packed=packed,
                                   apply_norm=False, interpret=interpret)

        @jax.jit
        def ref_fn(xv):
            xr = jnp.take(xv, order, axis=-1)
            xq = Q.quantize(xr, "nvfp4", tensor_scale=ts[0])
            if s:
                r_o = xr[..., :s] - xq.dequantize()[..., :s]
                rq = Q.quantize(r_o, "nvfp4", tensor_scale=ts[1])
                xq = ARC.to_interleaved(Q.concat_k(xq, rq), k, s)
            return Q.qmatmul(xq, w0)

        us_p = timeit(pallas_fn, x, iters=iters)
        us_r = timeit(ref_fn, x, iters=iters)
        emit(f"deployed_gemm_{label}_pallas", us_p,
             f"M={m} K={k} S={s} packed={packed}")
        emit(f"deployed_gemm_{label}_reference", us_r, f"M={m} K={k} S={s}")

    return wc, ws, wt, packed, order, ts, s, k


def bench_decode_fast_path(wc, ws, wt, packed, order, ts, s, k,
                           interpret: bool, slots: int, iters: int):
    """Decode-shape GEMM: fast schedule vs forced-generic schedule.

    The forced-generic run shrinks block_m below M so the grid grows an i
    dimension and every weight tile is re-decoded once per i — the cost
    the fast path removes. Weight-tile decode counts come from gemm_plan.
    """
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(slots, k)).astype(np.float32))
    xc, xs = arc_fused_quantize(x, jnp.ones((k,), jnp.float32), order, ts,
                                s, apply_norm=False, interpret=interpret)
    ka = k + s
    n = wc.shape[0]
    plan_fast = gemm_plan(slots, n, ka)
    assert plan_fast["path"] == "decode_fast"
    emit("decode_gemm_weight_tile_decodes_fast",
         float(plan_fast["weight_tile_decodes"]),
         f"M={slots} grid={plan_fast['grid']}")
    # ragged-M padding waste: the tile rule pads at sublane granularity,
    # not up to a full block (M=257 used to compute 512 rows)
    ragged = gemm_plan(257, n, ka)
    emit("prefill_gemm_ragged_padding_waste",
         float(ragged["padding_waste"]),
         f"M=257 bm={ragged['bm']} mp={ragged['mp']} "
         f"flops={ragged['flops']} useful={ragged['useful_flops']}")

    def fast(a, b):
        return nvfp4_gemm(a, b, wc, ws, w_tensor_scale=wt, w_packed=packed,
                          interpret=interpret)

    us_fast = timeit(fast, xc, xs, iters=iters)
    emit("decode_gemm_fast_path", us_fast,
         f"M={slots} decode schedule, {plan_fast['weight_tile_decodes']} "
         "weight tile decodes")

    # same-M schedule comparison: M=16 runs as one tile on the fast path
    # (weights decoded once per (j, k)) but as two i tiles when forced onto
    # the generic schedule with block_m=8 — every weight tile re-decoded
    # per i. The latency delta is the re-decode cost the fast path avoids.
    bm_forced = 8
    m_cmp = 2 * bm_forced
    reps = -(-m_cmp // slots)
    xcc = jnp.tile(xc, (reps, 1))[:m_cmp]
    xcs = jnp.tile(xs, (reps, 1))[:m_cmp]
    p_one = gemm_plan(m_cmp, n, ka)
    p_two = gemm_plan(m_cmp, n, ka, block_m=bm_forced)
    assert p_one["path"] == "decode_fast" and p_two["path"] == "generic"

    def generic(a, b):
        return nvfp4_gemm(a, b, wc, ws, w_tensor_scale=wt, w_packed=packed,
                          block_m=bm_forced, interpret=interpret)

    us_one = timeit(fast, xcc, xcs, iters=iters)
    us_two = timeit(generic, xcc, xcs, iters=iters)
    emit("decode_gemm_m16_single_decode", us_one,
         f"M={m_cmp} fast schedule, {p_one['weight_tile_decodes']} "
         "weight tile decodes")
    emit("decode_gemm_m16_per_i_redecode", us_two,
         f"M={m_cmp} forced generic (block_m={bm_forced}), "
         f"{p_two['weight_tile_decodes']} weight tile decodes")


def bench_engine(cfg, quant, plans, qparams, backend: str, interpret: bool,
                 requests: int, new_tokens: int, slots: int):
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 13))
                                        ).astype(np.int32),
                    max_new_tokens=new_tokens)
            for _ in range(requests)]
    eng = ServingEngine(qparams, cfg, quant, plans, batch_size=slots,
                        max_len=12 + new_tokens + 1, backend=backend,
                        interpret=interpret)
    eng.run(reqs)
    st = eng.last_stats
    summ = st.summary()
    emit(f"engine_{backend}_tokens_per_s",
         float(summ["wall_tokens_per_s"]),
         f"{st.generated_tokens} tokens ({st.decode_tokens} decode + "
         f"{st.prefill_sampled_tokens} prefill-sampled), "
         f"{st.decode_steps} steps")
    if st.decode_steps:
        emit(f"engine_{backend}_us_per_decode_step",
             1e6 * st.wall_seconds / st.decode_steps,
             f"batch={slots} decode_tok_per_step={st.tokens_per_step:.3f} "
             "(wall time incl. prefills)")
    return [r.out_tokens for r in reqs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--interpret", action="store_true",
                    help="run Pallas kernels in interpret mode (CPU CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal workload for the CI time budget")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.new_tokens, args.slots = 3, 3, 2
    iters = 2 if args.smoke else 5
    prefill_m = 128 if args.smoke else 512

    cfg, quant, plans, qparams = build(args.arch, args.layers)
    print(f"# deployed_serving arch={args.arch} layers={args.layers} "
          f"interpret={args.interpret}", flush=True)

    ops = bench_layer_gemm(plans, qparams, args.interpret,
                           [("prefill", prefill_m), ("decode", args.slots)],
                           iters)
    bench_decode_fast_path(*ops, interpret=args.interpret, slots=args.slots,
                           iters=iters)

    toks_ref = bench_engine(cfg, quant, plans, qparams, "reference",
                            args.interpret, args.requests, args.new_tokens,
                            args.slots)
    toks_pal = bench_engine(cfg, quant, plans, qparams, "pallas",
                            args.interpret, args.requests, args.new_tokens,
                            args.slots)
    match = toks_ref == toks_pal
    emit("engine_backend_greedy_parity", 1.0 if match else 0.0,
         "pallas tokens == reference tokens")
    if not match:
        raise SystemExit("backend parity violated: "
                         f"{toks_ref} != {toks_pal}")


if __name__ == "__main__":
    main()
