"""Paper §3.4: worst-case error bounds, theory + empirical check."""
from __future__ import annotations

import numpy as np

from repro.core import error_bounds as EB
from benchmarks.common import emit


def run():
    emit("bounds/alpha_mx_sup", 0.0, f"{EB.ALPHA_MX_SUP}")
    emit("bounds/alpha_nv_sq", 0.0, f"{EB.ALPHA_NV_SUP ** 2:.6f}")
    emit("bounds/ratio_arc_over_mx", 0.0, f"{EB.bound_ratio():.4f}")
    rng = np.random.default_rng(0)
    worst_arc, worst_mx = 0.0, 0.0
    for i in range(20):
        x = rng.normal(size=4096).astype(np.float32) * rng.uniform(0.5, 50)
        r = EB.empirical_worst_case(x)
        worst_arc = max(worst_arc, r.max_err_arc / r.bound_arc)
        worst_mx = max(worst_mx, r.max_err_mxfp8 / r.bound_mxfp8)
        assert r.arc_within_bound and r.mx_within_bound
    emit("bounds/empirical_arc_utilization", 0.0, f"{worst_arc:.3f}")
    emit("bounds/empirical_mx_utilization", 0.0, f"{worst_mx:.3f}")
    return {"ratio": EB.bound_ratio(), "arc_util": worst_arc}


if __name__ == "__main__":
    run()
