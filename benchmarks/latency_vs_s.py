"""Paper Figure 8a: GEMM cost vs augmented channel count S.

On real Blackwell this is kernel latency; on the CPU emulation we report
(a) the analytic FLOP/byte model — cost is exactly linear in (K+S)/K —
and (b) measured wall-clock of the jitted emulated GEMM, which tracks the
same line. The inset claim (ARC << W4A8 for S <= 512) falls out of the
bytes model: NVFP4 reads 4.5 bits/value vs MXFP8's 8.25.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arc as ARC
from benchmarks.common import emit, timeit


def run(m: int = 256, k: int = 2048, n: int = 2048,
        s_values=(0, 64, 128, 256, 512)):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    order = np.argsort(-np.abs(np.asarray(x)).max(0)).astype(np.int32)

    base_flops = 2 * m * k * n
    out = {}
    for s in s_values:
        plan = ARC.ArcPlan(order=order, s=int(s))
        w_aug = ARC.augment_weights(w, plan)
        fn = jax.jit(lambda xx: ARC.arc_matmul(xx, w_aug, plan))
        us = timeit(fn, x, warmup=1, iters=3)
        flops = 2 * m * (k + s) * n
        overhead = flops / base_flops - 1
        # bytes per GEMM at 4.5 bits/value (NVFP4) vs W4A8 (8.25 b activ.)
        bytes_arc = (m * (k + s) + n * (k + s)) * 4.5 / 8
        bytes_w4a8 = m * k * 8.25 / 8 + n * k * 4.25 / 8
        emit(f"latency_vs_s/s={s}", us,
             f"flop_overhead={overhead:.3%};bytes_vs_w4a8="
             f"{bytes_arc / bytes_w4a8:.2f}")
        out[s] = us
    # linearity check: fit slope
    ss = np.array(list(out))
    ts = np.array([out[s] for s in ss])
    slope = np.polyfit(ss, ts, 1)[0]
    emit("latency_vs_s/linear_fit", 0.0, f"us_per_channel={slope:.3f}")
    return out


if __name__ == "__main__":
    run()
