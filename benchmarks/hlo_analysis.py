"""Re-export: the trip-count-aware HLO analyzer lives in repro.launch."""
from repro.launch.hlo_analysis import analyze_hlo

__all__ = ["analyze_hlo"]
