"""Continuous batching vs static (gang-scheduled) batching.

Serving-side analogue of the paper's deployment claim (Fig. 5): the
format-level NVFP4 win only survives into production if decode steps stay
full. A mixed-length synthetic workload is served twice — once by the
static fixed-batch baseline (a batch holds every slot until its slowest
request finishes) and once by the continuous-batching engine (freed slots
admit queued requests between decode steps). Both engines run the same
jitted prefill/decode, so the comparison isolates scheduling.

Reported per engine:
  * decode steps to drain the workload
  * padding waste: fraction of slot-rows swept by decode that emitted no
    token for a live request
  * simulated tokens/s: decode-generated tokens per decode step (each
    step costs one full-batch forward regardless of occupancy) scaled by
    measured per-step wall time. Tokens sampled at prefill cost no decode
    step and are reported separately — folding them in (as the stats did
    before EngineStats split the counters) overstated decode throughput.

A second phase serves a workload salted with one long prompt twice —
one-shot prefill vs ``prefill_chunk=8`` — and reports time-to-first-token
(ticks) plus the *admission stall*: the most prefill tokens a single
tick had to compute before its decode could run. Chunked prefill bounds
the stall by slots x chunk regardless of prompt length, with final
tokens unchanged.

A third phase lands several long prompts in the same tick: per-slot
chunking alone lets every admission contribute a chunk (stall = slots x
chunk), while a shared per-tick ``prefill_budget`` (vLLM-style
``max_num_batched_tokens``) caps the *total* — the stall bound drops
from ``slots x chunk`` to ``budget``, again token-identically.

Run: PYTHONPATH=src python -m benchmarks.continuous_batching
"""
from __future__ import annotations

import copy

import numpy as np

from repro.configs.base import QuantConfig
from repro.quant import quantize_weights_for_serving
from repro.serving import Request, ServingEngine, StaticBatchEngine
from benchmarks.common import emit, plans_for, trained_proxy


def mixed_workload(vocab: int, n: int, seed: int = 0):
    """Prompt lengths 4..16, generation lengths 2..24 — the regime where
    gang scheduling idles short requests against long ones."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(4, 17))
        reqs.append(Request(prompt=rng.integers(0, vocab, plen).astype(np.int32),
                            max_new_tokens=int(rng.integers(2, 25))))
    return reqs


def run(n_requests: int = 12, slots: int = 4, seed: int = 0):
    cfg, params, data = trained_proxy("qwen2-1.5b", layers=2)
    quant = QuantConfig(method="arc")
    plans = plans_for(cfg, params, data, quant)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    reqs = mixed_workload(cfg.vocab_size, n_requests, seed)

    results = {}
    for name, cls in (("static", StaticBatchEngine),
                      ("continuous", ServingEngine)):
        eng = cls(qparams, cfg, quant, plans, batch_size=slots, max_len=48)
        served = eng.run(copy.deepcopy(reqs))
        s = eng.last_stats
        # per-step wall cost is engine-independent (same jitted batch
        # forward), so tokens/step x steps/s is the simulated throughput
        step_s = s.wall_seconds / max(s.decode_steps, 1)
        emit(f"serve_{name}", s.wall_seconds * 1e6,
             f"steps={s.decode_steps} waste={s.padding_waste:.3f} "
             f"decode_tok_per_step={s.tokens_per_step:.3f} "
             f"prefill_sampled={s.prefill_sampled_tokens} "
             f"sim_tok_per_s={s.tokens_per_step / step_s:.1f} "
             f"ttft_p50/p95={s.ttft_p50:.0f}/{s.ttft_p95:.0f} "
             f"latency_p50/p95={s.latency_p50:.0f}/{s.latency_p95:.0f}")
        results[name] = (s, served)

    st, ct = results["static"][0], results["continuous"][0]
    assert ct.generated_tokens == st.generated_tokens, "engines disagree"
    speedup = st.decode_steps / max(ct.decode_steps, 1)
    emit("continuous_speedup", 0.0,
         f"decode_steps {st.decode_steps}->{ct.decode_steps} "
         f"({speedup:.2f}x fewer) waste {st.padding_waste:.3f}->"
         f"{ct.padding_waste:.3f}")
    # greedy parity: scheduling must not change any request's tokens
    for a, b in zip(results["static"][1], results["continuous"][1]):
        assert a.out_tokens == b.out_tokens, "scheduling changed outputs"

    run_chunked_prefill(cfg, qparams, quant, plans, slots=slots, seed=seed)
    run_prefill_budget(cfg, qparams, quant, plans, slots=slots, seed=seed)
    return speedup


def run_chunked_prefill(cfg, qparams, quant, plans, slots: int = 4,
                        seed: int = 0, long_prompt: int = 40,
                        chunk: int = 8):
    """Admission stall with one long prompt: one-shot vs chunked prefill.

    Both runs share one engine's jit traces (cores differ only in
    ``prefill_chunk``), so the comparison isolates the schedule.
    """
    rng = np.random.default_rng(seed)
    reqs = mixed_workload(cfg.vocab_size, 6, seed)
    # the stall: a long prompt arriving mid-stream
    reqs.insert(3, Request(
        prompt=rng.integers(0, cfg.vocab_size, long_prompt).astype(np.int32),
        max_new_tokens=8))
    eng = ServingEngine(qparams, cfg, quant, plans, batch_size=slots,
                        max_len=long_prompt + 32)

    results = {}
    for name, pchunk in (("oneshot", None), ("chunked", chunk)):
        core = eng.make_core(prefill_chunk=pchunk)
        rids = [core.add_request(r.to_generation_request()) for r in reqs]
        while core.has_unfinished():
            core.step()
        states = [core.states[rid] for rid in rids]
        s = core.stats
        emit(f"serve_prefill_{name}", s.wall_seconds * 1e6,
             f"stall_tokens={s.max_prefill_tokens_per_step} "
             f"ttft_p50={s.ttft_p50:.0f} ttft_p95={s.ttft_p95:.0f} "
             f"ttft_max={max(st.ttft_steps for st in states)} "
             f"decode_steps={s.decode_steps}")
        results[name] = (core.stats, [st.out_tokens for st in states])

    one, chk = results["oneshot"][0], results["chunked"][0]
    assert results["chunked"][1] == results["oneshot"][1], \
        "chunked prefill changed greedy tokens"
    assert chk.max_prefill_tokens_per_step < one.max_prefill_tokens_per_step,\
        "chunked prefill should bound the admission stall"
    assert chk.max_prefill_tokens_per_step <= slots * chunk
    emit("chunked_prefill_stall", 0.0,
         f"worst tick prefill tokens {one.max_prefill_tokens_per_step}->"
         f"{chk.max_prefill_tokens_per_step} (bound={slots * chunk}), "
         f"tokens unchanged")
    return one.max_prefill_tokens_per_step, chk.max_prefill_tokens_per_step


def run_prefill_budget(cfg, qparams, quant, plans, slots: int = 4,
                       seed: int = 0, long_prompt: int = 32,
                       chunk: int = 8, budget: int = 8):
    """N simultaneous long admissions: per-slot chunking vs the shared
    per-tick token budget.

    With only the per-slot chunk bound, every slot that admits in the
    same tick contributes a chunk — the worst tick computes ``slots x
    chunk`` prefill tokens in front of its decode. The shared budget
    caps the tick total at ``budget`` no matter how many admissions
    landed together, with greedy tokens unchanged.
    """
    rng = np.random.default_rng(seed)
    # every request long and submitted up front: all slots admit at once
    reqs = [Request(
        prompt=rng.integers(0, cfg.vocab_size, long_prompt).astype(np.int32),
        max_new_tokens=6) for _ in range(slots + 2)]
    eng = ServingEngine(qparams, cfg, quant, plans, batch_size=slots,
                        max_len=long_prompt + 16)

    results = {}
    for name, pbudget in (("chunk_only", None), ("budget", budget)):
        core = eng.make_core(prefill_chunk=chunk, prefill_budget=pbudget)
        rids = [core.add_request(r.to_generation_request()) for r in reqs]
        while core.has_unfinished():
            core.step()
        states = [core.states[rid] for rid in rids]
        emit(f"serve_prefill_{name}", core.stats.wall_seconds * 1e6,
             f"stall_tokens={core.stats.max_prefill_tokens_per_step} "
             f"decode_steps={core.stats.decode_steps}")
        results[name] = (core.stats, [st.out_tokens for st in states])

    chk, bud = results["chunk_only"][0], results["budget"][0]
    assert results["budget"][1] == results["chunk_only"][1], \
        "the prefill budget changed greedy tokens"
    assert chk.max_prefill_tokens_per_step == slots * chunk, \
        "simultaneous admissions should stack chunks without a budget"
    assert bud.max_prefill_tokens_per_step <= budget, \
        "the shared budget must bound the tick's total prefill"
    emit("prefill_budget_stall", 0.0,
         f"worst tick prefill tokens {chk.max_prefill_tokens_per_step}"
         f" (slots x chunk) -> {bud.max_prefill_tokens_per_step} "
         f"(budget={budget}), tokens unchanged")
    return chk.max_prefill_tokens_per_step, bud.max_prefill_tokens_per_step


if __name__ == "__main__":
    run()
