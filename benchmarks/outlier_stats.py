"""Paper Figure 7: outlier channel count S across layers (from calibration)."""
from __future__ import annotations

import numpy as np

from repro.configs.base import QuantConfig
from repro.quant import plan_summary
from benchmarks.common import emit, plans_for, trained_proxy


def run():
    cfg, params, data = trained_proxy(layers=4)
    plans = plans_for(cfg, params, data, QuantConfig(method="arc"))
    summ = plan_summary(plans)
    for name in sorted(summ):
        v = summ[name]
        emit(f"outlier_s/{name}", 0.0,
             f"S={v['S']};K={v['K']};overhead={v['overhead']:.3f}")
    ss = [v["S"] for v in summ.values()]
    emit("outlier_s/aggregate", 0.0,
         f"mean={np.mean(ss):.1f};max={max(ss)};min={min(ss)}")
    return summ


if __name__ == "__main__":
    run()
