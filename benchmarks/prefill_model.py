"""Paper Table 8 / Figure 6 analogue on TPU: roofline-derived prefill cost.

We cannot time Blackwell GPUs; instead we compute, per model and sequence
length, the compute/memory roofline seconds for bf16 vs ARCQuant-NVFP4
weights on a v5e chip, which is the TPU translation of the paper's
"prefill speedup & memory" table. Weight bytes: bf16 = 16 bits/value;
NVFP4 packed = 4.5 (+ S/K augmentation overhead).
"""
from __future__ import annotations

import numpy as np

from repro.configs import ARCHS
from repro.launch.mesh import HBM_PER_CHIP, HBM_BW, PEAK_FLOPS_BF16
from benchmarks.common import emit

S_OVER_K = 256 / 4096     # typical augmentation overhead at S=256


def run(models=("qwen2-1.5b", "llama31-8b", "qwen3-32b"),
        batch: int = 4, seqs=(512, 1024, 2048)):
    out = {}
    for name in models:
        cfg = ARCHS[name]
        n = cfg.param_count()
        for seq in seqs:
            tokens = batch * seq
            flops = 2 * n * tokens
            t_compute = flops / PEAK_FLOPS_BF16
            for tag, bits in [("bf16", 16.0), ("arcquant", 4.5 * (1 + S_OVER_K))]:
                wbytes = n * bits / 8
                t_mem = wbytes / HBM_BW
                t = max(t_compute, t_mem)
                emit(f"prefill/{name}/b{batch}s{seq}/{tag}", t * 1e6,
                     f"bound={'compute' if t_compute > t_mem else 'memory'};"
                     f"weight_gb={wbytes/1e9:.2f}")
                out[(name, seq, tag)] = t
            sp = out[(name, seq, "bf16")] / out[(name, seq, "arcquant")]
            emit(f"prefill/{name}/b{batch}s{seq}/speedup", 0.0, f"x{sp:.2f}")
    return out


if __name__ == "__main__":
    run()
