"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled dry-run (per-device HLO program):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          [197e12 bf16]
    memory     = HLO_bytes_per_device / HBM_bw               [819e9 B/s]
    collective = wire_bytes_per_device / link_bw             [50e9 B/s]

plus MODEL_FLOPS (6*N*D train, 2*N_active*D inference) and the useful-
compute ratio MODEL_FLOPS / HLO_FLOPs. The dominant term is the
bottleneck the perf loop iterates on (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

CHIPS = {"pod": 256, "multipod": 512}


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()          # MoE: routed experts only
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens / chips
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch / chips


def analyze_cell(path: Path) -> dict | None:
    d = json.loads(path.read_text())
    if d.get("status") != "ok":
        return {"cell": d.get("cell", path.stem), "status": d.get("status"),
                "reason": d.get("reason", d.get("error", ""))[:90]}
    mesh = "multipod" if "multipod" in d["cell"] else "pod"
    chips = CHIPS[mesh]
    # prefer the trip-count-aware analyzer totals (XLA's cost_analysis
    # counts while bodies once); fall back to the XLA numbers
    ha = d.get("hlo_analysis")
    if ha:
        flops = ha["flops"]
        bytes_acc = ha["bytes"]
        coll = ha["collectives"]
    else:
        flops = d["cost"].get("flops", 0.0)
        bytes_acc = d["cost"].get("bytes accessed", 0.0)
        coll = d["collectives"]
    wire = sum(v for k, v in coll.items() if k != "count")
    t_c = flops / PEAK_FLOPS_BF16
    t_m = bytes_acc / HBM_BW
    t_x = wire / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])
    mf = model_flops_per_device(d["arch"], d["shape"], chips)
    useful = mf / flops if flops else 0.0
    step_t = max(t_c, t_m, t_x)
    mfu = mf / PEAK_FLOPS_BF16 / step_t if step_t else 0.0
    return {
        "cell": d["cell"], "status": "ok", "arch": d["arch"],
        "shape": d["shape"], "mesh": mesh, "kind": d["kind"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom[0], "step_seconds": step_t,
        "model_flops": mf, "hlo_flops": flops, "useful_ratio": useful,
        "roofline_fraction": mfu,
        "mem_gb": (d["memory"].get("argument_size_in_bytes", 0)
                   + d["memory"].get("temp_size_in_bytes", 0)
                   + d["memory"].get("output_size_in_bytes", 0)
                   - d["memory"].get("alias_size_in_bytes", 0)) / 2 ** 30,
        "recommendation": _recommend(dom[0], useful, d),
    }


def _recommend(dom: str, useful: float, d: dict) -> str:
    if dom == "collective":
        big = max(((k, v) for k, v in d["collectives"].items()
                   if k != "count"), key=lambda kv: kv[1])[0]
        return (f"dominant wire op is {big}: overlap it with compute or "
                f"reshard to remove it")
    if dom == "memory":
        return ("memory-bound: cut bytes/step — 4-bit packed weights, "
                "bf16 activations, fuse dequant into the GEMM (Pallas)")
    if useful < 0.4:
        return ("compute-bound with low useful ratio: remove masked/remat "
                "waste (causal block skipping, selective remat)")
    return "compute-bound: increase per-chip arithmetic intensity"


def run(results_dir: str = "results/dryrun", mesh: str = "pod",
        emit_rows: bool = True):
    rows = []
    for p in sorted(Path(results_dir).glob(f"*__{mesh}.json")):
        r = analyze_cell(p)
        if r:
            rows.append(r)
    if emit_rows:
        hdr = (f"{'cell':58s} {'dom':10s} {'t_comp':>9s} {'t_mem':>9s} "
               f"{'t_coll':>9s} {'useful':>7s} {'MFU':>6s} {'memGB':>6s}")
        print(hdr)
        for r in rows:
            if r["status"] != "ok":
                print(f"{r['cell']:58s} {r['status']}: {r.get('reason','')}")
                continue
            print(f"{r['cell']:58s} {r['dominant']:10s} "
                  f"{r['t_compute']:9.4f} {r['t_memory']:9.4f} "
                  f"{r['t_collective']:9.4f} {r['useful_ratio']:7.2%} "
                  f"{r['roofline_fraction']:6.2%} {r['mem_gb']:6.1f}")
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = run(args.results, args.mesh)
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
