"""Shared benchmark utilities: proxy-model training + timing helpers."""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import ModelConfig, QuantConfig
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import capture_stats, init_params, next_token_loss
from repro.optim import adamw_init
from repro.quant import make_plan_bundle

_CACHE: Dict[str, tuple] = {}


def trained_proxy(arch: str = "llama31-8b", layers: int = 2,
                  steps: int = 60, seed: int = 0):
    """Train a reduced-config proxy model (cached per run)."""
    key = f"{arch}:{layers}:{steps}:{seed}"
    if key in _CACHE:
        return _CACHE[key]
    cfg = ARCHS[arch].reduced(layers=layers)
    params = init_params(cfg, jax.random.PRNGKey(seed), jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, base_lr=3e-3, warmup=5, total=steps,
                                   remat=False), donate_argnums=(0, 1))
    data = SyntheticLM(cfg.vocab_size, seed)
    it = data.train_stream().batches(4, 64)
    for _ in range(steps):
        toks = next(it)
        pos = np.broadcast_to(np.arange(64), (4, 64)).astype(np.int32)
        params, opt, _ = step(params, opt, {"tokens": jnp.asarray(toks),
                                            "positions": jnp.asarray(pos)})
    _CACHE[key] = (cfg, params, data)
    return _CACHE[key]


def eval_ppl(cfg: ModelConfig, params, data: SyntheticLM,
             quant: QuantConfig, plans, n_batches: int = 3) -> float:
    tot, n = 0.0, 0
    for toks in data.eval_batches(4, 64, n_batches):
        _, aux = next_token_loss(params, cfg, jnp.asarray(toks), quant=quant,
                                 plans=plans)
        tot += float(aux["nll"])
        n += 1
    return float(np.exp(tot / n))


def plans_for(cfg, params, data, quant: QuantConfig, corpus="wikitext2"):
    from repro.data import make_calibration_set
    calib = make_calibration_set(cfg.vocab_size, 8, 64, corpus=corpus)
    stats = None
    for toks in calib.batches:
        s = capture_stats(params, cfg, tokens=jnp.asarray(toks))
        if stats is None:
            stats = {k: np.array(v) for k, v in s.items()}
        else:
            for k, v in s.items():
                np.maximum(stats[k], np.asarray(v), out=stats[k])
    return make_plan_bundle(stats, cfg, quant, params)


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
