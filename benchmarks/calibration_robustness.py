"""Paper Table 5 / §4.4: PPL sensitivity to the calibration corpus."""
from __future__ import annotations

import numpy as np

from repro.configs.base import QuantConfig
from benchmarks.common import emit, eval_ppl, plans_for, trained_proxy


def run(corpora=("wikitext2", "c4", "humaneval")):
    cfg, params, data = trained_proxy()
    q = QuantConfig(method="arc")
    ppls = {}
    for corpus in corpora:
        plans = plans_for(cfg, params, data, q, corpus=corpus)
        ppls[corpus] = eval_ppl(cfg, params, data, q, plans)
        emit(f"calib_robust/{corpus}", 0.0, f"ppl={ppls[corpus]:.3f}")
    spread = max(ppls.values()) - min(ppls.values())
    emit("calib_robust/spread", 0.0, f"delta_ppl={spread:.4f}")
    return ppls


if __name__ == "__main__":
    run()
