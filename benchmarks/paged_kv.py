"""Paged vs slot-pool KV cache under a mixed-length serving workload.

The slot pool reserves ``max_len`` positions per slot, so cache memory —
not compute — caps concurrency: ``num_slots`` is fixed by ``num_slots *
max_len`` bytes regardless of how short typical requests are. The paged
pool allocates fixed-size pages on demand through per-request block
tables, so the same bytes admit as many requests as actually fit.

Three engines serve the same workload:

  * ``slot``        — the slot-pool baseline, ``SLOTS`` slots
  * ``paged_eq``    — paged pool with exactly the slot pool's page budget
                      but 2x the slots: strictly more concurrent requests
                      in the same cache memory -> fewer decode steps
  * ``paged_half``  — paged pool with the baseline slot count but half
                      the page budget: the same workload served (greedy-
                      token-identical, preempting when pages run dry) in
                      half the full-attention cache memory

Reported per engine: decode steps, page utilization/peak, preemptions,
and the full-attention K/V bytes the pool actually reserves. Greedy
parity vs the slot pool is asserted for both paged runs.

Run: PYTHONPATH=src python -m benchmarks.paged_kv [--smoke]
"""
from __future__ import annotations

import argparse
import copy

import numpy as np

from repro.configs.base import FULL_ATTN, QuantConfig
from repro.quant import quantize_weights_for_serving
from repro.serving import PagedServingEngine, Request, ServingEngine
from benchmarks.common import emit, plans_for, trained_proxy


def mixed_workload(vocab: int, n: int, max_len: int, seed: int = 0):
    """Prompt lengths 4..16, generation 2..24: most requests use a small
    fraction of ``max_len``, the regime where per-slot reservation wastes
    the pool."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(4, 17))
        gen = int(rng.integers(2, min(25, max_len - plen)))
        reqs.append(Request(prompt=rng.integers(0, vocab, plen)
                            .astype(np.int32), max_new_tokens=gen))
    return reqs


def kv_bytes(cfg, positions: int) -> int:
    """bf16 K+V bytes for ``positions`` cache positions across the
    full-attention layers (the memory the paged pool manages)."""
    n_full = sum(1 for m in cfg.mixer_pattern if m == FULL_ATTN)
    n_full *= cfg.num_periods
    return positions * cfg.num_kv_heads * cfg.head_dim * 2 * 2 * n_full


def run(n_requests: int = 16, slots: int = 4, max_len: int = 64,
        block_size: int = 16, seed: int = 0):
    cfg, params, data = trained_proxy("qwen2-1.5b", layers=2)
    quant = QuantConfig(method="arc")
    plans = plans_for(cfg, params, data, quant)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    reqs = mixed_workload(cfg.vocab_size, n_requests, max_len, seed)

    max_blocks = max_len // block_size
    slot_budget = slots * max_blocks            # pages the slot pool owns

    engines = {
        "slot": ServingEngine(qparams, cfg, quant, plans, batch_size=slots,
                              max_len=max_len),
        # same page budget, twice the slots: memory no longer caps batch
        "paged_eq": PagedServingEngine(
            qparams, cfg, quant, plans, batch_size=2 * slots,
            max_len=max_len, num_pages=slot_budget + 1,
            block_size=block_size),
        # same slots, half the pages: same service from half the memory
        "paged_half": PagedServingEngine(
            qparams, cfg, quant, plans, batch_size=slots, max_len=max_len,
            num_pages=slot_budget // 2 + 1, block_size=block_size),
    }

    results = {}
    for name, eng in engines.items():
        served = eng.run(copy.deepcopy(reqs))
        s = eng.last_stats
        pages = s.num_pages or slot_budget
        mem = kv_bytes(cfg, pages * block_size)
        extra = ""
        if s.num_pages:
            extra = (f" page_util={s.page_utilization:.3f}"
                     f" peak_pages={s.peak_pages}"
                     f" preempt={s.preemptions}")
        ttft = [r.ttft_steps for r in served]    # set once a token emitted
        emit(f"paged_kv_{name}", s.wall_seconds * 1e6,
             f"slots={eng.batch_size} steps={s.decode_steps} "
             f"kv_bytes={mem} waste={s.padding_waste:.3f} "
             f"ttft_p50={int(np.median(ttft))} ttft_max={max(ttft)}"
             f"{extra}")
        results[name] = (s, [r.out_tokens for r in served], mem)

    st, ref_tokens, st_mem = results["slot"]
    for name in ("paged_eq", "paged_half"):
        assert results[name][1] == ref_tokens, f"{name} changed greedy tokens"
    eq, half = results["paged_eq"][0], results["paged_half"][0]
    assert eq.decode_steps < st.decode_steps, \
        "equal-memory paged pool should drain the workload in fewer steps"
    assert results["paged_half"][2] < st_mem
    emit("paged_kv_concurrency_win", 0.0,
         f"same memory: steps {st.decode_steps}->{eq.decode_steps} "
         f"({st.decode_steps / max(eq.decode_steps, 1):.2f}x fewer); "
         f"same steps budget: memory {st_mem}->{results['paged_half'][2]} "
         f"bytes ({half.preemptions} preemptions)")
    return st.decode_steps / max(eq.decode_steps, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal workload for the CI time budget")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots = 8, 2
    run(n_requests=args.requests, slots=args.slots, max_len=args.max_len)


if __name__ == "__main__":
    main()
