"""Robustness overhead and recovery: the cost of the hardening layer.

Two claims back the request-lifecycle hardening (abort / deadlines /
poisoned-request isolation) added to the serving engine:

  1. **Guard overhead** — the per-row non-finite-logit guard runs inside
     the decode jit (one fused ``isfinite`` all-reduce per row; only
     ``B`` bools cross to the host), so its decode-step cost should be
     noise against the batched forward. Served twice with identical
     workloads (``nan_guard`` off/on, same quantized weights, greedy
     parity asserted), target < 2% per-step overhead.
  2. **Abort recovery** — a fleet aborting ~10% of its in-flight
     requests at random ticks must not disturb survivors (slot-invariant
     sampling: traces stay bit-identical to the abort-free run) and must
     return every aborted request's pages to the pool (no leak: pages in
     use return to zero, pool invariants hold).

Run: PYTHONPATH=src python -m benchmarks.robustness
"""
from __future__ import annotations

import copy

import numpy as np

from repro.configs.base import QuantConfig
from repro.quant import quantize_weights_for_serving
from repro.serving import PagedServingEngine, Request, ServingEngine
from benchmarks.common import emit, plans_for, trained_proxy


def _workload(vocab: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab,
                                        int(rng.integers(4, 17))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(8, 25)))
            for _ in range(n)]


def run(n_requests: int = 12, slots: int = 4, seed: int = 0):
    cfg, params, data = trained_proxy("qwen2-1.5b", layers=2)
    quant = QuantConfig(method="arc")
    plans = plans_for(cfg, params, data, quant)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    reqs = _workload(cfg.vocab_size, n_requests, seed)

    overhead = run_guard_overhead(cfg, qparams, quant, plans, reqs, slots)
    run_abort_recovery(cfg, qparams, quant, plans, reqs, slots, seed)
    return overhead


def run_guard_overhead(cfg, qparams, quant, plans, reqs, slots: int):
    """Decode-step cost of the in-jit NaN guard: off vs on, same tokens."""
    engines = {name: ServingEngine(qparams, cfg, quant, plans,
                                   batch_size=slots, max_len=48,
                                   nan_guard=guard)
               for name, guard in (("guard_off", False), ("guard_on", True))}
    results = {}
    for name, eng in engines.items():           # traces + async-compile drain
        for _ in range(2):
            results[name] = (None, eng.run(copy.deepcopy(reqs)))
    times = {name: [] for name in engines}
    for _ in range(5):                          # interleaved: both variants
        for name, eng in engines.items():       # see the same host jitter
            results[name] = (None, eng.run(copy.deepcopy(reqs)))
            s = eng.last_stats
            times[name].append(s.wall_seconds / max(s.decode_steps, 1) * 1e6)
    for name, eng in engines.items():
        step_us = float(np.median(times[name]))
        s = eng.last_stats
        emit(f"serve_{name}", step_us,
             f"steps={s.decode_steps} tok_per_step={s.tokens_per_step:.3f}")
        results[name] = (step_us, results[name][1])
    # the guard must be pure observation: token parity off vs on
    for a, b in zip(results["guard_off"][1], results["guard_on"][1]):
        assert a.out_tokens == b.out_tokens, "nan_guard changed outputs"

    # the overhead claim itself is measured on the jitted decode step in
    # isolation (median of many calls, block_until_ready), free of the
    # engine's per-tick host bookkeeping and scheduler noise. The cache
    # is donated into each call, so it threads through the loop.
    import time as _time

    import jax
    import jax.numpy as jnp
    step_us = {}
    for name, eng in engines.items():
        core = eng.make_core()
        cache = core.pool.cache
        fixed = (jnp.zeros((slots, 1), jnp.int32),
                 jnp.zeros((slots, 1), jnp.int32),
                 jnp.zeros((slots,), jnp.float32),
                 jnp.arange(slots, dtype=jnp.int32),
                 jnp.zeros((slots,), jnp.int32), core._seed_key)
        ts = []
        for i in range(23):                     # 3 warmup + 20 timed
            t0 = _time.perf_counter()
            nxt, ok, cache = eng.fns.decode(eng.qparams, cache, *fixed)
            jax.block_until_ready((nxt, ok))
            if i >= 3:
                ts.append((_time.perf_counter() - t0) * 1e6)
        step_us[name] = float(np.median(ts))
        emit(f"decode_jit_{name}", step_us[name], f"batch={slots}")
    overhead = step_us["guard_on"] / step_us["guard_off"] - 1.0
    emit("nan_guard_overhead", 0.0,
         f"{100 * overhead:+.2f}% per jitted decode step (target < 2%)")
    return overhead


def run_abort_recovery(cfg, qparams, quant, plans, reqs, slots: int,
                       seed: int, abort_frac: float = 0.10):
    """Abort ~10% of requests at random mid-flight ticks; survivors must
    stay bit-identical and the paged pool must fully recover."""
    eng = PagedServingEngine(qparams, cfg, quant, plans, batch_size=slots,
                             max_len=48, block_size=4)

    base = eng.make_core()
    rids = [base.add_request(r.to_generation_request()) for r in reqs]
    while base.has_unfinished():
        base.step()
    base_tokens = {r: list(base.states[r].out_tokens) for r in rids}

    rng = np.random.default_rng(seed)
    doomed = set(rng.choice(rids, max(1, int(len(rids) * abort_frac)),
                            replace=False).tolist())
    abort_at = {r: int(rng.integers(1, 6)) for r in doomed}

    core = eng.make_core()
    for r in reqs:
        core.add_request(r.to_generation_request())
    tick = 0
    while core.has_unfinished():
        for r, t in list(abort_at.items()):
            if t == tick and not core.states[r].done:
                core.abort_request(r)
                del abort_at[r]
        core.step()
        tick += 1
    core.pool.check_invariants()
    assert core.pool.pages_in_use == 0, "aborted requests leaked pages"

    survivors = [r for r in rids if r not in doomed]
    for r in survivors:
        assert list(core.states[r].out_tokens) == base_tokens[r], \
            "abort perturbed a surviving request's trace"
    for r in doomed:
        st = core.states[r]
        assert str(st.finish_reason) in ("aborted", "length", "eos")
        assert list(st.out_tokens) == \
            base_tokens[r][: len(st.out_tokens)], \
            "aborted request diverged before its abort"

    s, b = core.stats, base.stats
    emit("abort_recovery", s.wall_seconds * 1e6,
         f"aborted={s.aborted}/{len(rids)} steps={b.decode_steps}->"
         f"{s.decode_steps} tok_per_step={b.tokens_per_step:.3f}->"
         f"{s.tokens_per_step:.3f} survivors_bit_identical=True "
         f"pages_leaked=0")


if __name__ == "__main__":
    run()
