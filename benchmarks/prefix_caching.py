"""Prefix-cached paged KV under a shared-system-prompt workload.

Real serving traffic at scale is dominated by shared prefixes — system
prompts, few-shot templates, multi-turn history — and prefill is where
the fused NVFP4 GEMMs burn their FLOPs. With the content-addressed page
pool (``PagedServingEngine(prefix_cache=True)``) every request after the
first finds the shared prompt's pages in the prefix-hash table and
admits with only its *uncached* suffix computed and charged against the
pool.

Three phases, all greedy-token-identical to ``prefix_cache=False``:

  * **prefill / TTFT** — N requests share a long system prompt; with
    chunked prefill the cached run finishes each admission's prefill in
    ~1 tick instead of ``ceil(prompt/chunk)``, so time-to-first-token
    (ticks) and total prefill tokens computed both collapse. Asserts
    >= 50% of prefill tokens are served from the cache. (The first
    *wave* of admissions — one per slot — is cold: nothing registers
    until the first install, so the workload must outnumber the slot
    count for the warm fraction to dominate, exactly as in production
    steady state.)
  * **concurrency** — the same page pool, sized so the *unshared* run
    can only hold ~2 requests' K/V at once: sharing the system prompt's
    pages lets more requests reside simultaneously, draining the
    workload in fewer decode steps from the same memory.
  * **MoE** — the same shared-prompt workload on an MoE proxy. Dropless
    dispatch (cap = S*K, nothing truncated) makes MoE prefill numerics
    batch-shape independent, which is what lets the cache manager keep
    prefix sharing enabled for MoE configs; asserts >= 50% of MoE
    prefill is served from cache, bit-identically — and that flipping
    ``moe_dropless`` off closes the gate again.

Run: PYTHONPATH=src python -m benchmarks.prefix_caching [--smoke]
"""
from __future__ import annotations

import argparse
import copy
import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import PagedServingEngine, Request
from benchmarks.common import emit, plans_for, trained_proxy


def shared_prefix_workload(vocab: int, n: int, sys_len: int,
                           tail: tuple = (3, 8), new: tuple = (4, 10),
                           seed: int = 0):
    """n requests = one shared system prompt + a unique short tail."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, sys_len).astype(np.int32)
    reqs = []
    for _ in range(n):
        t = rng.integers(0, vocab, int(rng.integers(*tail))).astype(np.int32)
        reqs.append(Request(prompt=np.concatenate([sys_prompt, t]),
                            max_new_tokens=int(rng.integers(*new))))
    return reqs


def _serve(eng, reqs):
    served = eng.run(copy.deepcopy(reqs))
    assert all(r.done for r in served)
    return [r.out_tokens for r in served], served, eng.last_stats


def run(n_requests: int = 12, sys_len: int = 48, slots: int = 4,
        max_len: int = 96, block_size: int = 16, chunk: int = 16,
        seed: int = 0):
    cfg, params, data = trained_proxy("qwen2-1.5b", layers=2)
    quant = QuantConfig(method="arc")
    plans = plans_for(cfg, params, data, quant)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    reqs = shared_prefix_workload(cfg.vocab_size, n_requests, sys_len,
                                  seed=seed)

    # -- phase 1: prefill tokens + TTFT on an amply sized pool -------------
    kw = dict(batch_size=slots, max_len=max_len, block_size=block_size,
              prefill_chunk=chunk)
    results = {}
    for name, pc in (("off", False), ("on", True)):
        eng = PagedServingEngine(qparams, cfg, quant, plans,
                                 prefix_cache=pc, **kw)
        toks, served, s = _serve(eng, reqs)
        ttft = [r.ttft_steps for r in served]
        emit(f"prefix_cache_{name}", s.wall_seconds * 1e6,
             f"prefill_tokens={s.prefill_tokens} "
             f"cached_prefix_tokens={s.cached_prefix_tokens} "
             f"ttft_p50={int(np.median(ttft))} ttft_max={max(ttft)} "
             f"decode_steps={s.decode_steps} "
             f"stall={s.max_prefill_tokens_per_step}")
        results[name] = (toks, served, s, ttft)

    off, on = results["off"], results["on"]
    assert on[0] == off[0], "prefix caching changed greedy tokens"
    total = on[2].prefill_tokens + on[2].cached_prefix_tokens
    skipped = on[2].cached_prefix_tokens / total
    assert skipped >= 0.5, \
        f"expected >=50% of prefill served from cache, got {skipped:.1%}"
    assert np.median(on[3]) < np.median(off[3]), \
        "prefix caching should cut time-to-first-token"
    emit("prefix_cache_prefill_win", 0.0,
         f"prefill tokens {off[2].prefill_tokens}->{on[2].prefill_tokens} "
         f"({skipped:.0%} served from cache), "
         f"ttft_p50 {int(np.median(off[3]))}->{int(np.median(on[3]))} ticks")

    # -- phase 2: concurrency from the same constrained pool ---------------
    # pages for ~2 unshared requests: the unshared run must queue/preempt,
    # the shared run fits more residents because the system prompt's
    # pages are counted once
    blocks_per_req = -(-(sys_len + 16) // block_size)
    tight_pages = 2 * blocks_per_req + 1
    kw_tight = dict(batch_size=slots, max_len=max_len,
                    block_size=block_size, num_pages=tight_pages,
                    prefill_chunk=chunk)
    tight = {}
    for name, pc in (("off", False), ("on", True)):
        eng = PagedServingEngine(qparams, cfg, quant, plans,
                                 prefix_cache=pc, **kw_tight)
        toks, served, s = _serve(eng, reqs)
        emit(f"prefix_cache_tight_{name}", s.wall_seconds * 1e6,
             f"pages={s.num_pages} decode_steps={s.decode_steps} "
             f"preemptions={s.preemptions} peak_pages={s.peak_pages} "
             f"prefill_tokens={s.prefill_tokens}")
        tight[name] = (toks, s)

    assert tight["on"][0] == tight["off"][0] == off[0], \
        "constrained-pool runs changed greedy tokens"
    t_off, t_on = tight["off"][1], tight["on"][1]
    assert t_on.decode_steps < t_off.decode_steps, \
        "sharing should raise concurrency (fewer decode steps, same pool)"
    emit("prefix_cache_concurrency_win", 0.0,
         f"same {t_on.num_pages}-page pool: decode steps "
         f"{t_off.decode_steps}->{t_on.decode_steps} "
         f"({t_off.decode_steps / max(t_on.decode_steps, 1):.2f}x fewer), "
         f"preemptions {t_off.preemptions}->{t_on.preemptions}")
    return skipped


def run_moe(n_requests: int = 8, sys_len: int = 32, slots: int = 2,
            max_len: int = 96, block_size: int = 16, chunk: int = 16,
            seed: int = 0):
    """Phase 3: prefix sharing on an MoE proxy, unlocked by dropless."""
    key = jax.random.PRNGKey(seed)
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced(layers=2)
    assert cfg.moe_dropless
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    reqs = shared_prefix_workload(cfg.vocab_size, n_requests, sys_len,
                                  seed=seed)
    kw = dict(batch_size=slots, max_len=max_len, block_size=block_size,
              prefill_chunk=chunk)
    results = {}
    for name, pc in (("off", False), ("on", True)):
        eng = PagedServingEngine(qparams, cfg, quant, plans,
                                 prefix_cache=pc, **kw)
        toks_o, _, s = _serve(eng, reqs)
        emit(f"prefix_cache_moe_{name}", s.wall_seconds * 1e6,
             f"prefill_tokens={s.prefill_tokens} "
             f"cached_prefix_tokens={s.cached_prefix_tokens} "
             f"decode_steps={s.decode_steps}")
        results[name] = (toks_o, s)

    assert results["on"][0] == results["off"][0], \
        "MoE prefix caching changed greedy tokens"
    s_on = results["on"][1]
    total = s_on.prefill_tokens + s_on.cached_prefix_tokens
    skipped = s_on.cached_prefix_tokens / total
    assert skipped >= 0.5, \
        f"expected >=50% of MoE prefill served from cache, got {skipped:.1%}"
    # capacity-capped dispatch is batch-shape dependent: the gate closes
    cfg_cap = dataclasses.replace(cfg, moe_dropless=False)
    eng_cap = PagedServingEngine(qparams, cfg_cap, quant, plans,
                                 prefix_cache=True, **kw)
    assert not eng_cap.make_core().pool.prefix_enabled, \
        "capacity-capped MoE must not prefix-share"
    emit("prefix_cache_moe_win", 0.0,
         f"dropless MoE: {skipped:.0%} of prefill served from cache, "
         f"bitwise identical; moe_dropless=False disables sharing")
    return skipped


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal workload for the CI time budget")
    # the workload must outnumber the slots: the first wave (one cold
    # admission per slot) registers the pages the rest hit
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--sys-len", type=int, default=48)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.slots, args.sys_len = 5, 2, 32
    run(n_requests=args.requests, sys_len=args.sys_len, slots=args.slots,
        max_len=2 * args.sys_len)
    run_moe(n_requests=5 if args.smoke else 8, sys_len=args.sys_len,
            slots=2, max_len=2 * args.sys_len)


if __name__ == "__main__":
    main()
