"""Paper Tables 1/2/6 at proxy scale: PPL per method x format.

The proxy is a trained reduced llama-3.1-family model on the synthetic
Zipf-Markov corpus; the *orderings* are what reproduce the paper's claims
(ARC best W4A4; QuaRot regresses on fine-grained formats; ARC generalizes
to INT4/MXFP4).
"""
from __future__ import annotations

from repro.configs.base import QuantConfig
from benchmarks.common import emit, eval_ppl, plans_for, trained_proxy

METHODS = ["none", "rtn", "smooth", "quarot", "atom", "arc"]


def run(formats=("nvfp4",), methods=METHODS, steps: int = 60):
    cfg, params, data = trained_proxy(steps=steps)
    results = {}
    for fmt in formats:
        for method in methods:
            q = QuantConfig(method=method, fmt=fmt)
            plans = plans_for(cfg, params, data, q)
            ppl = eval_ppl(cfg, params, data, q, plans)
            results[(fmt, method)] = ppl
            emit(f"accuracy/{fmt}/{method}", 0.0, f"ppl={ppl:.3f}")
    # W4A8 reference (MXFP4 weights + MXFP8 activations)
    q = QuantConfig(method="rtn", fmt="mxfp4", act_fmt="mxfp8")
    plans = plans_for(cfg, params, data, q)
    ppl = eval_ppl(cfg, params, data, q, plans)
    results[("w4a8", "rtn")] = ppl
    emit("accuracy/w4a8/rtn", 0.0, f"ppl={ppl:.3f}")
    return results


if __name__ == "__main__":
    run(formats=("nvfp4", "mxfp4", "int4"))
