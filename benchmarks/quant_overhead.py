"""Paper Table 4: calibration latency, quantization time, model memory."""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import QuantConfig
from repro.quant import quantize_weights_for_serving
from benchmarks.common import emit, plans_for, trained_proxy


def _tree_bytes(tree):
    import jax
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += leaf.size * leaf.dtype.itemsize
    return total


def run():
    cfg, params, data = trained_proxy(layers=2)
    q = QuantConfig(method="arc")
    t0 = time.time()
    plans = plans_for(cfg, params, data, q)
    t_calib = time.time() - t0
    t0 = time.time()
    qparams = quantize_weights_for_serving(params, cfg, q, plans, pack=True)
    t_quant = time.time() - t0
    orig = _tree_bytes(params)
    packed = _tree_bytes(qparams)
    emit("quant_overhead/calibration", t_calib * 1e6, f"s={t_calib:.2f}")
    emit("quant_overhead/quantize", t_quant * 1e6, f"s={t_quant:.2f}")
    emit("quant_overhead/memory", 0.0,
         f"fp32_mb={orig/1e6:.1f};packed_mb={packed/1e6:.1f};"
         f"ratio={orig/packed:.2f}")
    return {"calib_s": t_calib, "quant_s": t_quant,
            "compression": orig / packed}


if __name__ == "__main__":
    run()
