"""ARCQuant core semantics (paper §3.2): augmentation == compensation."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import arc, baselines as BL, quant as Q


def outlier_data(rng, m=16, k=128, n_out=3, mag=40.0):
    x = rng.normal(size=(m, k)).astype(np.float32)
    cols = rng.choice(k, n_out, replace=False)
    x[:, cols] *= mag
    return x, cols


class TestOutlierSelection:
    def test_tau_rule(self, rng):
        """tau = 2^-3 * M (3-bit exponent gap between E5M2 ref and E2M1)."""
        absmax = np.ones(64, np.float32)
        absmax[:5] = 100.0            # > tau = 12.5
        plan = arc.select_outliers(absmax)
        assert plan.s == 16           # 5 rounded up to the block size
        assert plan.layer_max == 100.0
        assert set(plan.order[:5]) == set(range(5))

    def test_s_capped(self):
        absmax = np.full(64, 50.0, np.float32)
        absmax[0] = 100.0             # everything above tau
        plan = arc.select_outliers(absmax, max_fraction=0.25)
        assert plan.s == 16           # 25% of 64, block-aligned

    def test_block_alignment(self, rng):
        for n_out in [1, 15, 16, 17, 31]:
            absmax = np.ones(256, np.float32)
            absmax[:n_out] = 100.0
            plan = arc.select_outliers(absmax)
            assert plan.s % 16 == 0
            assert plan.s >= min(n_out, 64)

    def test_order_is_permutation(self, rng):
        plan = arc.select_outliers(rng.random(100).astype(np.float32))
        assert sorted(plan.order) == list(range(100))
        np.testing.assert_array_equal(plan.inverse_order[plan.order],
                                      np.arange(100))


class TestEquivalence:
    """Eq. 2: single augmented GEMM == explicit two-GEMM compensation."""

    @pytest.mark.parametrize("fmt", ["nvfp4", "mxfp4", "int4"])
    def test_exact(self, fmt, rng):
        x, _ = outlier_data(rng)
        w = rng.normal(size=(32, 128)).astype(np.float32)
        g = Q.quantize(jnp.asarray(x), fmt).fmt.block_size
        plan = arc.select_outliers(np.abs(x).max(0), fmt)
        y_aug = arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan)
        y_ref = arc.arc_matmul_reference(jnp.asarray(x), jnp.asarray(w), plan)
        # the unified GEMM accumulates over K+S in one reduction while the
        # reference adds two K-sized reductions: same math, different f32
        # summation order, so allow accumulation-order noise only
        np.testing.assert_allclose(np.asarray(y_aug), np.asarray(y_ref),
                                   rtol=2e-6, atol=1e-3)

    def test_augmented_shapes(self, rng):
        x, _ = outlier_data(rng)
        plan = arc.select_outliers(np.abs(x).max(0))
        xa = arc.augment_activations(jnp.asarray(x), plan)
        assert xa.shape == (16, 128 + plan.s)
        w = rng.normal(size=(32, 128)).astype(np.float32)
        wa = arc.augment_weights(jnp.asarray(w), plan)
        assert wa.shape == (32, 128 + plan.s)

    def test_weight_duplication_is_quantized_copy(self, rng):
        """Q_W_aug = [Q_W | Q_W_o] — duplicated columns reuse quantized values."""
        w = rng.normal(size=(8, 64)).astype(np.float32)
        plan = arc.ArcPlan(order=np.arange(64, dtype=np.int32), s=16)
        wa = arc.augment_weights(jnp.asarray(w), plan)
        np.testing.assert_array_equal(np.asarray(wa.elements[..., 64:]),
                                      np.asarray(wa.elements[..., :16]))


class TestAccuracy:
    def test_arc_beats_rtn_on_outliers(self, rng):
        x, _ = outlier_data(rng, m=64, k=256, n_out=4, mag=50.0)
        w = rng.normal(size=(128, 256)).astype(np.float32)
        y_fp = x @ w.T
        plan = arc.select_outliers(np.abs(x).max(0))
        y_arc = np.asarray(arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan))
        y_rtn = np.asarray(BL.rtn_matmul(jnp.asarray(x), jnp.asarray(w)))
        mse_arc = np.mean((y_arc - y_fp) ** 2)
        mse_rtn = np.mean((y_rtn - y_fp) ** 2)
        assert mse_arc < mse_rtn

    def test_s0_equals_rtn(self, rng):
        x = rng.normal(size=(8, 64)).astype(np.float32)
        w = rng.normal(size=(16, 64)).astype(np.float32)
        plan = arc.ArcPlan(order=np.arange(64, dtype=np.int32), s=0)
        y_arc = arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan)
        y_rtn = BL.rtn_matmul(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(y_arc), np.asarray(y_rtn))

    def test_residual_shrinks_with_s(self, rng):
        """More compensated channels -> monotone-ish error reduction."""
        x, _ = outlier_data(rng, m=32, k=128, n_out=8, mag=30.0)
        w = rng.normal(size=(64, 128)).astype(np.float32)
        y_fp = x @ w.T
        order = np.argsort(-np.abs(x).max(0)).astype(np.int32)
        errs = []
        for s in [0, 16, 32]:
            plan = arc.ArcPlan(order=order, s=s)
            y = np.asarray(arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan))
            errs.append(np.mean((y - y_fp) ** 2))
        assert errs[1] < errs[0]
        assert errs[2] <= errs[1] * 1.05


class TestInterleavedLayout:
    """Appendix D: interleave is a permutation; GEMM is permutation-invariant."""

    def test_permutation(self):
        perm = arc.interleaved_permutation(64, 32, 16)
        assert sorted(perm) == list(range(96))
        # first 16 = primary block 0, next 16 = its residual block
        np.testing.assert_array_equal(perm[:16], np.arange(16))
        np.testing.assert_array_equal(perm[16:32], 64 + np.arange(16))

    def test_gemm_invariant(self, rng):
        x, _ = outlier_data(rng, m=8, k=64)
        w = rng.normal(size=(16, 64)).astype(np.float32)
        plan = arc.select_outliers(np.abs(x).max(0))
        xa = arc.augment_activations(jnp.asarray(x), plan)
        wa = arc.augment_weights(jnp.asarray(w), plan)
        y = Q.qmatmul(xa, wa)
        xi = arc.to_interleaved(xa, 64, plan.s)
        wi = arc.to_interleaved(wa, 64, plan.s)
        yi = Q.qmatmul(xi, wi)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yi),
                                   rtol=1e-5, atol=1e-4)


class TestArcMatmulParity:
    """Satellite parity sweep: the deployed single-GEMM path
    (``arc_matmul`` over pre-augmented weights) against the explicit
    two-GEMM compensation reference, across shapes and S values."""

    @pytest.mark.parametrize("m,k,n", [(4, 32, 8), (8, 64, 16),
                                       (16, 128, 32), (32, 256, 24)])
    def test_shapes_with_calibrated_s(self, m, k, n, rng):
        x = rng.normal(size=(m, k)).astype(np.float32)
        x[:, : max(1, k // 32)] *= 30
        w = rng.normal(size=(n, k)).astype(np.float32)
        plan = arc.select_outliers(np.abs(x).max(0))
        w_aug = arc.augment_weights(jnp.asarray(w), plan)
        y = arc.arc_matmul(jnp.asarray(x), w_aug, plan)
        y_ref = arc.arc_matmul_reference(jnp.asarray(x), jnp.asarray(w), plan)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-6, atol=1e-3)

    @pytest.mark.parametrize("s", [0, 16, 32, 64])
    def test_explicit_s_values(self, s, rng):
        k = 128
        x = rng.normal(size=(8, k)).astype(np.float32)
        w = rng.normal(size=(16, k)).astype(np.float32)
        order = np.argsort(-np.abs(x).max(0)).astype(np.int32)
        plan = arc.ArcPlan(order=order, s=s)
        w_aug = arc.augment_weights(jnp.asarray(w), plan)
        assert w_aug.shape == (16, k + s)
        y = arc.arc_matmul(jnp.asarray(x), w_aug, plan)
        y_ref = arc.arc_matmul_reference(jnp.asarray(x), jnp.asarray(w), plan)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-6, atol=1e-3)

    def test_max_fraction_clamp_to_zero(self):
        """A cap below one block floors S to 0 (augmentation disabled)."""
        absmax = np.full(32, 50.0, np.float32)      # all above tau
        plan = arc.select_outliers(absmax, max_fraction=0.1)
        assert plan.s == 0                           # (0.1*32)//16*16 == 0

    @pytest.mark.parametrize("max_fraction,want", [(0.25, 32), (0.5, 64),
                                                   (0.125, 16)])
    def test_max_fraction_clamp_block_aligned(self, max_fraction, want):
        absmax = np.full(128, 50.0, np.float32)     # every channel an outlier
        plan = arc.select_outliers(absmax, max_fraction=max_fraction)
        assert plan.s == want and plan.s % 16 == 0


class TestInterleavedRoundTrip:
    """Appendix D layout: interleaving is invertible and preserves the
    logical [primary | residual] content block-for-block."""

    @pytest.mark.parametrize("k,s", [(64, 0), (64, 32), (128, 32), (256, 64)])
    def test_permutation_round_trip(self, k, s):
        perm = arc.interleaved_permutation(k, s, 16)
        assert sorted(perm) == list(range(k + s))
        inv = np.argsort(perm)
        np.testing.assert_array_equal(perm[inv], np.arange(k + s))
        np.testing.assert_array_equal(
            np.arange(k + s)[perm][inv], np.arange(k + s))

    def test_to_interleaved_round_trip_against_logical(self, rng):
        k, g = 128, 16
        x, _ = outlier_data(rng, m=8, k=k)
        plan = arc.select_outliers(np.abs(x).max(0))
        s = plan.s
        assert s > 0
        xa = arc.augment_activations(jnp.asarray(x), plan)   # logical layout
        xi = arc.to_interleaved(xa, k, s)
        perm = arc.interleaved_permutation(k, s, g)
        inv = np.argsort(perm)
        # elements: undoing the channel permutation recovers the logical
        # [primary | residual] augmented tensor exactly
        np.testing.assert_array_equal(
            np.asarray(xi.elements)[..., inv], np.asarray(xa.elements))
        # scales: block b of the interleaved tensor is block perm[b*g]//g
        # of the logical tensor
        sperm = perm[::g] // g
        np.testing.assert_array_equal(
            np.asarray(xi.scales), np.asarray(xa.scales)[..., sperm])
        # and dequantized content is preserved channel-for-channel
        np.testing.assert_allclose(
            np.asarray(xi.dequantize())[..., inv],
            np.asarray(xa.dequantize()), rtol=1e-6, atol=1e-7)
