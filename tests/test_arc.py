"""ARCQuant core semantics (paper §3.2): augmentation == compensation."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import arc, baselines as BL, quant as Q


def outlier_data(rng, m=16, k=128, n_out=3, mag=40.0):
    x = rng.normal(size=(m, k)).astype(np.float32)
    cols = rng.choice(k, n_out, replace=False)
    x[:, cols] *= mag
    return x, cols


class TestOutlierSelection:
    def test_tau_rule(self, rng):
        """tau = 2^-3 * M (3-bit exponent gap between E5M2 ref and E2M1)."""
        absmax = np.ones(64, np.float32)
        absmax[:5] = 100.0            # > tau = 12.5
        plan = arc.select_outliers(absmax)
        assert plan.s == 16           # 5 rounded up to the block size
        assert plan.layer_max == 100.0
        assert set(plan.order[:5]) == set(range(5))

    def test_s_capped(self):
        absmax = np.full(64, 50.0, np.float32)
        absmax[0] = 100.0             # everything above tau
        plan = arc.select_outliers(absmax, max_fraction=0.25)
        assert plan.s == 16           # 25% of 64, block-aligned

    def test_block_alignment(self, rng):
        for n_out in [1, 15, 16, 17, 31]:
            absmax = np.ones(256, np.float32)
            absmax[:n_out] = 100.0
            plan = arc.select_outliers(absmax)
            assert plan.s % 16 == 0
            assert plan.s >= min(n_out, 64)

    def test_order_is_permutation(self, rng):
        plan = arc.select_outliers(rng.random(100).astype(np.float32))
        assert sorted(plan.order) == list(range(100))
        np.testing.assert_array_equal(plan.inverse_order[plan.order],
                                      np.arange(100))


class TestEquivalence:
    """Eq. 2: single augmented GEMM == explicit two-GEMM compensation."""

    @pytest.mark.parametrize("fmt", ["nvfp4", "mxfp4", "int4"])
    def test_exact(self, fmt, rng):
        x, _ = outlier_data(rng)
        w = rng.normal(size=(32, 128)).astype(np.float32)
        g = Q.quantize(jnp.asarray(x), fmt).fmt.block_size
        plan = arc.select_outliers(np.abs(x).max(0), fmt)
        y_aug = arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan)
        y_ref = arc.arc_matmul_reference(jnp.asarray(x), jnp.asarray(w), plan)
        np.testing.assert_array_equal(np.asarray(y_aug), np.asarray(y_ref))

    def test_augmented_shapes(self, rng):
        x, _ = outlier_data(rng)
        plan = arc.select_outliers(np.abs(x).max(0))
        xa = arc.augment_activations(jnp.asarray(x), plan)
        assert xa.shape == (16, 128 + plan.s)
        w = rng.normal(size=(32, 128)).astype(np.float32)
        wa = arc.augment_weights(jnp.asarray(w), plan)
        assert wa.shape == (32, 128 + plan.s)

    def test_weight_duplication_is_quantized_copy(self, rng):
        """Q_W_aug = [Q_W | Q_W_o] — duplicated columns reuse quantized values."""
        w = rng.normal(size=(8, 64)).astype(np.float32)
        plan = arc.ArcPlan(order=np.arange(64, dtype=np.int32), s=16)
        wa = arc.augment_weights(jnp.asarray(w), plan)
        np.testing.assert_array_equal(np.asarray(wa.elements[..., 64:]),
                                      np.asarray(wa.elements[..., :16]))


class TestAccuracy:
    def test_arc_beats_rtn_on_outliers(self, rng):
        x, _ = outlier_data(rng, m=64, k=256, n_out=4, mag=50.0)
        w = rng.normal(size=(128, 256)).astype(np.float32)
        y_fp = x @ w.T
        plan = arc.select_outliers(np.abs(x).max(0))
        y_arc = np.asarray(arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan))
        y_rtn = np.asarray(BL.rtn_matmul(jnp.asarray(x), jnp.asarray(w)))
        mse_arc = np.mean((y_arc - y_fp) ** 2)
        mse_rtn = np.mean((y_rtn - y_fp) ** 2)
        assert mse_arc < mse_rtn

    def test_s0_equals_rtn(self, rng):
        x = rng.normal(size=(8, 64)).astype(np.float32)
        w = rng.normal(size=(16, 64)).astype(np.float32)
        plan = arc.ArcPlan(order=np.arange(64, dtype=np.int32), s=0)
        y_arc = arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan)
        y_rtn = BL.rtn_matmul(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(y_arc), np.asarray(y_rtn))

    def test_residual_shrinks_with_s(self, rng):
        """More compensated channels -> monotone-ish error reduction."""
        x, _ = outlier_data(rng, m=32, k=128, n_out=8, mag=30.0)
        w = rng.normal(size=(64, 128)).astype(np.float32)
        y_fp = x @ w.T
        order = np.argsort(-np.abs(x).max(0)).astype(np.int32)
        errs = []
        for s in [0, 16, 32]:
            plan = arc.ArcPlan(order=order, s=s)
            y = np.asarray(arc.fake_quant_matmul(jnp.asarray(x), jnp.asarray(w), plan))
            errs.append(np.mean((y - y_fp) ** 2))
        assert errs[1] < errs[0]
        assert errs[2] <= errs[1] * 1.05


class TestInterleavedLayout:
    """Appendix D: interleave is a permutation; GEMM is permutation-invariant."""

    def test_permutation(self):
        perm = arc.interleaved_permutation(64, 32, 16)
        assert sorted(perm) == list(range(96))
        # first 16 = primary block 0, next 16 = its residual block
        np.testing.assert_array_equal(perm[:16], np.arange(16))
        np.testing.assert_array_equal(perm[16:32], 64 + np.arange(16))

    def test_gemm_invariant(self, rng):
        x, _ = outlier_data(rng, m=8, k=64)
        w = rng.normal(size=(16, 64)).astype(np.float32)
        plan = arc.select_outliers(np.abs(x).max(0))
        xa = arc.augment_activations(jnp.asarray(x), plan)
        wa = arc.augment_weights(jnp.asarray(w), plan)
        y = Q.qmatmul(xa, wa)
        xi = arc.to_interleaved(xa, 64, plan.s)
        wi = arc.to_interleaved(wa, 64, plan.s)
        yi = Q.qmatmul(xi, wi)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yi),
                                   rtol=1e-5, atol=1e-4)
