"""Paged KV cache: greedy parity vs the slot pool across architectures,
page lifecycle (allocation, release, reuse), preemption/resume, ragged
bucketed decode, and engine page accounting."""
import copy

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import (PagedCacheManager, PagedServingEngine, Request,
                           ServingEngine)

KEY = jax.random.PRNGKey(0)

# dense attention, MoE, SSM (rwkv), hybrid (mamba+attn+moe), local/global
PARITY_ARCHS = ["qwen2-1.5b", "qwen3-moe-235b-a22b", "rwkv6-3b",
                "jamba-v0.1-52b", "gemma3-12b"]


def _build(arch):
    return _build_from_cfg(ARCHS[arch].reduced())


def _build_from_cfg(cfg):
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


@pytest.fixture(scope="module", params=PARITY_ARCHS)
def served(request):
    return request.param, _build(request.param)


def _workload(cfg, n=4, seed=42):
    rng = np.random.default_rng(seed)
    return [Request(
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(3, 15))).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 9))) for _ in range(n)]


def _tokens(engine, reqs):
    served = engine.run(copy.deepcopy(reqs))
    assert all(r.done for r in served)
    return [r.out_tokens for r in served]


# ---------------------------------------------------------------------------
# Greedy parity: the paged pool is a pure memory-layout change
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_paged_matches_slot_pool_greedy(served):
    """Paged engine serves token-identical greedy traces to the slot pool
    on dense, MoE, SSM, and hybrid configs (the correctness anchor)."""
    arch, (cfg, quant, plans, qparams) = served
    reqs = _workload(cfg, n=4)
    slot = ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                         max_len=48)
    paged = PagedServingEngine(qparams, cfg, quant, plans, batch_size=2,
                               max_len=48)
    assert _tokens(slot, reqs) == _tokens(paged, reqs), arch
    s = paged.last_stats
    assert s.num_pages > 0 and s.peak_pages > 0
    assert 0.0 < s.page_utilization <= 1.0
    assert s.preemptions == 0       # parity pool is sized for the slot bound


@pytest.mark.slow
def test_continuous_matches_static_greedy(served):
    """Continuous-vs-static greedy parity beyond dense attention (the
    ROADMAP parity item): MoE, SSM, and hybrid configs too."""
    from repro.serving import StaticBatchEngine
    arch, (cfg, quant, plans, qparams) = served
    reqs = _workload(cfg, n=4, seed=11)
    cont = ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                         max_len=48)
    stat = StaticBatchEngine(qparams, cfg, quant, plans, batch_size=2,
                             max_len=48)
    assert _tokens(cont, reqs) == _tokens(stat, reqs), arch


# ---------------------------------------------------------------------------
# Paged-specific behavior (dense config keeps these fast)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense():
    return _build("qwen2-1.5b")


@pytest.mark.slow
def test_preemption_preserves_greedy_tokens(dense):
    """A pool too small for both requests forces eviction + re-prefill;
    the greedy trace must be unchanged (recompute is exact)."""
    cfg, quant, plans, qparams = dense
    reqs = _workload(cfg, n=4)
    ref = _tokens(ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                                max_len=48), reqs)
    tiny = PagedServingEngine(qparams, cfg, quant, plans, batch_size=2,
                              max_len=48, num_pages=3, block_size=16)
    assert _tokens(tiny, reqs) == ref
    assert tiny.last_stats.preemptions > 0


@pytest.mark.slow
def test_bucketed_ragged_decode_matches_full_batch(dense):
    """decode_buckets=True launches decode at the active-count bucket
    instead of the full slot count; greedy tokens are unchanged."""
    cfg, quant, plans, qparams = dense
    reqs = _workload(cfg, n=5, seed=3)
    full = PagedServingEngine(qparams, cfg, quant, plans, batch_size=4,
                              max_len=48)
    ragged = PagedServingEngine(qparams, cfg, quant, plans, batch_size=4,
                                max_len=48, decode_buckets=True)
    assert _tokens(full, reqs) == _tokens(ragged, reqs)


@pytest.mark.slow
def test_more_slots_than_slot_pool_memory(dense):
    """The headline claim: with the page count of a 2-slot slot pool, the
    paged engine runs 4 slots concurrently and drains a mixed workload in
    fewer decode steps."""
    cfg, quant, plans, qparams = dense
    reqs = _workload(cfg, n=8, seed=5)
    slot = ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                         max_len=48)
    slot.run(copy.deepcopy(reqs))
    pages_of_two_slots = 2 * (48 // 16) + 1
    paged = PagedServingEngine(qparams, cfg, quant, plans, batch_size=4,
                               max_len=48, num_pages=pages_of_two_slots,
                               block_size=16)
    out = paged.run(copy.deepcopy(reqs))
    assert all(r.done for r in out)
    assert paged.last_stats.decode_steps < slot.last_stats.decode_steps


@pytest.mark.slow
def test_ssm_family_hybrid_full_attn_paged():
    """ssm-family configs attach cmix_shift to every mixer's cache dict —
    including a paged full-attention position, where it must ride as a
    slot-resident leaf through the page write/release/gather ops."""
    import dataclasses
    base = ARCHS["rwkv6-3b"].reduced()
    cfg = dataclasses.replace(base, name="rwkv6-attn-hybrid",
                              mixer_pattern=("rwkv", "full"),
                              ffn_pattern=("dense", "dense"), num_layers=2)
    built = _build_from_cfg(cfg)
    reqs = _workload(cfg, n=3, seed=7)
    cfg, quant, plans, qparams = built
    slot = ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                         max_len=48)
    paged = PagedServingEngine(qparams, cfg, quant, plans, batch_size=2,
                               max_len=48, decode_buckets=True)
    assert _tokens(slot, reqs) == _tokens(paged, reqs)


@pytest.mark.slow
def test_admission_does_not_overcommit_pages(dense):
    """One usable page, two free slots, three queued requests: the gate
    must reserve pages as it admits (admitting two against the same free
    page would blow up the allocator) and still drain the queue."""
    cfg, quant, plans, qparams = dense
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 4)
                    .astype(np.int32), max_new_tokens=2) for _ in range(3)]
    eng = PagedServingEngine(qparams, cfg, quant, plans, batch_size=2,
                             max_len=32, num_pages=2, block_size=16)
    out = eng.run(reqs)
    assert all(r.done and len(r.out_tokens) == 2 for r in out)


def test_oversized_request_rejected_by_capacity(dense):
    cfg, quant, plans, qparams = dense
    eng = PagedServingEngine(qparams, cfg, quant, plans, batch_size=1,
                             max_len=64, num_pages=3, block_size=16)
    # 2 usable pages = 32 positions < 40 needed: preemption could never
    # free enough, so the liveness check rejects it up front
    with pytest.raises(ValueError):
        eng.run([Request(prompt=np.arange(32, dtype=np.int32),
                         max_new_tokens=8)])


# ---------------------------------------------------------------------------
# PagedCacheManager unit tests (no model forward)
# ---------------------------------------------------------------------------


def _manager(num_pages=5, slots=2):
    cfg = ARCHS["qwen2-1.5b"].reduced()
    return PagedCacheManager(cfg, slots, 48, num_pages=num_pages,
                             block_size=16)


def _claim(m, slot, prefill_len):
    """Claim the blocks a prefill of ``prefill_len`` tokens occupies
    (what the backend's per-chunk allocation does on admission)."""
    for b in range(m.blocks_for(prefill_len)):
        assert m.ensure(slot, b)


class TestPagedCacheManager:
    def test_null_page_reserved(self):
        m = _manager()
        assert m.usable_pages == 4
        assert 0 not in m._free

    def test_allocate_release_roundtrip(self):
        m = _manager()
        _claim(m, 0, 20)                    # 2 blocks
        assert m.pages_in_use == 2
        assert (m.tables[0, :2] >= 1).all() and m.tables[0, 2] < 0
        m.release(0)
        assert m.pages_in_use == 0
        assert (m.tables[0] < 0).all()

    def test_ensure_allocates_once(self):
        m = _manager()
        _claim(m, 0, 10)                    # 1 block
        assert m.ensure(0, 1)
        page = m.tables[0, 1]
        assert page >= 1
        assert m.ensure(0, 1)               # idempotent
        assert m.tables[0, 1] == page

    def test_ensure_fails_when_exhausted(self):
        m = _manager(num_pages=2)           # 1 usable page
        _claim(m, 0, 10)
        assert not m.ensure(1, 0)

    def test_admission_charge_counts_first_decode_block(self):
        m = _manager(num_pages=3)           # 2 usable
        # prefill 1 block + tail block fits; 2 prefill blocks + tail not
        assert m.admission_charge(np.arange(16)) == (0, 2)
        assert m.admission_charge(np.arange(32))[1] > m.free_page_count

    def test_read_tables_null_for_unallocated(self):
        m = _manager()
        _claim(m, 1, 5)
        t = m.read_tables()
        assert t[0].tolist() == [0, 0, 0]
        assert t[1, 0] >= 1 and t[1, 1] == 0

    def test_released_pages_are_reused(self):
        m = _manager(num_pages=2)
        _claim(m, 0, 10)
        page = int(m.tables[0, 0])
        m.release(0)
        _claim(m, 1, 10)
        assert int(m.tables[1, 0]) == page


# ---------------------------------------------------------------------------
# Scheduler preemption unit tests (no model)
# ---------------------------------------------------------------------------


def _state(n_prompt=8, max_new=6, rid=0):
    from repro.serving import GenerationRequest, RequestState, SamplingParams
    return RequestState(
        GenerationRequest(prompt=np.arange(n_prompt, dtype=np.int32),
                          sampling=SamplingParams(max_new_tokens=max_new)),
        rid=rid)


class TestSchedulerPreemption:
    def _decoding_slot(self, sched, n_prompt=8, max_new=6):
        sched.submit(_state(n_prompt=n_prompt, max_new=max_new))
        [(slot, st)] = sched.admissions()
        sched.record_token(slot, 7)
        return slot, st

    def test_preempt_requeues_at_front(self):
        from repro.serving import FREE, Scheduler
        sched = Scheduler(num_slots=1, max_len=64)
        slot, st = self._decoding_slot(sched)
        sched.submit(_state(n_prompt=4, rid=1))
        got = sched.preempt(slot)
        assert got is st and st.preemptions == 1
        assert slot.state == FREE and sched.queue[0] is st

    def test_resume_restores_decode_state(self):
        from repro.serving import DECODE, Scheduler
        sched = Scheduler(num_slots=1, max_len=64)
        slot, st = self._decoding_slot(sched, n_prompt=5)
        sched.record_token(slot, 9)
        sched.preempt(slot)
        [(slot2, got)] = sched.admissions()
        assert got is st
        sched.resume(slot2)
        assert slot2.state == DECODE
        assert slot2.last_token == 9                # last sampled token
        assert slot2.next_pos == 5 + 2 - 1          # prompt + outs - 1
        assert st.resume_prefill_len == 6

    def test_preempt_mid_chunked_prefill(self):
        """A PREFILL-state victim (chunked prefill in flight) discards its
        partial cache and requeues at the front with no tokens lost."""
        from repro.serving import FREE, PREFILL, Scheduler
        sched = Scheduler(num_slots=1, max_len=64)
        sched.submit(_state(n_prompt=30))
        [(slot, st)] = sched.admissions()
        slot.prefill_pos = 8                        # one chunk fed
        slot.prefill_cache = object()
        assert slot.state == PREFILL
        got = sched.preempt(slot)
        assert got is st and st.preemptions == 1 and not st.out_tokens
        assert slot.state == FREE
        assert slot.prefill_pos == 0 and slot.prefill_cache is None
        assert sched.queue[0] is st

    def test_admission_gate_blocks_head_of_line(self):
        from repro.serving import Scheduler
        sched = Scheduler(num_slots=2, max_len=64)
        big = _state(n_prompt=30, rid=0)
        small = _state(n_prompt=2, rid=1)
        sched.submit(big)
        sched.submit(small)
        # gate rejects the big head: FIFO means nothing is admitted
        out = sched.admissions(lambda st: st.prompt_len < 10)
        assert out == []
        assert list(sched.queue) == [big, small]
