"""Fused GEMM epilogues, decode residency, and dropless MoE dispatch.

Covers the deployed hot-path extensions end to end:

  * kernel level — ``nvfp4_gemm_swiglu`` (dual-weight gate/up launch with
    the in-VMEM silu(g)*u epilogue) and the bias epilogue are bitwise
    equal to the unfused chains; the decode resident schedule returns
    the exact streamed result while decoding each tile once
  * plan level — ``gemm_plan`` rejects block sizes that would split the
    packed byte-pair / scale-group unit; ``swiglu_plan`` prices the
    fused launch at strictly fewer HBM bytes than two back-to-back GEMMs
  * layer / forward level — fused pairs produce bit-identical MLP and
    expert-FFN outputs under jit, and full forward() greedy numerics are
    unchanged with ``fuse_epilogue`` on vs off
  * MoE dispatch — dropless (cap = S*K) matches an ample-capacity run
    bitwise, and the paged engine's prefix cache is enabled (and shares
    pages bit-identically) for MoE configs under dropless
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.kernels import ops as KOPS
from repro.kernels.arc_fused_quant import arc_fused_quantize
from repro.kernels.nvfp4_gemm import (GROUP, gemm_plan, nvfp4_gemm,
                                      nvfp4_gemm_swiglu, swiglu_plan)
from repro.models import capture_stats, init_params
from repro.models import layers as L
from repro.models.lm import forward, init_cache
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import PagedServingEngine, Request, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    """qwen2-1.5b proxy (has MLP bias-free gate/up + qkv bias): packed
    weights, plans (with detected fused pairs), and period-0 slices."""
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=1)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc", backend="pallas",
                        act_scale="calibrated", interpret=True)
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


def _mlp_operands(plans, qparams):
    """Period-0 gate/up operands + a quantized activation at M rows."""
    arrs = {k[3:]: jax.tree.map(lambda v: v[0], v)
            for k, v in plans.arrays.items() if k.startswith("b0.")}
    meta = {k[3:]: v for k, v in plans.meta.items() if k.startswith("b0.")}
    mlp = {k: jax.tree.map(lambda v: v[0], v)
           for k, v in qparams["blocks"][0]["mlp"].items()}
    return arrs, meta, mlp


def _quantize_x(m, k, arrs, meta, seed=3):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)
    xc, xs = arc_fused_quantize(x, jnp.ones((k,), jnp.float32),
                                arrs["mlp.w_gate"]["order"],
                                arrs["mlp.w_gate"]["act_scales"],
                                meta["mlp.w_gate"], apply_norm=False,
                                interpret=True)
    return xc, xs


# ---------------------------------------------------------------------------
# plan validation (satellite): reject blocks that split the packed unit
# ---------------------------------------------------------------------------

def test_gemm_plan_rejects_misaligned_block_k():
    unit = 2 * GROUP
    for bad in (unit - 1, unit + 1, unit // 2, 3 * unit + 7):
        with pytest.raises(ValueError, match="packed byte-pair"):
            gemm_plan(8, 256, 4 * unit, block_k=bad)
    with pytest.raises(ValueError, match="positive tile size"):
        gemm_plan(8, 256, 4 * unit, block_m=0)
    # aligned multiples are accepted
    for ok in (unit, 2 * unit, 64 * unit):
        assert gemm_plan(8, 256, 64 * unit, block_k=ok)["bk"] % unit == 0


def test_swiglu_plan_saves_hbm_and_decodes():
    """The fused launch reads the activation once (not per projection)
    and writes one output tile instead of two full outputs + one fused
    read-back of each."""
    m, n, ka = 64, 256, 2048
    single = gemm_plan(m, n, ka)
    fused = swiglu_plan(m, n, ka, out_bytes=2)
    assert fused["kernel"] == "nvfp4_gemm_swiglu"
    assert fused["hbm_read_bytes"] < 2 * single["hbm_read_bytes"]
    assert fused["hbm_write_bytes"] < 2 * single["hbm_write_bytes"]
    # both packed weights still decoded exactly once per (j, k) tile
    assert fused["weight_tile_decodes"] == 2 * single["weight_tile_decodes"]


def test_resident_plan_decodes_activation_once():
    n, ka = 256, 2048
    plan = gemm_plan(4, n, ka)          # decode shape
    assert plan["path"] == "decode_fast" and plan["residency"]
    assert plan["x_tile_decodes"] == 1
    streamed = gemm_plan(4, n, ka, block_k=ka // 4)
    assert streamed["x_tile_decodes"] >= 1
    big = gemm_plan(512, n, ka)         # prefill shape never resident
    assert not big["residency"]
    with pytest.raises(ValueError, match="decode fast path"):
        nvfp4_gemm(jnp.zeros((512, ka), jnp.uint8),
                   jnp.zeros((512, ka // GROUP), jnp.uint8),
                   jnp.zeros((n, ka // 2), jnp.uint8),
                   jnp.zeros((n, ka // GROUP), jnp.uint8),
                   w_tensor_scale=jnp.float32(1.0), w_packed=True,
                   interpret=True, resident=True)


# ---------------------------------------------------------------------------
# kernel-level bitwise parity (fast interpret-mode smoke)
# ---------------------------------------------------------------------------

def test_kernel_swiglu_bitwise(dense_setup):
    cfg, quant, plans, qparams = dense_setup
    arrs, meta, mlp = _mlp_operands(plans, qparams)
    xc, xs = _quantize_x(5, cfg.d_model, arrs, meta)
    gc, gs, gt, gp = KOPS.qtensor_gemm_operands(mlp["w_gate"])
    uc, us, ut, _ = KOPS.qtensor_gemm_operands(mlp["w_up"])
    yg = nvfp4_gemm(xc, xs, gc, gs, w_tensor_scale=gt, w_packed=gp,
                    interpret=True)
    yu = nvfp4_gemm(xc, xs, uc, us, w_tensor_scale=ut, w_packed=gp,
                    interpret=True)
    for dt in (jnp.bfloat16, jnp.float32):
        ref = L._swiglu(yg.astype(dt), yu.astype(dt))
        out = nvfp4_gemm_swiglu(xc, xs, gc, gs, uc, us, g_tensor_scale=gt,
                                u_tensor_scale=ut, w_packed=gp,
                                out_dtype=dt, interpret=True)
        assert out.dtype == dt
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_kernel_bias_epilogue_bitwise(dense_setup):
    cfg, quant, plans, qparams = dense_setup
    arrs, meta, _ = _mlp_operands(plans, qparams)
    blk = {k: jax.tree.map(lambda v: v[0], v)
           for k, v in qparams["blocks"][0]["attn"].items()}
    xc, xs = _quantize_x(4, cfg.d_model, arrs, meta, seed=5)
    wc, ws, wt, wp = KOPS.qtensor_gemm_operands(blk["wq"])
    b = jax.random.normal(jax.random.PRNGKey(6), (wc.shape[0],), jnp.float32)
    base = nvfp4_gemm(xc, xs, wc, ws, w_tensor_scale=wt, w_packed=wp,
                      interpret=True)
    fused = nvfp4_gemm(xc, xs, wc, ws, w_tensor_scale=wt, w_packed=wp,
                       interpret=True, bias=b)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(base + b))


def test_kernel_resident_bitwise(dense_setup):
    cfg, quant, plans, qparams = dense_setup
    arrs, meta, mlp = _mlp_operands(plans, qparams)
    xc, xs = _quantize_x(4, cfg.d_model, arrs, meta, seed=7)
    gc, gs, gt, gp = KOPS.qtensor_gemm_operands(mlp["w_gate"])
    uc, us, ut, _ = KOPS.qtensor_gemm_operands(mlp["w_up"])
    on = nvfp4_gemm(xc, xs, gc, gs, w_tensor_scale=gt, w_packed=gp,
                    interpret=True, resident=True)
    off = nvfp4_gemm(xc, xs, gc, gs, w_tensor_scale=gt, w_packed=gp,
                     interpret=True, resident=False)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    # the fused swiglu launch honors the same residency toggle
    s_on = nvfp4_gemm_swiglu(xc, xs, gc, gs, uc, us, g_tensor_scale=gt,
                             u_tensor_scale=ut, w_packed=gp,
                             interpret=True, resident=True)
    s_off = nvfp4_gemm_swiglu(xc, xs, gc, gs, uc, us, g_tensor_scale=gt,
                              u_tensor_scale=ut, w_packed=gp,
                              interpret=True, resident=False)
    np.testing.assert_array_equal(np.asarray(s_on), np.asarray(s_off))


# ---------------------------------------------------------------------------
# layer-level parity under jit (the epilogue must be compilation-stable)
# ---------------------------------------------------------------------------

def test_mlp_layer_fused_parity_under_jit(dense_setup):
    cfg, quant, plans, qparams = dense_setup
    arrs, meta, mlp = _mlp_operands(plans, qparams)
    assert plans.fused.get("b0.mlp.w_gate") == "b0.mlp.w_up"
    ctx_f = L.LayerCtx(cfg, quant, plan_arrays=arrs, plan_meta=meta,
                       fused_pairs={"mlp.w_gate": "mlp.w_up"})
    ctx_u = L.LayerCtx(cfg, quant, plan_arrays=arrs, plan_meta=meta,
                       fused_pairs=None)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 5, cfg.d_model),
                          jnp.bfloat16)
    y_f = jax.jit(lambda v: L.mlp_layer(ctx_f, "mlp", mlp, v))(x)
    y_u = jax.jit(lambda v: L.mlp_layer(ctx_u, "mlp", mlp, v))(x)
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_u))


def test_forward_fused_parity(dense_setup):
    """forward() with detected fused pairs == forward() with fusion
    stripped from the plan bundle, bit-for-bit — prefill and cache."""
    cfg, quant, plans, qparams = dense_setup
    plans_u = dataclasses.replace(plans, fused={})
    toks = jax.random.randint(jax.random.PRNGKey(11), (1, 5),
                              0, cfg.vocab_size)
    lf, _, _ = forward(qparams, cfg, tokens=toks, quant=quant, plans=plans)
    lu, _, _ = forward(qparams, cfg, tokens=toks, quant=quant, plans=plans_u)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lu))
    cache = init_cache(cfg, 1, 16)
    cf, _, _ = forward(qparams, cfg, tokens=toks, cache=cache, quant=quant,
                       plans=plans)
    cu, _, _ = forward(qparams, cfg, tokens=toks, cache=cache, quant=quant,
                       plans=plans_u)
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(cu))


# ---------------------------------------------------------------------------
# dropless MoE dispatch
# ---------------------------------------------------------------------------

def test_moe_dropless_matches_ample_capacity():
    """cap = S*K drops nothing, so an explicit capacity run with enough
    slots for every token (capacity_factor = E) is bit-identical."""
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced(layers=1)
    assert cfg.moe_dropless
    params = init_params(cfg, KEY)
    cfg_cap = dataclasses.replace(cfg, moe_dropless=False,
                                  capacity_factor=float(cfg.num_experts))
    for shape in ((2, 16), (1, 16), (3, 16)):
        toks = jax.random.randint(jax.random.PRNGKey(13), shape, 0,
                                  cfg.vocab_size)
        la, _, _ = forward(params, cfg, tokens=toks)
        lb, _, _ = forward(params, cfg_cap, tokens=toks)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_moe_dropless_batch_shape_independent():
    """No capacity truncation means a token's expert mix can't change
    with who else is in the batch: row 0 of a 1-seq batch == row 0 of a
    3-seq batch, bitwise."""
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced(layers=1)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(17), (3, 16), 0,
                              cfg.vocab_size)
    l3, _, _ = forward(params, cfg, tokens=toks)
    l1, _, _ = forward(params, cfg, tokens=toks[:1])
    np.testing.assert_array_equal(np.asarray(l1[0]), np.asarray(l3[0]))


# ---------------------------------------------------------------------------
# engine level (interpret-mode Pallas end to end: slow job)
# ---------------------------------------------------------------------------

def _moe_setup():
    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced(layers=2)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc", interpret=True)
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


def _shared_prefix_reqs(cfg, n=3, seed=0):
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    return [Request(prompt=np.concatenate(
                [sysp, rng.integers(0, cfg.vocab_size, 3 + i)
                 .astype(np.int32)]), max_new_tokens=4)
            for i in range(n)]


@pytest.mark.slow
def test_engine_fused_vs_unfused_greedy_parity(dense_setup):
    cfg, quant, plans, qparams = dense_setup
    reqs = _shared_prefix_reqs(cfg)
    toks = {}
    for fuse in (True, False):
        q = dataclasses.replace(quant, fuse_epilogue=fuse)
        eng = ServingEngine(qparams, cfg, q, plans, batch_size=2,
                            max_len=64, backend="pallas", interpret=True)
        served = eng.run(copy.deepcopy(reqs))
        assert all(r.done for r in served)
        toks[fuse] = [r.out_tokens for r in served]
    assert toks[True] == toks[False]


@pytest.mark.slow
def test_moe_engine_fused_vs_unfused_greedy_parity():
    cfg, quant, plans, qparams = _moe_setup()
    assert any("experts_gate" in k for k in plans.fused)
    reqs = _shared_prefix_reqs(cfg, seed=1)
    toks = {}
    for fuse in (True, False):
        q = dataclasses.replace(quant, fuse_epilogue=fuse)
        eng = ServingEngine(qparams, cfg, q, plans, batch_size=2,
                            max_len=64, backend="pallas", interpret=True)
        served = eng.run(copy.deepcopy(reqs))
        toks[fuse] = [r.out_tokens for r in served]
    assert toks[True] == toks[False]


@pytest.mark.slow
def test_moe_prefix_cache_shares_and_matches():
    """Dropless dispatch makes MoE prefill batch-shape independent, so
    the paged engine's prefix cache is enabled for MoE configs — pages
    are actually shared and greedy tokens are unchanged."""
    cfg, quant, plans, qparams = _moe_setup()
    reqs = _shared_prefix_reqs(cfg, seed=1)
    kw = dict(batch_size=2, max_len=64, backend="pallas", interpret=True)
    on = PagedServingEngine(qparams, cfg, quant, plans, prefix_cache=True,
                            **kw)
    assert on.make_core().pool.prefix_enabled
    off = PagedServingEngine(qparams, cfg, quant, plans, prefix_cache=False,
                             **kw)
    t_on = [r.out_tokens for r in on.run(copy.deepcopy(reqs))]
    t_off = [r.out_tokens for r in off.run(copy.deepcopy(reqs))]
    assert t_on == t_off
    assert on.last_stats.cached_prefix_tokens > 0
    # the gate still closes when dispatch can drop tokens
    cfg_cap = dataclasses.replace(cfg, moe_dropless=False)
    capped = PagedServingEngine(qparams, cfg_cap, quant, plans,
                                prefix_cache=True, **kw)
    assert not capped.make_core().pool.prefix_enabled
