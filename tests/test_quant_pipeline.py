"""Calibration -> plans -> quantized serving weights (paper §3.2 offline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.core.calibration import Calibrator
from repro.models import capture_stats, forward, init_params
from repro.quant import (make_plan_bundle, plan_summary,
                         quantize_weights_for_serving)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["llama31-8b"].reduced(layers=2)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    return cfg, params, toks, stats


def test_capture_covers_all_linears(setup):
    cfg, params, toks, stats = setup
    names = set(stats)
    assert {"b0.attn.wq", "b0.attn.wk", "b0.attn.wv", "b0.attn.wo",
            "b0.mlp.w_gate", "b0.mlp.w_up", "b0.mlp.w_down"} <= names
    for v in stats.values():
        assert v.shape[0] == cfg.num_periods
        assert bool(jnp.isfinite(v).all()) and float(v.min()) >= 0


def test_plan_bundle(setup):
    cfg, params, toks, stats = setup
    q = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, q, params)
    for name, s in plans.meta.items():
        assert s % 16 == 0
        order = np.asarray(plans.arrays[name]["order"])
        for row in order:
            assert sorted(row) == list(range(order.shape[-1]))
    summ = plan_summary(plans)
    assert all(0 <= v["overhead"] <= 0.25 + 1e-9 for v in summ.values())


@pytest.mark.parametrize("method", ["rtn", "smooth", "quarot", "atom", "arc"])
def test_all_methods_run(setup, method):
    cfg, params, toks, stats = setup
    q = QuantConfig(method=method)
    plans = make_plan_bundle(stats, cfg, q, params)
    lg, _, _ = forward(params, cfg, tokens=toks, quant=q, plans=plans)
    assert bool(jnp.isfinite(lg[..., : cfg.vocab_size]).all())


def test_deployed_equals_simulated(setup):
    cfg, params, toks, stats = setup
    q = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, q, params)
    qp = quantize_weights_for_serving(params, cfg, q, plans, pack=True)
    lg_d, _, _ = forward(qp, cfg, tokens=toks, quant=q, plans=plans)
    lg_s, _, _ = forward(params, cfg, tokens=toks, quant=q, plans=plans)
    np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_s))


def test_packed_memory_footprint(setup):
    cfg, params, toks, stats = setup
    q = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, q, params)
    qp = quantize_weights_for_serving(params, cfg, q, plans, pack=True)
    w = qp["blocks"][0]["mlp"]["w_gate"]
    bf16_bytes = np.prod(params["blocks"][0]["mlp"]["w_gate"].shape) * 2
    packed_bytes = (np.prod(w.elements.shape) * 1 + np.prod(w.scales.shape))
    # ~4.5 bits/value vs 16 (+ S augmentation overhead)
    assert packed_bytes < 0.45 * bf16_bytes


def test_calibrator_streaming(rng):
    c = Calibrator()
    for _ in range(3):
        c.observe({"l0": jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))})
    plans = c.make_plans()
    assert "l0" in plans
    assert c.summary()["l0"]["k"] == 32


def test_calibration_robustness(setup):
    """Paper §4.4: outlier structure is stable across calibration sets."""
    cfg, params, _, _ = setup
    orders = []
    for seed in [1, 2]:
        toks = jax.random.randint(jax.random.PRNGKey(seed), (4, 32), 0,
                                  cfg.vocab_size)
        stats = capture_stats(params, cfg, tokens=toks)
        plans = make_plan_bundle(stats, cfg, QuantConfig(method="arc"), params)
        orders.append(np.asarray(plans.arrays["b0.mlp.w_gate"]["order"])[0])
    # top-32 outlier channel sets should overlap substantially (the model
    # is random-init, so structure is weaker than a trained checkpoint)
    overlap = len(set(orders[0][:32]) & set(orders[1][:32]))
    assert overlap >= 8
