"""Sharding rules + dry-run HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import (cache_sharding_rules, logical_to_spec,
                            param_sharding_rules, shardable, use_mesh,
                            maybe_shard)


@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


@pytest.fixture(scope="module")
def mesh4x4():
    # abstract mesh over 16 logical positions is not constructible with 1
    # device; use the rule functions with a mesh-shaped stand-in instead
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}
    return FakeMesh()


def test_tp_dims(mesh4x4):
    m = mesh4x4
    # column-parallel QKV shards the output dim
    assert param_sharding_rules("blocks/0/attn/wq", (2, 64, 128), m) == \
        P(None, "model", None)
    # row-parallel output proj shards the input dim
    assert param_sharding_rules("blocks/0/attn/wo", (2, 128, 64), m) == \
        P(None, None, "model")
    # experts over model (EP)
    spec = param_sharding_rules("blocks/0/moe/experts_gate",
                                (2, 8, 64, 128), m)
    assert spec[1] == "model"


def test_fsdp_added_for_large(mesh4x4):
    big = (2, 4096, 4096)   # 32M f32 > threshold
    spec = param_sharding_rules("blocks/0/mlp/w_gate", big, mesh4x4)
    assert spec == P(None, "model", "data")
    small = (2, 64, 64)
    spec = param_sharding_rules("blocks/0/mlp/w_gate", small, mesh4x4)
    assert spec == P(None, "model", None)


def test_non_divisible_replicated(mesh4x4):
    spec = param_sharding_rules("blocks/0/attn/wq", (2, 63, 127), mesh4x4)
    assert spec == P(None, None, None)


def test_no_duplicate_axes(mesh4x4):
    """Every generated spec must be valid (no axis used twice)."""
    shapes = [("embed", (1024, 512)), ("blocks/0/attn/wq", (4, 512, 512)),
              ("blocks/0/moe/experts_down", (4, 8, 512, 1024)),
              ("blocks/0/mamba/in_proj", (4, 1024, 512))]
    for path, shape in shapes:
        spec = param_sharding_rules(path, shape, mesh4x4)
        axes = [a for a in jax.tree.leaves(tuple(spec)) if a is not None]
        assert len(axes) == len(set(axes)), (path, spec)


def test_cache_rules(mesh4x4):
    # (P, B, L, Hkv, D) — batch over data, heads over model
    spec = cache_sharding_rules("0/k", (2, 8, 128, 8, 64), mesh4x4)
    assert spec == P(None, "data", None, "model", None)
    # batch=1 long-context: sequence-parallel cache
    spec = cache_sharding_rules("0/k", (2, 1, 1024, 8, 64), mesh4x4)
    assert spec == P(None, None, "data", "model", None)


def test_logical_to_spec_divisibility(mesh4x4):
    spec = logical_to_spec(("batch", None, "model"), mesh4x4, (8, 3, 128))
    assert spec == P("data", None, "model")
    spec = logical_to_spec(("batch", None, "model"), mesh4x4, (3, 3, 127))
    assert spec == P(None, None, None)


def test_maybe_shard_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = maybe_shard(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %ar = bf16[16,4096]{1,0} all-reduce(%x), replica_groups=[16,32]<=[512]
  %ag.1 = f32[64,128]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}
  %rs = bf16[8,16]{1,0} reduce-scatter(%z), replica_groups=[32,16]<=[512]
  %cp = (f32[4,4]{1,0}) collective-permute(%w)
  %done = f32[1]{0} all-gather-done(%h)
"""
    out = parse_collectives(hlo)
    assert out["count"] == 4
    # all-reduce: 2*(31/32)*16*4096*2B
    assert out["all-reduce"] == pytest.approx(2 * 31 / 32 * 16 * 4096 * 2)
    # all-gather over group of 4: (3/4) * 64*128*4
    assert out["all-gather"] == pytest.approx(0.75 * 64 * 128 * 4)
    # reduce-scatter: (n-1) * result = 15 * 8*16*2
    assert out["reduce-scatter"] == pytest.approx(15 * 8 * 16 * 2)
    assert out["collective-permute"] == pytest.approx(4 * 4 * 4)


def test_sharded_forward_runs(mesh):
    """End-to-end forward under a real (1-device-per-axis) mesh context."""
    from repro.configs import ARCHS
    from repro.models import forward, init_params
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    with use_mesh(mesh):
        logits, _, _ = forward(params, cfg, tokens=toks)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all())
