"""Checkpoint store: roundtrip, atomicity, retention, resume pointers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.optim import adamw_init


def make_tree(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "c": [jnp.ones((2,)), jnp.zeros((3,))]}}


def test_roundtrip(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    opt = adamw_init(tree)
    save_checkpoint(tmp_path, 7, tree, opt, extra={"stream": {"seed": 0, "step": 3}})
    restored, opt2, meta = load_checkpoint(tmp_path, tree, opt)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta["step"] == 7
    assert meta["extra"]["stream"]["step"] == 3
    assert int(opt2.step) == 0


def test_latest_pointer(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, interval=10, keep=2)
    for step in [10, 20, 30]:
        mgr.save(step, tree)
    assert mgr.latest_step() == 30
    # retention keeps the newest 2
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000020", "step_00000030"]


def test_should_save_cadence(tmp_path):
    mgr = CheckpointManager(tmp_path, interval=5)
    assert not mgr.should_save(0)
    assert mgr.should_save(5)
    assert not mgr.should_save(6)


def test_no_partial_checkpoint_visible(tmp_path):
    """A .tmp directory must never be resolvable as latest."""
    tree = make_tree(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 1, tree)
    (tmp_path / "step_00000099.tmp").mkdir()
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() == 1


def test_restore_specific_step(tmp_path):
    t1 = make_tree(jax.random.PRNGKey(1))
    t2 = make_tree(jax.random.PRNGKey(2))
    save_checkpoint(tmp_path, 1, t1)
    save_checkpoint(tmp_path, 2, t2)
    r1, _, _ = load_checkpoint(tmp_path, t1, step=1)
    np.testing.assert_array_equal(np.asarray(r1["a"]), np.asarray(t1["a"]))
