"""Baseline PTQ adaptations (paper §4.1): SmoothQuant, QuaRot, Atom, W4A8."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL


def test_hadamard_orthogonal():
    for k in [64, 128, 96]:       # 96 = block-diagonal path
        h = BL.hadamard_matrix(k)
        np.testing.assert_allclose(h @ h.T, np.eye(k), atol=1e-5)


def test_rotation_preserves_product(rng):
    """(XH)(WH)^T == XW^T exactly in fp32 (before quantization)."""
    x = rng.normal(size=(8, 64)).astype(np.float32)
    w = rng.normal(size=(16, 64)).astype(np.float32)
    h = BL.hadamard_matrix(64)
    np.testing.assert_allclose((x @ h) @ (w @ h).T, x @ w.T, atol=1e-4)


def test_smooth_plan_scales(rng):
    a = np.abs(rng.normal(size=64)).astype(np.float32) * 10
    w = np.abs(rng.normal(size=64)).astype(np.float32)
    plan = BL.make_smooth_plan(a, w, alpha=0.5)
    assert plan.smooth.shape == (64,)
    assert (plan.smooth > 0).all()
    # migration: activation range shrinks where a >> w
    big = a > 5 * w
    assert (plan.smooth[big] > 1).mean() > 0.5


def test_smooth_exact_without_quant(rng):
    """X/s @ (W*s)^T == XW^T in exact arithmetic."""
    x = rng.normal(size=(8, 64)).astype(np.float64)
    w = rng.normal(size=(16, 64)).astype(np.float64)
    s = np.abs(rng.normal(size=64)) + 0.5
    np.testing.assert_allclose((x / s) @ (w * s).T, x @ w.T, rtol=1e-9)


def test_atom_mixed_precision(rng):
    x = rng.normal(size=(8, 128)).astype(np.float32)
    x[:, :3] *= 40
    w = rng.normal(size=(32, 128)).astype(np.float32)
    plan = BL.make_atom_plan(np.abs(x).max(0), s=32)
    y = np.asarray(BL.atom_matmul(jnp.asarray(x), jnp.asarray(w), plan))
    y_fp = x @ w.T
    y_rtn = np.asarray(BL.rtn_matmul(jnp.asarray(x), jnp.asarray(w)))
    # high-precision outliers should beat uniform RTN
    assert np.mean((y - y_fp) ** 2) < np.mean((y_rtn - y_fp) ** 2)


def test_w4a8_better_than_w4a4(rng):
    x = rng.normal(size=(16, 128)).astype(np.float32) * 3
    w = rng.normal(size=(32, 128)).astype(np.float32)
    y_fp = x @ w.T
    e_w4a8 = np.mean((np.asarray(BL.w4a8_matmul(jnp.asarray(x), jnp.asarray(w))) - y_fp) ** 2)
    e_w4a4 = np.mean((np.asarray(BL.rtn_matmul(jnp.asarray(x), jnp.asarray(w), "nvfp4")) - y_fp) ** 2)
    assert e_w4a8 < e_w4a4


def test_hadamard_spreads_outliers(rng):
    """Paper Fig. 2: rotation raises the dynamic range of quiet blocks."""
    x = rng.normal(size=(64, 128)).astype(np.float32)
    x[:, 5] *= 60
    h = BL.hadamard_matrix(128)
    xh = x @ h
    # block-wise amax of non-outlier blocks grows after rotation
    def quiet_block_amax(z):
        zb = np.abs(z.reshape(64, -1, 16)).max(-1)      # (rows, blocks)
        return np.median(zb)
    assert quiet_block_amax(np.asarray(xh)) > 2 * quiet_block_amax(
        np.delete(x, 5, axis=1)[:, :112])
