"""Block quantizer invariants (paper §3.1 Eq. 1) + QTensor storage."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, hnp, st

from repro.core import formats as F
from repro.core import quant as Q

arrays = hnp.arrays(np.float32, st.tuples(st.integers(1, 8),
                                          st.integers(16, 96)),
                    elements=st.floats(-100, 100, width=32))


@pytest.mark.parametrize("fmt", ["nvfp4", "mxfp4", "mxfp8", "int4"])
def test_dequant_error_bounded_per_block(fmt, rng):
    f = F.get_format(fmt)
    x = rng.normal(size=(16, 128)).astype(np.float32) * 10
    qt = Q.quantize(jnp.asarray(x), fmt)
    err = np.abs(np.asarray(qt.dequantize()) - x)
    # per-block error <= scale * eps-ish; bound loosely by amax/qmax
    xb = x.reshape(16, -1, f.block_size)
    amax = np.abs(xb).max(-1, keepdims=True)
    bound = np.broadcast_to(2.2 * amax * f.epsilon / 1 + 1e-6, xb.shape)
    assert (err.reshape(xb.shape) <= bound).all()


@given(arrays)
def test_idempotent(x):
    qt = Q.quantize_dequantize(jnp.asarray(x), "nvfp4")
    qt2 = Q.quantize_dequantize(qt, "nvfp4")
    np.testing.assert_allclose(np.asarray(qt2), np.asarray(qt),
                               rtol=1e-6, atol=1e-6)


@given(arrays)
def test_elements_within_range(x):
    qt = Q.quantize(jnp.asarray(x), "nvfp4")
    el = np.asarray(qt.elements)
    allowed = np.concatenate([-F.E2M1_VALUES[::-1], F.E2M1_VALUES])
    assert np.isin(el, allowed).all()
    assert np.asarray(qt.scales).min() > 0


def test_zero_block_safe():
    x = jnp.zeros((2, 32))
    qt = Q.quantize(x, "nvfp4")
    np.testing.assert_array_equal(np.asarray(qt.dequantize()), 0.0)


def test_padding_roundtrip(rng):
    x = rng.normal(size=(3, 40)).astype(np.float32)   # 40 % 16 != 0
    qt = Q.quantize(jnp.asarray(x), "nvfp4")
    assert qt.shape == (3, 40)
    assert qt.dequantize().shape == (3, 40)


def test_concat_k(rng):
    a = Q.quantize(jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32)), "nvfp4")
    b = Q.quantize(jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32)), "nvfp4")
    c = Q.concat_k(a, b)
    assert c.shape == (4, 48)
    np.testing.assert_array_equal(
        np.asarray(c.dequantize()),
        np.concatenate([np.asarray(a.dequantize()),
                        np.asarray(b.dequantize())], -1))


@pytest.mark.parametrize("fmt", ["nvfp4", "mxfp4"])
def test_packed_roundtrip_exact(fmt, rng):
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32) * 5)
    qt = Q.quantize(x, fmt)
    pk = qt.to_packed()
    assert pk.elements.dtype == jnp.uint8
    assert pk.elements.shape[-1] == qt.elements.shape[-1] // 2
    assert pk.scales.dtype == jnp.uint8          # true 8-bit scale storage
    np.testing.assert_array_equal(np.asarray(pk.dequantize()),
                                  np.asarray(qt.dequantize()))


def test_bits_per_value():
    assert Q.quantize(jnp.ones((1, 16)), "nvfp4").bits_per_value() == 4.5
    assert Q.quantize(jnp.ones((1, 32)), "mxfp4").bits_per_value() == 4.25


def test_nvfp4_scale_hierarchy(rng):
    """Element -> E4M3 block scale -> FP32 tensor scale (Appendix A)."""
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32) * 100)
    qt = Q.quantize(x, "nvfp4")
    t = np.asarray(qt.tensor_scale)
    ratios = np.asarray(qt.scales) / t
    # every block scale / tensor scale must be an exact E4M3 value
    rounded = np.asarray(F.quantize_e4m3(jnp.asarray(ratios)))
    np.testing.assert_allclose(ratios, rounded, rtol=1e-6)
    assert ratios.max() <= F.E4M3_MAX * (1 + 1e-6)
