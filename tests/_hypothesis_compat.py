"""Property-testing shim: real ``hypothesis`` when installed, else a
deterministic fallback.

The CI box does not ship hypothesis, and the tier-1 command must collect
and run on a clean checkout. Test modules import ``given`` / ``st`` /
``hnp`` from here instead of from ``hypothesis`` directly. When the real
library is available it is re-exported unchanged; otherwise a tiny
deterministic sampler stands in: ``@given`` reruns the test body
``max_examples`` times with values drawn from a per-test seeded
``numpy`` Generator, so failures reproduce exactly across runs.

Only the strategy surface this suite uses is implemented: ``floats``,
``integers``, ``lists``, ``tuples``, ``just``, ``sampled_from`` and
``hypothesis.extra.numpy.arrays``.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _PROFILES = {"default": {"max_examples": 25}}
    _ACTIVE = dict(_PROFILES["default"])

    class settings:  # noqa: N801 - mirrors the hypothesis API
        """API-compatible stub for the subset conftest.py touches."""

        def __init__(self, **kwargs):
            self.kwargs = kwargs

        def __call__(self, fn):           # used as @settings(...) decorator
            fn._compat_settings = self.kwargs
            return fn

        @staticmethod
        def register_profile(name, **kwargs):
            _PROFILES[name] = kwargs

        @staticmethod
        def load_profile(name):
            _ACTIVE.clear()
            _ACTIVE.update({"max_examples": 25})
            _ACTIVE.update({k: v for k, v in _PROFILES[name].items()
                            if k == "max_examples"})

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self.draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self.draw(rng)
                    if pred(v):
                        return v
                raise RuntimeError("filter predicate too strict")
            return _Strategy(draw)

    def _as_strategy(obj):
        return obj if isinstance(obj, _Strategy) else _Strategy(lambda rng: obj)

    class st:  # noqa: N801 - mirrors hypothesis.strategies
        @staticmethod
        def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
                   allow_infinity=False, width=64):
            lo, hi = float(min_value), float(max_value)

            def draw(rng):
                # mix uniform draws with boundary values, as hypothesis does
                r = rng.random()
                if r < 0.05:
                    v = lo
                elif r < 0.10:
                    v = hi
                elif r < 0.15 and lo <= 0.0 <= hi:
                    v = 0.0
                else:
                    v = rng.uniform(lo, hi)
                if width == 32:
                    v = float(np.clip(np.float32(v), np.float32(lo),
                                      np.float32(hi)))
                return v
            return _Strategy(draw)

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            elements = _as_strategy(elements)

            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strats):
            strats = [_as_strategy(s) for s in strats]
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    class _HnpModule:
        @staticmethod
        def arrays(dtype, shape, elements=None):
            shape_s = _as_strategy(shape)
            elements = elements or st.floats(-1e3, 1e3, width=32)

            def draw(rng):
                shp = shape_s.draw(rng)
                if isinstance(shp, (int, np.integer)):
                    shp = (int(shp),)
                flat = [elements.draw(rng) for _ in range(int(np.prod(shp)))]
                return np.asarray(flat, dtype).reshape(shp)
            return _Strategy(draw)

    hnp = _HnpModule()

    def given(*strats, **kwstrats):
        strats = [_as_strategy(s) for s in strats]
        kwstrats = {k: _as_strategy(v) for k, v in kwstrats.items()}

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # per-test deterministic seed so examples differ across tests
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                # canonical ordering puts @settings *above* @given, so the
                # attribute lands on this wrapper; check it first, then the
                # inner fn (@settings below @given), then the active profile
                overrides = getattr(wrapper, "_compat_settings",
                                    getattr(fn, "_compat_settings", _ACTIVE))
                n = overrides.get("max_examples", _ACTIVE["max_examples"])
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strats]
                    kdrawn = {k: v.draw(rng) for k, v in kwstrats.items()}
                    fn(*args, *drawn, **kwargs, **kdrawn)
            # hide the drawn parameters from pytest's fixture resolution:
            # like hypothesis, the wrapper's visible signature keeps only
            # the parameters given() does not supply (self, real fixtures)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if strats:
                params = params[:len(params) - len(strats)]
            params = [p for p in params if p.name not in kwstrats]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper.hypothesis_compat = True
            return wrapper
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "hnp", "settings", "st"]
