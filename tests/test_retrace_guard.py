"""Rule R5 (retrace guard): a full mixed serving run — admissions,
chunked prefill, preemption + resume, a mid-run abort, and every
active-request count — must compile each jitted entry point exactly
once. The negative control flips the legacy ``decode_buckets`` knob,
whose pow2 launch widths are a *declared* multi-bucket shape family:
the guard must flag it at the default allowance and accept it once the
buckets are declared.

Workload constraints that keep the positive run single-trace:
  * every prompt is longer than ``prefill_chunk`` so all prefill work
    (including preemption resumes) goes through the chunked path — the
    final chunk pads to the chunk width, so ``prefill`` always launches
    at one shape (a short prompt would instead take the one-shot
    pow2-bucketed path at a different width);
  * prompt + generation stays well under ``max_len`` so the final-chunk
    pad is never truncated.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.retrace import _fingerprint
from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import init_params
from repro.serving import (GenerationRequest, PagedServingEngine,
                           SamplingParams)
from repro.serving.request import FinishReason

import jax

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=2)
    return cfg, init_params(cfg, KEY), QuantConfig(method="none")


def _req(rng, vocab, plen, new):
    return GenerationRequest(
        prompt=rng.integers(0, vocab, plen).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=new))


def test_fingerprint_keys_on_structure_shape_dtype():
    f = _fingerprint
    a = jnp.zeros((2, 3))
    assert f((a,), {}) == f((jnp.ones((2, 3)),), {})    # values don't key
    assert f((a,), {}) != f((jnp.zeros((3, 2)),), {})   # shape does
    assert f((a,), {}) != f((jnp.zeros((2, 3), jnp.int32),), {})  # dtype does
    assert f((a,), {}) != f(([a],), {})                 # structure does


def test_single_trace_across_mixed_serving_run(tiny, trace_guard):
    cfg, params, quant = tiny
    eng = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                             max_len=48, num_pages=3, block_size=16,
                             prefill_chunk=4)
    core = eng.make_core(trace_guard=trace_guard)
    rng = np.random.default_rng(9)
    rids = [core.add_request(_req(rng, cfg.vocab_size,
                                  plen=int(rng.integers(5, 13)),
                                  new=int(rng.integers(3, 9))))
            for _ in range(4)]
    for _ in range(6):
        core.step()
    # abort one in-flight request between ticks, then admit a latecomer
    # so the run also covers post-abort active-count transitions
    victim = next(r for r in rids if not core.states[r].done)
    assert core.abort_request(victim)
    core.add_request(_req(rng, cfg.vocab_size, plen=7, new=4))
    while core.has_unfinished():
        core.step()
    assert core.states[victim].finish_reason is FinishReason.ABORTED
    # the tiny pool forced preemption + resume re-prefill mid-run
    assert core.stats.preemptions > 0

    counts = trace_guard.trace_counts()
    # every entry point the run exercised saw exactly one signature
    assert counts == {"prefill": 1, "prefill_chunk": 1,
                      "decode_paged": 1, "sample": 1}, counts
    # cross-check against the jit caches where the runtime exposes them
    for name, n in trace_guard.compile_counts().items():
        if n is not None:
            assert n <= 1, (name, n)
    assert not [f for f in trace_guard.findings()
                if f.severity == "error"]


def test_decode_buckets_retrace_flagged_and_declarable(tiny, trace_guard):
    cfg, params, quant = tiny
    eng = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                             max_len=48, decode_buckets=True)
    core = eng.make_core(trace_guard=trace_guard)
    rng = np.random.default_rng(3)
    # gen lengths 2 vs 10: the active count drops from 2 to 1 mid-run,
    # so bucketed decode launches at two pow2 widths
    core.add_request(_req(rng, cfg.vocab_size, plen=6, new=2))
    core.add_request(_req(rng, cfg.vocab_size, plen=6, new=10))
    while core.has_unfinished():
        core.step()
    assert trace_guard.trace_counts()["decode_paged"] >= 2
    errs = [f for f in trace_guard.findings() if f.severity == "error"]
    assert errs and all(f.rule == "R5" and f.entry == "decode_paged"
                        for f in errs)
    # declaring the pow2 buckets clears the finding — the knob is a
    # shape family, not a leak
    assert not [f for f in trace_guard.findings({"decode_paged": 2})
                if f.severity == "error"]
