"""Bit-exact numeric format tests (paper Appendix A, Table 7)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import formats as F

finite_f = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                     width=32)


class TestE2M1:
    def test_value_set(self):
        vals = F.quantize_e2m1(jnp.linspace(-8, 8, 1001))
        allowed = np.concatenate([-F.E2M1_VALUES[::-1], F.E2M1_VALUES])
        assert np.isin(np.asarray(vals), allowed).all()

    @pytest.mark.parametrize("x,want", [
        (0.24, 0.0), (0.26, 0.5), (0.74, 0.5), (0.76, 1.0),
        (1.24, 1.0), (1.26, 1.5), (1.74, 1.5), (1.76, 2.0),
        (2.49, 2.0), (2.51, 3.0), (3.49, 3.0), (3.51, 4.0),
        (4.99, 4.0), (5.01, 6.0), (7.0, 6.0), (-2.4, -2.0),
    ])
    def test_rounding(self, x, want):
        assert float(F.quantize_e2m1(jnp.float32(x))) == want

    @pytest.mark.parametrize("tie,want", [
        # round-half-to-even over the code points
        (0.25, 0.0), (0.75, 1.0), (1.25, 1.0), (1.75, 2.0),
        (2.5, 2.0), (3.5, 4.0), (5.0, 4.0),
    ])
    def test_ties_to_even(self, tie, want):
        assert float(F.quantize_e2m1(jnp.float32(tie))) == want

    @given(st.lists(finite_f, min_size=1, max_size=64))
    def test_codes_roundtrip(self, xs):
        v = F.quantize_e2m1(jnp.asarray(xs, jnp.float32))
        codes = F.encode_e2m1(v)
        back = F.decode_e2m1(codes)
        # -0.0 encodes as sign-magnitude zero; compare by value
        np.testing.assert_array_equal(np.asarray(back) + 0.0,
                                      np.asarray(v) + 0.0)

    @given(st.lists(finite_f, min_size=2, max_size=64))
    def test_pack_unpack(self, xs):
        if len(xs) % 2:
            xs = xs[:-1]
        v = F.quantize_e2m1(jnp.asarray(xs, jnp.float32))
        codes = F.encode_e2m1(v)
        packed = F.pack_e2m1(codes)
        assert packed.size == codes.size // 2
        np.testing.assert_array_equal(np.asarray(F.unpack_e2m1(packed)),
                                      np.asarray(codes))


class TestE4M3:
    def test_max_saturates(self):
        assert float(F.quantize_e4m3(jnp.float32(1e6))) == 448.0
        assert float(F.quantize_e4m3(jnp.float32(-1e6))) == -448.0

    def test_subnormals(self):
        step = 2.0 ** -9
        assert float(F.quantize_e4m3(jnp.float32(step))) == step
        assert float(F.quantize_e4m3(jnp.float32(step * 0.49))) == 0.0

    @given(st.floats(min_value=0.015625, max_value=440.0, width=32))
    def test_relative_error(self, x):
        q = float(F.quantize_e4m3(jnp.float32(x)))
        assert abs(q - x) <= x * 2 ** -4 * (1 + 1e-6)   # eps8 = 2^-4

    @given(st.lists(st.floats(min_value=2 ** -9, max_value=448.0, width=32),
                    min_size=1, max_size=64))
    def test_byte_codes_roundtrip(self, xs):
        v = F.quantize_e4m3(jnp.asarray(xs, jnp.float32))
        codes = F.encode_e4m3(v)
        back = F.decode_e4m3(codes)
        np.testing.assert_allclose(np.asarray(back), np.asarray(v), rtol=0,
                                   atol=0)


class TestE8M0:
    @given(st.integers(min_value=-100, max_value=100),
           st.floats(min_value=1.0, max_value=1.9990234375, width=32))
    def test_power_of_two(self, e, frac):
        x = np.float32(frac) * np.float32(2.0) ** e
        s = float(F.quantize_e8m0(jnp.float32(x)))
        assert s == 2.0 ** np.floor(np.log2(float(x)))

    @given(st.integers(min_value=-100, max_value=100))
    def test_byte_codes(self, e):
        v = jnp.float32(2.0 ** e)
        assert float(F.decode_e8m0(F.encode_e8m0(v))) == float(v)


class TestFormatTable:
    """Paper Table 7 invariants."""

    def test_specs(self):
        from repro.core.formats import INT4, MXFP4, MXFP8, NVFP4
        assert NVFP4.block_size == 16 and NVFP4.scale_kind == "e4m3+tensor"
        assert MXFP4.block_size == 32 and MXFP4.scale_kind == "e8m0"
        assert MXFP8.block_size == 32
        assert NVFP4.element_max == 6.0 and MXFP8.element_max == 448.0
        # eps4^2 == eps8 (the dual-stage bridge, §3.4)
        assert NVFP4.epsilon ** 2 == MXFP8.epsilon
