"""Deployed kernel backend: pallas (fused kernels, interpret) == reference.

The acceptance bar for the kernel-backend layer: routing every deployed
ARC linear through arc_fused_quantize + packed nvfp4_gemm must serve the
same greedy tokens as the emulated reference backend, end to end through
the continuous-batching engine (dense attention config).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # interpret-mode Pallas, end-to-end

from repro.checkpoint import load_serving_checkpoint, save_serving_checkpoint
from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.core import quant as Q
from repro.models import capture_stats, forward, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.quant.apply import reinterleave_qtensor
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["llama31-8b"].reduced(layers=2)     # dense full attention
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


def _serve(backend, setup, interpret):
    cfg, quant, plans, qparams = setup
    rng = np.random.default_rng(7)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, n)
                    .astype(np.int32), max_new_tokens=m)
            for n, m in ((5, 4), (9, 3), (7, 5))]
    eng = ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                        max_len=16, backend=backend, interpret=interpret)
    eng.run(reqs)
    return [r.out_tokens for r in reqs]


def test_engine_greedy_parity_pallas_vs_reference(setup):
    """Identical greedy tokens through the continuous-batching engine."""
    ref = _serve("reference", setup, interpret=False)
    pal = _serve("pallas", setup, interpret=True)
    assert ref == pal


def test_forward_logits_close_across_backends(setup):
    """Batched prefill logits agree to GEMM-accumulation-order tolerance."""
    cfg, quant, plans, qparams = setup
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                              cfg.vocab_size)
    q_ref = dataclasses.replace(quant, act_scale="calibrated")
    q_pal = dataclasses.replace(quant, act_scale="calibrated",
                                backend="pallas", interpret=True)
    lg_r, _, _ = forward(qparams, cfg, tokens=toks, quant=q_ref, plans=plans)
    lg_p, _, _ = forward(qparams, cfg, tokens=toks, quant=q_pal, plans=plans)
    r = np.asarray(lg_r[..., : cfg.vocab_size], np.float32)
    p = np.asarray(lg_p[..., : cfg.vocab_size], np.float32)
    np.testing.assert_allclose(p, r, rtol=2e-2, atol=2e-2)
    # and greedy decisions agree everywhere
    np.testing.assert_array_equal(p.argmax(-1), r.argmax(-1))


def test_pallas_backend_requires_calibrated_scales(setup):
    """No silent fallback: pallas without calibrated scales is an error."""
    cfg, quant, plans, qparams = setup
    q = dataclasses.replace(quant, backend="pallas", interpret=True,
                            act_scale="token")
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    with pytest.raises(ValueError, match="calibrated"):
        forward(qparams, cfg, tokens=toks, quant=q, plans=plans)


# ---------------------------------------------------------------------------
# Legacy (concat-K) checkpoint loader shim
# ---------------------------------------------------------------------------


def _legacy_augment(qt: Q.QTensor, s: int) -> Q.QTensor:
    """Reconstruct the pre-unification concat-K layout from an interleaved
    QTensor by inverting the interleave permutation."""
    from repro.core.arc import interleaved_permutation
    if s == 0:
        return qt
    g = qt.fmt.block_size
    k = qt.valid_k - s
    perm = np.asarray(interleaved_permutation(k, s, g))
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    from repro.core import formats as F
    codes = F.unpack_e2m1(qt.elements) if qt.packed else qt.elements
    codes = jnp.take(codes, jnp.asarray(inv), axis=-1)
    elements = F.pack_e2m1(codes) if qt.packed else codes
    scales = jnp.take(qt.scales, jnp.asarray(inv[::g] // g), axis=-1)
    return Q.QTensor(elements, scales, qt.fmt_name, qt.valid_k,
                     qt.tensor_scale, qt.packed)


def test_legacy_checkpoint_reinterleaved_on_load(setup, tmp_path):
    cfg, quant, plans, qparams = setup
    # build an old-layout params tree (concat-K augmented weights)
    def to_legacy(leaf, name_s):
        fn = lambda t: _legacy_augment(t, name_s)
        for _ in range(leaf.elements.ndim - 2):
            fn = jax.vmap(fn)
        return fn(leaf)

    legacy = {"blocks": []}
    for i, block in enumerate(qparams["blocks"]):
        nb = {}
        for mod, sub in block.items():
            if not isinstance(sub, dict):
                nb[mod] = sub
                continue
            ns = {}
            for leaf_name, leaf in sub.items():
                name = f"b{i}.{mod}.{leaf_name}"
                if isinstance(leaf, Q.QTensor) and plans.meta.get(name, 0):
                    ns[leaf_name] = to_legacy(leaf, plans.meta[name])
                else:
                    ns[leaf_name] = leaf
            nb[mod] = ns
        legacy["blocks"].append(nb)
    for k, v in qparams.items():
        if k != "blocks":
            legacy[k] = v

    # legacy writer: no layout stamp
    from repro.checkpoint import save_checkpoint
    save_checkpoint(tmp_path, 0, legacy)
    restored, meta = load_serving_checkpoint(tmp_path, legacy, plans=plans)

    # the shim must reproduce the canonical interleaved weights bit-exactly
    for i, block in enumerate(qparams["blocks"]):
        for mod, sub in block.items():
            if not isinstance(sub, dict):
                continue
            for leaf_name, leaf in sub.items():
                if isinstance(leaf, Q.QTensor):
                    got = restored["blocks"][i][mod][leaf_name]
                    np.testing.assert_array_equal(np.asarray(got.elements),
                                                  np.asarray(leaf.elements))
                    np.testing.assert_array_equal(np.asarray(got.scales),
                                                  np.asarray(leaf.scales))

    # stamped (new) checkpoints load without conversion
    save_serving_checkpoint(tmp_path, 1, qparams)
    again, meta2 = load_serving_checkpoint(tmp_path, qparams, step=1)
    assert meta2["extra"]["weight_layout"] == "interleaved"
    w0 = again["blocks"][0]["mlp"]["w_gate"]
    np.testing.assert_array_equal(
        np.asarray(w0.elements),
        np.asarray(qparams["blocks"][0]["mlp"]["w_gate"].elements))


def test_reinterleave_qtensor_round_trip(rng):
    """reinterleave(legacy) == canonical for both storage modes."""
    w = jnp.asarray(rng.normal(size=(24, 64)).astype(np.float32))
    order = jnp.asarray(rng.permutation(64).astype(np.int32))
    s = 32
    from repro.quant.apply import _augment_weight
    canonical = _augment_weight(w, order, s, "nvfp4")
    legacy = _legacy_augment(canonical, s)
    back = reinterleave_qtensor(legacy, s)
    np.testing.assert_array_equal(np.asarray(back.elements),
                                  np.asarray(canonical.elements))
    np.testing.assert_array_equal(np.asarray(back.scales),
                                  np.asarray(canonical.scales))
    # packed storage
    canon_p = canonical.to_packed()
    back_p = reinterleave_qtensor(_legacy_augment(canon_p, s), s)
    np.testing.assert_array_equal(np.asarray(back_p.elements),
                                  np.asarray(canon_p.elements))
    np.testing.assert_array_equal(np.asarray(back_p.scales),
                                  np.asarray(canon_p.scales))
