"""Continuous-batching scheduler: lifecycle, parity with the static
baseline, slot reuse / cache isolation, EOS and max-token edge cases,
guarded tick metrics."""
import copy

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import (DECODE, DONE, FREE, PREFILL, FINISH_EOS,
                           FINISH_LENGTH, GenerationRequest, Request,
                           RequestState, SamplingParams, Scheduler,
                           ServingEngine, StaticBatchEngine)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Pure-Python scheduler unit tests (no model)
# ---------------------------------------------------------------------------


def _state(n_prompt=8, max_new=4, rid=0, **kw):
    return RequestState(
        GenerationRequest(prompt=np.arange(n_prompt, dtype=np.int32),
                          sampling=SamplingParams(max_new_tokens=max_new,
                                                  **kw)),
        rid=rid)


class TestSchedulerLifecycle:
    def test_admission_fifo_into_free_slots(self):
        sched = Scheduler(num_slots=2, max_len=64)
        sts = [_state(rid=i) for i in range(3)]
        for st in sts:
            sched.submit(st)
        admitted = sched.admissions()
        assert [st for _, st in admitted] == sts[:2]
        assert [s.state for s, _ in admitted] == [PREFILL, PREFILL]
        assert len(sched.queue) == 1
        # no FREE slot left -> nothing more is admitted
        assert sched.admissions() == []

    def test_slot_cycle_free_prefill_decode_done_free(self):
        sched = Scheduler(num_slots=1, max_len=64)
        sched.submit(_state(n_prompt=5, max_new=2))
        [(slot, st)] = sched.admissions()
        assert not sched.record_token(slot, 7)      # first (prefill) token
        assert slot.state == DECODE
        assert slot.next_pos == 5 and slot.last_token == 7
        assert sched.record_token(slot, 9)          # hits max_new_tokens
        assert slot.state == DONE and st.done
        assert st.finish_reason == FINISH_LENGTH
        assert st.out_tokens == [7, 9]
        sched.free(slot)
        assert slot.state == FREE and slot.req is None

    def test_eos_finishes_early(self):
        sched = Scheduler(num_slots=1, max_len=64)
        sched.submit(_state(max_new=10, eos_token=3))
        [(slot, st)] = sched.admissions()
        assert not sched.record_token(slot, 5)
        assert sched.record_token(slot, 3)          # EOS
        assert slot.req is not None and slot.state == DONE
        assert st.finish_reason == FINISH_EOS

    def test_eos_on_first_token_finishes_at_prefill(self):
        sched = Scheduler(num_slots=1, max_len=64)
        sched.submit(_state(max_new=10, eos_token=3))
        [(slot, st)] = sched.admissions()
        assert sched.record_token(slot, 3)
        assert st.out_tokens == [3]

    def test_oversized_request_rejected(self):
        sched = Scheduler(num_slots=1, max_len=16)
        with pytest.raises(ValueError):
            sched.submit(_state(n_prompt=12, max_new=8))

    def test_freed_slot_admits_queued_request(self):
        sched = Scheduler(num_slots=1, max_len=64)
        a, b = _state(max_new=1, rid=0), _state(max_new=1, rid=1)
        sched.submit(a)
        sched.submit(b)
        [(slot, got)] = sched.admissions()
        assert got is a
        sched.record_token(slot, 1)                 # a finishes at prefill
        sched.free(slot)
        [(slot2, got2)] = sched.admissions()
        assert got2 is b and slot2 is slot
        assert sched.has_work()
        sched.record_token(slot2, 1)
        sched.free(slot2)
        assert not sched.has_work()

    def test_latency_metrics(self):
        sched = Scheduler(num_slots=1, max_len=64)
        a, b = _state(max_new=2, rid=0), _state(max_new=2, rid=1)
        sched.submit(a)
        sched.submit(b)
        [(slot, _)] = sched.admissions()
        sched.record_token(slot, 1)
        sched.step += 1
        sched.record_token(slot, 1)
        sched.free(slot)
        [(slot, _)] = sched.admissions()
        assert a.latency_steps == 1
        assert a.ttft_steps == 0
        assert b.queue_wait_steps == 1


class TestGuardedMetrics:
    """Satellite: metric properties must not return nonsense negatives
    while their underlying event has not happened."""

    def test_request_state_unset_metrics_are_none(self):
        st = _state()
        assert st.queue_wait_steps is None          # never admitted
        assert st.ttft_steps is None                # no token yet
        assert st.latency_steps is None             # unfinished
        st.submit_step = 3
        assert st.queue_wait_steps is None          # still never admitted
        st.admit_step = 5
        assert st.queue_wait_steps == 2
        assert st.latency_steps is None             # admitted != finished
        st.first_token_step = 5
        st.finish_step = 9
        assert st.ttft_steps == 2 and st.latency_steps == 6

    def test_legacy_request_unset_metrics_are_none(self):
        r = Request(prompt=np.arange(4, dtype=np.int32))
        assert r.queue_wait_steps is None
        assert r.ttft_steps is None
        assert r.latency_steps is None

    def test_queued_but_never_admitted(self):
        sched = Scheduler(num_slots=1, max_len=64)
        a, b = _state(rid=0), _state(rid=1)
        sched.submit(a)
        sched.submit(b)
        sched.admissions()                          # only a fits
        sched.step += 4
        assert b.queue_wait_steps is None
        assert b.latency_steps is None

    @pytest.mark.slow
    def test_run_backfills_legacy_metrics(self, served):
        qparams, cfg, quant, plans = served
        eng = ServingEngine(qparams, cfg, quant, plans, batch_size=1,
                            max_len=48)
        rng = np.random.default_rng(5)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 6)
                        .astype(np.int32), max_new_tokens=3)
                for _ in range(2)]
        eng.run(reqs)
        for r in reqs:
            assert r.queue_wait_steps is not None
            assert r.ttft_steps is not None
            assert r.latency_steps is not None
            assert r.latency_steps >= r.ttft_steps >= 0


# ---------------------------------------------------------------------------
# Engine integration: parity, slot reuse, cache isolation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return qparams, cfg, quant, plans


def _engines(served, batch=2, max_len=48):
    qparams, cfg, quant, plans = served
    cont = ServingEngine(qparams, cfg, quant, plans, batch_size=batch,
                         max_len=max_len)
    stat = StaticBatchEngine(qparams, cfg, quant, plans, batch_size=batch,
                             max_len=max_len)
    return cont, stat, cfg


def _mixed_workload(cfg, rng, n=5):
    """Deterministic mixed-length trace: prompts 3..14, new tokens 2..8."""
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 15))
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 9))))
    return reqs


@pytest.mark.slow
def test_continuous_matches_static_greedy_trace(served):
    """Token-for-token parity on a deterministic mixed-length trace."""
    cont, stat, cfg = _engines(served)
    rng = np.random.default_rng(42)
    reqs = _mixed_workload(cfg, rng, n=5)
    out_c = cont.run(copy.deepcopy(reqs))
    out_s = stat.run(copy.deepcopy(reqs))
    for rc, rs in zip(out_c, out_s):
        assert rc.out_tokens == rs.out_tokens
        assert rc.done and rs.done
    # the whole point: continuous batching wastes fewer slot-steps
    assert cont.last_stats.decode_steps <= stat.last_stats.decode_steps
    assert cont.last_stats.padding_waste <= stat.last_stats.padding_waste


@pytest.mark.slow
def test_slot_reuse_more_requests_than_slots(served):
    """6 requests through 2 slots: freed rows admit queued requests."""
    cont, _, cfg = _engines(served)
    rng = np.random.default_rng(7)
    reqs = _mixed_workload(cfg, rng, n=6)
    cont.run(reqs)
    for r in reqs:
        assert r.done and len(r.out_tokens) >= 1
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)
    # with 2 slots and 6 requests, at least one admission reused a slot
    # after another request freed it (admit after step 0)
    assert any(r.admit_step > 0 for r in reqs)


@pytest.mark.slow
def test_cache_does_not_leak_across_requests(served):
    """A request decodes identically alone and after a slot reuse."""
    qparams, cfg, quant, plans = served
    rng = np.random.default_rng(3)
    a = Request(prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=3)
    b = Request(prompt=rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                max_new_tokens=5)
    eng = ServingEngine(qparams, cfg, quant, plans, batch_size=1, max_len=48)
    served_b_after_a = eng.run([copy.deepcopy(a), copy.deepcopy(b)])[1]
    served_b_alone = eng.run([copy.deepcopy(b)])[0]
    assert served_b_after_a.out_tokens == served_b_alone.out_tokens


@pytest.mark.slow
def test_eos_truncates_generation(served):
    cont, _, cfg = _engines(served)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    [ref] = cont.run([Request(prompt=prompt.copy(), max_new_tokens=6)])
    assert len(ref.out_tokens) == 6
    # declare the third greedy token to be EOS and rerun
    eos = ref.out_tokens[2]
    [cut] = cont.run([Request(prompt=prompt.copy(), max_new_tokens=6,
                              eos_token=eos)])
    assert cut.out_tokens == ref.out_tokens[:3]
    assert cut.done and cut.finish_reason == FINISH_EOS


@pytest.mark.slow
def test_single_token_request_finishes_at_prefill(served):
    cont, _, cfg = _engines(served)
    rng = np.random.default_rng(13)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=1)]
    cont.run(reqs)
    assert reqs[0].done and len(reqs[0].out_tokens) == 1
    assert cont.last_stats.decode_steps == 0


@pytest.mark.slow
def test_temperature_sampling_runs_and_varies_by_seed(served):
    qparams, cfg, quant, plans = served
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    def sample(seed):
        eng = ServingEngine(qparams, cfg, quant, plans, batch_size=1,
                            max_len=48, seed=seed)
        [r] = eng.run([Request(prompt=prompt.copy(), max_new_tokens=8,
                               temperature=5.0)])
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)
        return r.out_tokens

    assert sample(0) == sample(0)            # same seed -> same trace
    draws = {tuple(sample(s)) for s in range(4)}
    assert len(draws) > 1                    # high temperature actually samples


@pytest.mark.slow
def test_engine_metrics_consistency(served):
    cont, _, cfg = _engines(served)
    rng = np.random.default_rng(23)
    reqs = _mixed_workload(cfg, rng, n=4)
    cont.run(reqs)
    s = cont.last_stats
    total = sum(len(r.out_tokens) for r in reqs)
    assert s.generated_tokens == total
    assert s.useful_slot_steps <= s.slot_steps
    assert 0.0 <= s.padding_waste < 1.0
    assert s.summary()["generated_tokens"] == total
    for r in reqs:
        assert 0 <= r.admit_step <= r.finish_step
