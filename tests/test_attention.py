"""Flash (chunked online-softmax) attention vs naive reference, fwd + bwd."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import chunked_attention


def naive(q, k, v, qp, kp, window=None):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(D)
    mask = (kp[:, None, :] <= qp[:, :, None]) & (kp[:, None, :] >= 0)
    if window is not None:
        mask &= (qp[:, :, None] - kp[:, None, :]) < window
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


def make(rng, B=2, Sq=33, Skv=33, Hq=4, Hkv=2, D=16):
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)).astype(np.float32))
    qp = jnp.broadcast_to(jnp.arange(Skv - Sq, Skv)[None], (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv)[None], (B, Skv))
    return q, k, v, qp, kp


@pytest.mark.parametrize("chunk", [8, 16, 512])
@pytest.mark.parametrize("window", [None, 7])
def test_forward_matches(chunk, window, rng):
    q, k, v, qp, kp = make(rng)
    o1 = chunked_attention(q, k, v, qp, kp, window=window,
                           q_chunk=chunk, kv_chunk=chunk)
    o2 = naive(q, k, v, qp, kp, window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])
def test_gqa(Hq, Hkv, rng):
    q, k, v, qp, kp = make(rng, Hq=Hq, Hkv=Hkv)
    o1 = chunked_attention(q, k, v, qp, kp, q_chunk=16, kv_chunk=16)
    o2 = naive(q, k, v, qp, kp)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_decode_single_query(rng):
    q, k, v, qp, kp = make(rng, Sq=1, Skv=40)
    o1 = chunked_attention(q, k, v, qp, kp, kv_chunk=16)
    o2 = naive(q, k, v, qp, kp)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_invalid_cache_positions_masked(rng):
    """kv_pos < 0 (unwritten ring-buffer slots) must not contribute."""
    q, k, v, qp, kp = make(rng, Sq=4, Skv=16)
    kp = kp.at[:, 10:].set(-1)
    o1 = chunked_attention(q, k, v, qp, kp, q_chunk=4, kv_chunk=8)
    o2 = naive(q, k[:, :10], v[:, :10], qp, kp[:, :10])
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 9])
def test_gradients_match(window, rng):
    q, k, v, qp, kp = make(rng, Sq=24, Skv=24)

    def f1(q, k, v):
        return jnp.sum(jnp.sin(chunked_attention(
            q, k, v, qp, kp, window=window, q_chunk=8, kv_chunk=8)))

    def f2(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, qp, kp, window)))

    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_grad_under_remat(rng):
    q, k, v, qp, kp = make(rng, Sq=16, Skv=16)

    def f(q, k, v):
        fn = jax.checkpoint(lambda q, k, v: chunked_attention(
            q, k, v, qp, kp, q_chunk=8, kv_chunk=8))
        return jnp.sum(fn(q, k, v) ** 2)

    g1 = jax.grad(f, (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(naive(q, k, v, qp, kp) ** 2),
                  (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
