"""Data pipeline: determinism, checkpointable cursor, corpus variants."""
import numpy as np

from repro.data import SyntheticLM, TokenStream, make_calibration_set


def test_deterministic():
    a = TokenStream(1000, seed=3)
    b = TokenStream(1000, seed=3)
    xa = next(a.batches(2, 16))
    xb = next(b.batches(2, 16))
    np.testing.assert_array_equal(xa, xb)


def test_seed_changes_stream():
    a = next(TokenStream(1000, seed=1).batches(2, 16))
    b = next(TokenStream(1000, seed=2).batches(2, 16))
    assert not np.array_equal(a, b)


def test_cursor_resume():
    s = TokenStream(1000, seed=0)
    it = s.batches(2, 8)
    next(it); next(it)
    state = s.state_dict()
    third = next(it)

    s2 = TokenStream(1000, seed=0)
    s2.load_state(state)
    third2 = next(s2.batches(2, 8))
    np.testing.assert_array_equal(third, third2)


def test_tokens_in_range():
    x = next(TokenStream(512, seed=0).batches(4, 64))
    assert x.min() >= 0 and x.max() < 512


def test_zipfian_marginals():
    """Unigram distribution should be heavy-tailed (Zipf-like)."""
    x = next(TokenStream(256, seed=0).batches(64, 256))
    counts = np.bincount(x.ravel(), minlength=256)
    top = np.sort(counts)[::-1]
    assert top[:8].sum() > 0.2 * counts.sum()


def test_markov_structure_learnable():
    """Bigram predictability materially better than unigram (has signal)."""
    x = next(TokenStream(64, seed=0).batches(64, 256))
    flat = x.reshape(-1)
    uni = np.bincount(flat, minlength=64).astype(np.float64)
    uni /= uni.sum()
    h_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    # conditional entropy H(x_t | x_{t-1})
    big = np.zeros((64, 64))
    for row in x:
        np.add.at(big, (row[:-1], row[1:]), 1)
    p_joint = big / big.sum()
    p_cond = big / np.maximum(big.sum(1, keepdims=True), 1)
    h_cond = -(p_joint[big > 0] * np.log(p_cond[big > 0])).sum()
    assert h_cond < h_uni - 0.05


def test_calibration_sets_differ_by_corpus():
    a = make_calibration_set(512, corpus="wikitext2")
    b = make_calibration_set(512, corpus="c4")
    assert not np.array_equal(a.batches[0], b.batches[0])
    assert a.name != b.name
