"""Prefix-cached paged KV: content-addressed pages, ref counts, COW.

Three layers of coverage:

  * engine-level greedy/sampled parity — prefix caching ON must be
    token-identical to OFF (and to the slot pool) on shared-prefix
    workloads, including chunked prefill, mid-prefill preemption+resume,
    the per-tick prefill token budget, and two sharers diverging past a
    shared page boundary (the COW path);
  * ``PagedCacheManager`` unit tests — match/share/register semantics,
    ref counting, cached-free retention + LRU eviction, copy-on-write
    via ``ensure_writable``;
  * randomized pool-allocation invariants — admit/grow/release/preempt
    sequences (via ``tests/_hypothesis_compat.py``) assert no page is
    leaked, double-freed, or freed while referenced, and that free +
    cached + referenced pages always partition the pool.

The fast tests drive an unquantized (method="none") reduced dense model;
the arc-quantized architecture matrix (dense/MoE/SSM/hybrid — MoE now
shares under the default dropless dispatch, while non-pageable SSM/ring
state must still silently disable sharing while staying correct) runs
under the `slow` marker with the other end-to-end serving suites.
"""
import copy

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import (PagedCacheManager, PagedServingEngine, Request,
                           ServingEngine)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=2)
    params = init_params(cfg, KEY)
    return cfg, params, QuantConfig(method="none")


def _shared_prefix_workload(cfg, n=5, sys_len=32, seed=0, temperature=0.0,
                            max_new=6):
    """n requests sharing a ``sys_len``-token system prompt with unique
    short tails — the workload prefix caching exists for."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(2, 7))).astype(np.int32)
        reqs.append(Request(prompt=np.concatenate([sys_prompt, tail]),
                            max_new_tokens=max_new + (i % 3),
                            temperature=temperature))
    return reqs


def _tokens(engine, reqs):
    served = engine.run(copy.deepcopy(reqs))
    assert all(r.done for r in served)
    return [r.out_tokens for r in served], served


# ---------------------------------------------------------------------------
# Engine-level parity: prefix caching is a pure memory/scheduling change
# ---------------------------------------------------------------------------


def test_prefix_on_matches_off_and_slot_pool(tiny):
    cfg, params, quant = tiny
    reqs = _shared_prefix_workload(cfg)
    slot = ServingEngine(params, cfg, quant, None, batch_size=2, max_len=64)
    off = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                             max_len=64)
    on = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                            max_len=64, prefix_cache=True)
    ref, _ = _tokens(slot, reqs)
    t_off, _ = _tokens(off, reqs)
    t_on, served = _tokens(on, reqs)
    assert t_on == t_off == ref
    s_on, s_off = on.last_stats, off.last_stats
    # every request after the first skips the shared system prompt
    assert s_on.cached_prefix_tokens >= 4 * 32
    assert s_on.prefill_tokens < s_off.prefill_tokens
    assert s_off.cached_prefix_tokens == 0
    assert [r.cached_prefix_tokens > 0 for r in served[1:]] == [True] * 4


def test_fully_cached_prompt_cow_duplicates_tail(tiny):
    """Two identical block-aligned prompts: the second shares every full
    block; the capped tail block is duplicated copy-on-write (read from
    the shared page, written to a private one) so only the final token
    is recomputed — and the shared original is never written."""
    cfg, params, quant = tiny
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    reqs = [Request(prompt=prompt.copy(), max_new_tokens=5)
            for _ in range(2)]
    off = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                             max_len=64)
    on = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                            max_len=64, prefix_cache=True)
    t_off, _ = _tokens(off, reqs)
    t_on, served = _tokens(on, reqs)
    assert t_on == t_off
    assert served[0].cached_prefix_tokens == 0
    assert served[1].cached_prefix_tokens == 31    # capped at len-1


def test_sharers_diverge_past_shared_boundary_sampled(tiny):
    """Identical prompts, temperature>0, distinct request ids: the
    sharers take the COW path, then their sampled continuations diverge
    in private pages — each must match its solo (unshared) trace."""
    cfg, params, quant = tiny
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    reqs = [Request(prompt=prompt.copy(), max_new_tokens=8,
                    temperature=1.4) for _ in range(2)]
    on = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                            max_len=64, seed=7, prefix_cache=True)
    t_on, _ = _tokens(on, reqs)
    # solo references: each request served alone, no sharing possible
    solo = []
    for i, r in enumerate(reqs):
        eng = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                                 max_len=64, seed=7)
        core = eng.make_core()
        core.add_request(copy.deepcopy(r).to_generation_request(request_id=i))
        while core.has_unfinished():
            core.step()
        solo.append(core.states[i].out_tokens)
    assert t_on == solo
    assert t_on[0] != t_on[1]       # genuinely diverged after the fork


def test_chunked_prefill_and_preemption_with_prefix(tiny):
    """Chunked prefill resumes from the shared-prefix boundary; a pool
    too small to hold everyone preempts mid-flight and the resume
    re-shares its own registered pages. Tokens must be unchanged."""
    cfg, params, quant = tiny
    reqs = _shared_prefix_workload(cfg, n=6, seed=5)
    ref, _ = _tokens(ServingEngine(params, cfg, quant, None, batch_size=2,
                                   max_len=64), reqs)
    eng = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                             max_len=64, num_pages=8, block_size=8,
                             prefix_cache=True, prefill_chunk=8)
    out, _ = _tokens(eng, reqs)
    assert out == ref
    assert eng.last_stats.preemptions > 0
    assert eng.last_stats.cached_prefix_tokens > 0


def test_prefix_cache_admits_more_from_same_pool(tiny):
    """The concurrency claim: with the system prompt's pages shared, a
    pool that could only hold ~2 unshared requests serves the same
    workload with fewer preemptions and less prefill compute."""
    cfg, params, quant = tiny
    reqs = _shared_prefix_workload(cfg, n=6, seed=6)
    pool_pages = 2 * (64 // 16) + 1     # two slots' worth of pages
    kw = dict(batch_size=4, max_len=64, num_pages=pool_pages, block_size=16)
    off = PagedServingEngine(params, cfg, quant, None, **kw)
    on = PagedServingEngine(params, cfg, quant, None, prefix_cache=True,
                            **kw)
    t_off, _ = _tokens(off, reqs)
    t_on, _ = _tokens(on, reqs)
    assert t_on == t_off
    assert on.last_stats.prefill_tokens < off.last_stats.prefill_tokens
    assert on.last_stats.decode_steps <= off.last_stats.decode_steps


# ---------------------------------------------------------------------------
# Per-tick prefill token budget (satellite: vLLM-style shared bound)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("which", ["slot", "paged"])
def test_prefill_budget_bounds_tick_across_admissions(which, tiny):
    """N simultaneous long admissions may not stack N chunks into one
    tick: the shared budget caps the tick's total prefill tokens, with
    greedy tokens unchanged."""
    cfg, params, quant = tiny
    rng = np.random.default_rng(7)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 24)
                    .astype(np.int32), max_new_tokens=4) for _ in range(3)]
    cls = ServingEngine if which == "slot" else PagedServingEngine
    ref_eng = cls(params, cfg, quant, None, batch_size=3, max_len=48)
    ref, _ = _tokens(ref_eng, reqs)
    # per-slot chunking alone: 3 admissions x 8-token chunks in one tick
    chunked = cls(params, cfg, quant, None, batch_size=3, max_len=48,
                  prefill_chunk=8)
    t_c, _ = _tokens(chunked, reqs)
    budgeted = cls(params, cfg, quant, None, batch_size=3, max_len=48,
                   prefill_chunk=8, prefill_budget=8)
    t_b, _ = _tokens(budgeted, reqs)
    assert t_c == ref and t_b == ref
    assert chunked.last_stats.max_prefill_tokens_per_step == 3 * 8
    assert budgeted.last_stats.max_prefill_tokens_per_step <= 8


def test_prefill_budget_without_chunk(tiny):
    """A budget alone (no per-slot chunk) slices prefill by whatever
    budget remains in the tick."""
    cfg, params, quant = tiny
    rng = np.random.default_rng(8)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 20)
                    .astype(np.int32), max_new_tokens=3) for _ in range(2)]
    ref, _ = _tokens(ServingEngine(params, cfg, quant, None, batch_size=2,
                                   max_len=48), reqs)
    eng = ServingEngine(params, cfg, quant, None, batch_size=2, max_len=48,
                        prefill_budget=6)
    out, _ = _tokens(eng, reqs)
    assert out == ref
    assert eng.last_stats.max_prefill_tokens_per_step <= 6


# ---------------------------------------------------------------------------
# PagedCacheManager unit tests (no model forward)
# ---------------------------------------------------------------------------


def _manager(num_pages=8, slots=2, block_size=8, max_len=32,
             prefix_cache=True):
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=1)
    return PagedCacheManager(cfg, slots, max_len, num_pages=num_pages,
                             block_size=block_size,
                             prefix_cache=prefix_cache)


def _admit(m, slot, seq):
    """Manager-level admission: share the cached prefix, then claim the
    remaining blocks and register the full ones (what the backend does
    around the prefill)."""
    cached = m.share_prefix(slot, seq)
    if cached:
        m.gather_prefix(slot, m.fresh_prefill_cache())
    for b in range(cached // m.block_size, m.blocks_for(len(seq))):
        assert m.ensure_writable(slot, b)
    m.register_prefix(slot, seq)
    return cached


class TestPrefixManager:
    def test_match_requires_registration(self):
        m = _manager()
        seq = np.arange(20, dtype=np.int32)
        assert m.match_prefix(seq) == 0
        _admit(m, 0, seq)
        # a second sequence sharing the first two full blocks
        seq2 = np.concatenate([seq[:16], np.arange(40, 50, dtype=np.int32)])
        assert m.match_prefix(seq2) == 16
        m.check_invariants()

    def test_match_capped_below_full_sequence(self):
        m = _manager()
        seq = np.arange(16, dtype=np.int32)
        _admit(m, 0, seq)
        assert m.match_prefix(seq) == 15    # must recompute the last token

    def test_share_increfs_release_decrefs(self):
        m = _manager()
        seq = np.arange(24, dtype=np.int32)
        _admit(m, 0, seq)
        page = int(m.tables[0, 0])
        assert m.ref[page] == 1
        cached = _admit(m, 1, np.concatenate(
            [seq[:16], np.arange(60, 66, dtype=np.int32)]))
        assert cached == 16
        assert int(m.tables[1, 0]) == page and m.ref[page] == 2
        m.release(0)
        assert m.ref[page] == 1             # slot 1 still reads it
        m.release(1)
        assert m.ref[page] == 0
        # registered pages stay resident (cached-free), not on the free list
        assert page not in m._free and page in m._cached
        m.check_invariants()

    def test_cached_free_pages_rematch_then_evict(self):
        m = _manager(num_pages=4, slots=1, block_size=8, max_len=16)
        seq = np.arange(16, dtype=np.int32)
        _admit(m, 0, seq)
        m.release(0)
        assert m.cached_page_count > 0
        # the same content re-shares the resident pages
        assert m.match_prefix(seq) == 15
        # exhausting the pool evicts cached-free pages for reuse
        other = np.arange(100, 116, dtype=np.int32)
        _admit(m, 0, other)
        m.check_invariants()
        assert m.match_prefix(seq) < 15     # at least one page evicted

    def test_ensure_writable_cows_shared_page(self):
        m = _manager()
        seq = np.arange(16, dtype=np.int32)
        _admit(m, 0, seq)
        page = int(m.tables[0, 0])
        # slot 1 shares the full block outright (simulating a forked table)
        m._retain(page)
        m.tables[1, 0] = page
        assert m.ref[page] == 2
        assert m.ensure_writable(1, 0)
        fresh = int(m.tables[1, 0])
        assert fresh != page
        assert m.ref[page] == 1 and m.ref[fresh] == 1
        assert int(m.tables[0, 0]) == page  # the original is untouched
        m.check_invariants()

    def test_prefix_disabled_keeps_plain_pool_behavior(self):
        m = _manager(prefix_cache=False)
        seq = np.arange(24, dtype=np.int32)
        assert _admit(m, 0, seq) == 0
        m.release(0)
        assert m.cached_page_count == 0     # nothing retained
        assert m.pages_in_use == 0
        m.check_invariants()

    def test_admission_charge_counts_cached_free_retention(self):
        """A cache hit on cached-free pages pins them, shrinking the
        evictable supply: the admission charge must count those pages or
        a same-tick gate could over-admit against them."""
        m = _manager(num_pages=6, slots=2, block_size=8, max_len=32)
        seq = np.arange(24, dtype=np.int32)
        _admit(m, 0, seq)
        m.release(0)                        # 3 full blocks cached-free
        cached, charge = m.admission_charge(seq)
        assert cached == 23                 # all 3 blocks hit, capped len-1
        # 2 fresh pages (COW tail block + first decode block) + 3
        # retained cached-free pages
        assert charge == 2 + 3
        # once re-admitted, blocks 0-1 are actively shared (ref > 0) —
        # free to retain; the COW tail block is rewritten privately but
        # registration dedups it back onto the original registered page
        # (now actively referenced), so only the 2 fresh pages remain
        _admit(m, 0, seq)
        cached, charge = m.admission_charge(seq)
        assert cached == 23 and charge == 2
        m.check_invariants()

    def test_register_dedups_identical_private_page(self):
        """Concurrent admissions of the same uncached prefix build
        private copies; registration must repoint the duplicate at the
        registered page and free the copy (not first-writer-wins)."""
        m = _manager()
        seq = np.arange(16, dtype=np.int32)
        _admit(m, 0, seq)
        page = int(m.tables[0, 0])
        assert m.ensure_writable(1, 0)
        dup = int(m.tables[1, 0])
        assert dup != page
        m.register_prefix(1, seq)
        # registry still names the original page...
        assert m._hash_to_page[m._page_hash[page]] == page
        # ...and slot 1 now shares it; the private duplicate was freed
        assert int(m.tables[1, 0]) == page
        assert m.ref[page] == 2
        assert dup in m._free and dup not in m._page_hash
        m.check_invariants()

    def test_register_keeps_shared_duplicate_private(self):
        """Dedup only fires on private (ref == 1) duplicates: a page
        other tables still name must not be repointed from under them."""
        m = _manager(slots=3)
        seq = np.arange(16, dtype=np.int32)
        _admit(m, 0, seq)
        page = int(m.tables[0, 0])
        assert m.ensure_writable(1, 0)
        dup = int(m.tables[1, 0])
        m._retain(dup)                      # simulate a second reader
        m.tables[2, 0] = dup
        m.register_prefix(1, seq)
        assert int(m.tables[1, 0]) == dup   # left alone
        assert m.ref[page] == 1 and m.ref[dup] == 2
        m.check_invariants()

    def test_hot_prefix_survives_oneoff_burst(self):
        """Hit-weighted eviction: a reused prefix (system prompt) must
        outlive a burst of one-off prompts that pure LRU would let
        flush it, because eviction targets the least-hit pages first."""
        m = _manager(num_pages=8, slots=1, block_size=8, max_len=32)
        hot = np.arange(16, dtype=np.int32)
        _admit(m, 0, hot)
        m.release(0)
        _admit(m, 0, hot)                   # reuse: bumps the hit counts
        m.release(0)
        one_a = np.arange(100, 116, dtype=np.int32)
        one_b = np.arange(300, 316, dtype=np.int32)
        _admit(m, 0, one_a)
        m.release(0)
        _admit(m, 0, one_b)
        m.release(0)
        # 6 cached-free pages + 1 free; hot pages are the LRU-oldest, so
        # pure LRU would evict them first. 3 blocks force 2 evictions.
        assert m.cached_page_count == 6 and m.free_page_count == 7
        _admit(m, 0, np.arange(200, 224, dtype=np.int32))
        m.check_invariants()
        assert m.match_prefix(hot) == 15    # hot prefix still resident
        assert m.match_prefix(one_a) == 0   # zero-hit burst page evicted
        m.release(0)
        m.check_invariants()


# ---------------------------------------------------------------------------
# Randomized pool-allocation invariants (satellite: no leak / double free)
# ---------------------------------------------------------------------------

_OPS = st.lists(
    st.tuples(st.sampled_from(["admit", "grow", "release", "preempt"]),
              st.integers(0, 2),            # slot
              st.integers(1, 30),           # sequence length
              st.integers(0, 5)),           # content seed (small alphabet
    min_size=1, max_size=40)                # -> frequent prefix collisions)


@settings(max_examples=15)
@given(_OPS)
def test_randomized_allocation_invariants(ops):
    """Random admit/grow/release/preempt sequences conserve the pool:
    free + cached + referenced pages always partition ``usable_pages``,
    ref counts equal table occurrences, and nothing double-frees. The
    small content alphabet makes prefix hits, COW, cached-free retention
    and eviction all fire along the way."""
    m = _manager(num_pages=7, slots=3, block_size=8, max_len=32)
    occupied = {}                           # slot -> tokens resident
    for op, slot, length, salt in ops:
        if op == "admit" and slot not in occupied:
            seq = (np.full((length,), salt, np.int32)
                   + np.arange(length, dtype=np.int32) // 8)
            cached = m.share_prefix(slot, seq)
            if cached:
                m.gather_prefix(slot, m.fresh_prefill_cache())
            ok = True
            for b in range(cached // m.block_size, m.blocks_for(len(seq))):
                if not m.ensure_writable(slot, b):
                    ok = False
                    break
            if ok:
                m.register_prefix(slot, seq)
                occupied[slot] = len(seq)
            else:                           # admission failed: roll back
                m.release(slot)
        elif op == "grow" and slot in occupied:
            tokens = occupied[slot]
            if tokens < m.padded_len:
                if m.ensure_writable(slot, tokens // m.block_size):
                    occupied[slot] = tokens + 1
        elif op in ("release", "preempt") and slot in occupied:
            m.release(slot)                 # preempt reclaims identically
            del occupied[slot]
        m.check_invariants()
    for slot in list(occupied):
        m.release(slot)
    m.check_invariants()
    assert m.pages_in_use == 0
    assert len(m._free) + m.cached_page_count == m.usable_pages


# ---------------------------------------------------------------------------
# Arc-quantized architecture matrix (slow): the acceptance criterion
# ---------------------------------------------------------------------------

# dense attention shares, and so does MoE now that dropless dispatch
# (cap = S*K, the default) makes prefill numerics batch-shape
# independent — capacity-capped dispatch (moe_dropless=False) still
# silently disables sharing; SSM and hybrid must disable too
# (slot-resident recurrent/ring state cannot be skipped)
PARITY_ARCHS = ["qwen2-1.5b", "qwen3-moe-235b-a22b", "rwkv6-3b",
                "jamba-v0.1-52b"]
SHARING_ARCHS = {"qwen2-1.5b", "qwen3-moe-235b-a22b"}


def _build(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


@pytest.mark.slow
@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefix_cache_parity_quantized_matrix(arch):
    """Greedy tokens with prefix caching ON equal OFF on the quantized
    dense/MoE/SSM/hybrid matrix, with chunked prefill in the loop; the
    pageable configs must actually share, the rest must not."""
    cfg, quant, plans, qparams = _build(arch)
    reqs = _shared_prefix_workload(cfg, n=4, sys_len=18, seed=21,
                                   max_new=4)
    kw = dict(batch_size=2, max_len=48, prefill_chunk=8, block_size=16)
    off = PagedServingEngine(qparams, cfg, quant, plans, **kw)
    on = PagedServingEngine(qparams, cfg, quant, plans, prefix_cache=True,
                            **kw)
    t_off, _ = _tokens(off, reqs)
    t_on, _ = _tokens(on, reqs)
    assert t_on == t_off, arch
    if arch in SHARING_ARCHS:
        # the first wave (one admission per slot) is cold — nothing is
        # registered until the first install — so the 2 requests behind
        # it hit the shared 16-token block
        assert on.last_stats.cached_prefix_tokens >= 2 * 16
    else:
        assert on.last_stats.cached_prefix_tokens == 0


@pytest.mark.slow
def test_prefix_cache_preemption_resume_quantized():
    """Mid-flight preemption + resume with prefix caching on the
    quantized dense path: the COW/cached-free machinery must preserve
    greedy tokens while the pool thrashes."""
    cfg, quant, plans, qparams = _build("qwen2-1.5b")
    reqs = _shared_prefix_workload(cfg, n=5, sys_len=18, seed=22,
                                   max_new=4)
    ref, _ = _tokens(ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                                   max_len=48), reqs)
    eng = PagedServingEngine(qparams, cfg, quant, plans, batch_size=2,
                             max_len=48, num_pages=6, block_size=8,
                             prefix_cache=True, prefill_chunk=8)
    out, _ = _tokens(eng, reqs)
    assert out == ref
    assert eng.last_stats.preemptions > 0
