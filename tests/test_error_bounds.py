"""Paper §3.4: dual-stage NVFP4 worst-case error vs single-stage MXFP8."""
import numpy as np
import pytest
from _hypothesis_compat import given, hnp, st

from repro.core import error_bounds as EB


def test_alignment_factors():
    # sup alpha1*alpha2 = 1.125^2 ~= 1.266 < 2 = sup alpha_mx
    assert EB.ALPHA_NV_SUP ** 2 == pytest.approx(1.265625)
    assert EB.bound_ratio() < 1.0


def test_epsilon_bridge():
    # eps4^2 == eps8 — the dual stage matches 8-bit resolution
    assert EB.EPS4 ** 2 == EB.EPS8


def test_bounds_formulae():
    m = 10.0
    assert EB.mxfp8_bound(m) == pytest.approx(2 * 10 * 2 ** -4)
    assert EB.arc_bound(m) == pytest.approx(1.265625 * 10 * 2 ** -4)
    assert EB.arc_bound(m) < EB.mxfp8_bound(m)


@given(hnp.arrays(np.float32, st.integers(32, 256),
                  elements=st.floats(-50, 50, width=32)))
def test_empirical_within_bounds(x):
    if np.abs(x).max() < 1e-3:
        return
    r = EB.empirical_worst_case(x)
    assert r.arc_within_bound
    assert r.mx_within_bound


def test_dual_stage_improves_on_single(rng):
    """Dual-stage NVFP4 should land well below single-stage NVFP4 error."""
    import jax.numpy as jnp
    from repro.core import quant as Q
    x = rng.normal(size=(1, 4096)).astype(np.float32) * 8
    q1 = np.asarray(Q.quantize_dequantize(jnp.asarray(x), "nvfp4"))
    r = x - q1
    q2 = np.asarray(Q.quantize_dequantize(jnp.asarray(r), "nvfp4"))
    e_single = np.abs(q1 - x).max()
    e_dual = np.abs(q1 + q2 - x).max()
    assert e_dual < e_single * 0.5


def test_dual_stage_comparable_to_mxfp8(rng):
    """Empirically: dual-stage NVFP4 ~ MXFP8 fidelity (the paper's claim)."""
    r = EB.empirical_worst_case(rng.normal(size=8192).astype(np.float32) * 5)
    # within the theoretical ratio of bounds (1.266/2), allow 2x slack
    assert r.max_err_arc <= 2 * r.max_err_mxfp8
