"""Serving engine: batched quantized decode produces coherent tokens."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # end-to-end serving runs

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def engine():
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                         max_len=48), cfg


def test_serves_batch(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4) for _ in range(4)]
    eng.run(reqs)
    for r in reqs:
        assert r.done and len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_respects_max_new_tokens(engine):
    eng, cfg = engine
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=n) for n in (2, 5)]
    eng.run(reqs)
    assert len(reqs[0].out_tokens) == 2
    assert len(reqs[1].out_tokens) == 5


def test_deterministic(engine):
    eng, cfg = engine
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    r1 = [Request(prompt=p.copy(), max_new_tokens=4),
          Request(prompt=p.copy(), max_new_tokens=4)]
    eng.run(r1)
    assert r1[0].out_tokens == r1[1].out_tokens
