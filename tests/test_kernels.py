"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arc, quant as Q
from repro.kernels import (arc_fused_quantize, nvfp4_gemm, nvfp4_quantize,
                           ops, ref)

# interpret-mode Pallas is bit-faithful but slow on CPU; CI runs these in
# the dedicated `slow` job
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("m,k", [(16, 64), (32, 256), (8, 48), (64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_matches_ref(m, k, dtype, rng):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 4, dtype)
    c1, s1, t1 = nvfp4_quantize(x, interpret=True, block_m=16, block_k=64)
    c2, s2, t2 = ref.ref_nvfp4_quantize(x.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


@pytest.mark.parametrize("m,n,k", [(16, 16, 64), (32, 64, 256), (8, 24, 48)])
def test_gemm_kernel_matches_ref(m, n, k, rng):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 3)
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    xc, xs, _ = ref.ref_nvfp4_quantize(x)
    wc, ws, _ = ref.ref_nvfp4_quantize(w)
    y1 = nvfp4_gemm(xc, xs, wc, ws, interpret=True,
                    block_m=8, block_n=8, block_k=64)
    y2 = ref.ref_nvfp4_gemm(xc, xs, wc, ws)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("s", [0, 16, 48])
@pytest.mark.parametrize("m,k", [(16, 64), (32, 128)])
def test_fused_kernel_matches_ref(s, m, k, rng):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 2)
    gamma = jnp.asarray(1 + 0.1 * rng.normal(size=(k,)).astype(np.float32))
    order = jnp.asarray(rng.permutation(k).astype(np.int32))
    ts = jnp.asarray([0.02, 0.002], jnp.float32)
    c1, s1 = arc_fused_quantize(x, gamma, order, ts, s, interpret=True,
                                block_m=8)
    c2, s2 = ref.ref_arc_fused(x, gamma, order, ts, s)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_arc_linear_end_to_end_vs_core(rng):
    """Kernel pipeline ~ core simulated path (same math, fused layout).

    The kernel uses calibrated (static) per-tensor scales while the core
    path computes them dynamically, so comparison is against a core run
    given the same tensor scales.
    """
    m, k, n, s = 32, 128, 64, 32
    x = rng.normal(size=(m, k)).astype(np.float32)
    x[:, :4] *= 25
    w = rng.normal(size=(n, k)).astype(np.float32)
    gamma = np.ones(k, np.float32)
    order = np.argsort(-np.abs(x).max(0)).astype(np.int32)

    # normalized activations (what both paths quantize)
    var = (x ** 2).mean(-1, keepdims=True)
    xn = x / np.sqrt(var + 1e-6)
    ts = jnp.asarray([float(np.abs(xn).max()) / (6 * 448),
                      float(np.abs(xn).max()) / (6 * 448) / 16], jnp.float32)

    wc, ws = ops.quantize_weight_interleaved(jnp.asarray(w),
                                             jnp.asarray(order), s,
                                             interpret=True)
    y_kernel = ops.arc_linear(jnp.asarray(x), jnp.asarray(gamma),
                              jnp.asarray(order), wc, ws, ts, s,
                              interpret=True)
    y_fp = xn @ w.T
    rel = np.abs(np.asarray(y_kernel) - y_fp).max() / np.abs(y_fp).max()
    assert rel < 0.2     # W4A4 quantization error regime, not garbage

    # and the kernel beats plain-RTN kernels on the same data
    wc_r, ws_r, _ = nvfp4_quantize(jnp.asarray(w), interpret=True)
    y_rtn = ops.rtn_linear(jnp.asarray(xn), wc_r, ws_r, interpret=True)
    err_arc = np.mean((np.asarray(y_kernel) - y_fp) ** 2)
    err_rtn = np.mean((np.asarray(y_rtn) - y_fp) ** 2)
    assert err_arc < err_rtn


def test_kernel_vs_core_quantizer_agreement(rng):
    """Kernel E2M1/E4M3 arithmetic == core.formats bit-exact emulation."""
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32) * 6)
    codes, scales, t = nvfp4_quantize(x, interpret=True)
    qt = Q.quantize(x, "nvfp4")
    from repro.kernels import common as C
    deq_kernel = (C.decode_e2m1(codes).reshape(8, 4, 16)
                  * scales[..., None]).reshape(8, 64)
    np.testing.assert_allclose(np.asarray(deq_kernel),
                               np.asarray(qt.dequantize()), rtol=1e-6,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# Satellite: broader interpret-mode regression coverage (CPU-only CI)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_m,block_k", [(8, 16), (64, 32), (256, 2048),
                                             (10, 48)])
def test_quant_kernel_tile_sweep(block_m, block_k, rng):
    """Tiling (including the divisor-shrink fallback) never changes codes."""
    x = jnp.asarray(rng.normal(size=(24, 96)).astype(np.float32) * 5)
    c1, s1, t1 = nvfp4_quantize(x, interpret=True, block_m=block_m,
                                block_k=block_k)
    c2, s2, t2 = ref.ref_nvfp4_quantize(x)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(float(t1), float(t2), rtol=1e-6)


def test_quant_kernel_calibrated_tensor_amax(rng):
    """A fixed (calibrated) tensor amax reproduces the oracle bit-exactly."""
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    amax = jnp.float32(12.5)
    c1, s1, _ = nvfp4_quantize(x, tensor_amax=amax, interpret=True)
    c2, s2, _ = ref.ref_nvfp4_quantize(x, tensor_amax=amax)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_gemm_kernel_multi_ktile_accumulation(rng):
    """K split over several grid steps accumulates like the one-shot ref."""
    m, n, k = 16, 16, 512
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    xc, xs, _ = ref.ref_nvfp4_quantize(x)
    wc, ws, _ = ref.ref_nvfp4_quantize(w)
    y_ref = ref.ref_nvfp4_gemm(xc, xs, wc, ws)
    for bk in (32, 128, 512):
        y = nvfp4_gemm(xc, xs, wc, ws, interpret=True, block_m=16,
                       block_n=16, block_k=bk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-4)


def test_gemm_kernel_augmented_interleaved_operands(rng):
    """The unified GEMM consumes ARC-augmented interleaved tensors with no
    special casing — kernel output matches the oracle on the same codes."""
    m, n, k, s = 8, 16, 64, 32
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 2)
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    gamma = jnp.ones((k,), jnp.float32)
    order = jnp.asarray(rng.permutation(k).astype(np.int32))
    ts = jnp.asarray([0.01, 0.001], jnp.float32)
    xc, xs = arc_fused_quantize(x, gamma, order, ts, s, interpret=True)
    wc, ws = ops.quantize_weight_interleaved(w, order, s, interpret=True)
    y = nvfp4_gemm(xc, xs, wc, ws, interpret=True, block_m=8, block_n=8,
                   block_k=32)
    y_ref = ref.ref_nvfp4_gemm(xc, xs, wc, ws)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Packed-weight GEMM, decode fast path, ragged-M padding
# ---------------------------------------------------------------------------


from repro.core import quant as Q
from repro.kernels.nvfp4_gemm import gemm_plan


@pytest.mark.parametrize("m", [1, 3, 5, 17])
def test_gemm_odd_m_padded_not_degenerate(m, rng):
    """Ragged M (odd active decode slots) pads to the tile instead of
    spinning the old block-shrink loop; results match the oracle."""
    n, k = 24, 64
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 2)
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    xc, xs, _ = ref.ref_nvfp4_quantize(x)
    wc, ws, _ = ref.ref_nvfp4_quantize(w)
    y = nvfp4_gemm(xc, xs, wc, ws, interpret=True, block_m=8, block_n=8)
    y_ref = ref.ref_nvfp4_gemm(xc, xs, wc, ws)
    assert y.shape == (m, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-4)


def test_gemm_packed_weights_match_unpacked(rng):
    """In-kernel byte-pair unpack + E4M3 scale decode == unpacked operands."""
    m, n, k = 8, 16, 128
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 3)
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    xc, xs, _ = ref.ref_nvfp4_quantize(x)
    wq = Q.quantize(w, "nvfp4")
    wp = wq.to_packed()
    wc_u, ws_u, wt_u, pk_u = ops.qtensor_gemm_operands(wq)
    wc_p, ws_p, wt_p, pk_p = ops.qtensor_gemm_operands(wp)
    assert not pk_u and pk_p
    y_u = nvfp4_gemm(xc, xs, wc_u, ws_u, interpret=True, block_k=64)
    y_p = nvfp4_gemm(xc, xs, wc_p, ws_p, w_tensor_scale=wt_p, w_packed=True,
                     interpret=True, block_k=64)
    np.testing.assert_array_equal(np.asarray(y_u), np.asarray(y_p))


def test_gemm_plan_decode_fast_path_decode_counts():
    """The decode schedule decodes each weight tile once; the generic
    schedule re-decodes per i tile."""
    p = gemm_plan(4, 256, 512)                      # decode shape
    assert p["path"] == "decode_fast"
    assert p["weight_tile_decodes"] == (256 // p["bn"]) * (512 // p["bk"])
    g = gemm_plan(512, 256, 512, block_m=128)       # prefill shape
    assert g["path"] == "generic"
    assert g["weight_tile_decodes"] == 4 * p["weight_tile_decodes"]


def test_gemm_decode_fast_path_matches_generic(rng):
    """Same operands through both schedules -> same result."""
    n, k = 16, 128
    m = 16
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    xc, xs, _ = ref.ref_nvfp4_quantize(x)
    wc, ws, _ = ref.ref_nvfp4_quantize(w)
    assert gemm_plan(m, n, k)["path"] == "decode_fast"
    assert gemm_plan(m, n, k, block_m=8)["path"] == "generic"
    y_fast = nvfp4_gemm(xc, xs, wc, ws, interpret=True)
    y_gen = nvfp4_gemm(xc, xs, wc, ws, interpret=True, block_m=8)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_gen),
                               rtol=1e-6, atol=1e-6)


def test_fused_kernel_apply_norm_false(rng):
    """apply_norm=False consumes pre-normalized input (wo/w_down path)."""
    m, k, s = 16, 64, 16
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 2)
    order = jnp.asarray(rng.permutation(k).astype(np.int32))
    ts = jnp.asarray([0.02, 0.002], jnp.float32)
    gamma = jnp.ones((k,), jnp.float32)
    c1, s1 = arc_fused_quantize(x, gamma, order, ts, s, apply_norm=False,
                                interpret=True)
    c2, s2 = ref.ref_arc_fused(x, gamma, order, ts, s, apply_norm=False)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


@pytest.mark.parametrize("m", [3, 10])
def test_fused_kernel_ragged_m(m, rng):
    """Ragged row counts (odd active slot sets) pad and slice correctly."""
    k, s = 64, 16
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    gamma = jnp.asarray(1 + 0.1 * rng.normal(size=(k,)).astype(np.float32))
    order = jnp.asarray(rng.permutation(k).astype(np.int32))
    ts = jnp.asarray([0.02, 0.002], jnp.float32)
    c1, s1 = arc_fused_quantize(x, gamma, order, ts, s, interpret=True)
    c2, s2 = ref.ref_arc_fused(x, gamma, order, ts, s)
    assert c1.shape == (m, k + s)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_packed_interleaved_round_trip_property(rng):
    """Property sweep: offline QTensor weights (canonical interleaved,
    packed) -> kernel consumption == f32-carrier math, E2M1-exactly.

    The packed path re-derives every value in-kernel from 4-bit codes +
    8-bit scale codes + the FP32 tensor scale; the carrier path dequantizes
    the same QTensor in f32. Identical augmented GEMM results prove the
    4.5-bit storage is lossless end to end.
    """
    from repro.quant.apply import _augment_weight
    for trial in range(4):
        k = int(rng.choice([64, 128]))
        s = int(rng.choice([0, 16, 48]))
        m, n = 8, 16
        w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 2)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32) * 2)
        order = jnp.asarray(rng.permutation(k).astype(np.int32))
        ts = jnp.asarray([0.05, 0.005], jnp.float32)

        wq = _augment_weight(w, order, s, "nvfp4")       # f32 carrier
        wp = wq.to_packed()                              # 4.5-bit storage
        # bit-exact storage round trip
        np.testing.assert_array_equal(
            np.asarray(wp.dequantize()), np.asarray(wq.dequantize()))

        xc, xs = arc_fused_quantize(x, jnp.ones((k,), jnp.float32), order,
                                    ts, s, apply_norm=False, interpret=True)
        wc, ws, wt, packed = ops.qtensor_gemm_operands(wp)
        assert packed
        y_kernel = nvfp4_gemm(xc, xs, wc, ws, w_tensor_scale=wt,
                              w_packed=True, interpret=True)
        # f32-carrier oracle over the same codes
        y_carrier = ref.ref_nvfp4_gemm(
            xc, xs, jnp.asarray(np.asarray(
                ops.qtensor_gemm_operands(wq)[0])), wq.scales)
        np.testing.assert_allclose(np.asarray(y_kernel),
                                   np.asarray(y_carrier),
                                   rtol=1e-5, atol=1e-4)


def test_augment_weight_matches_kernel_quantizer(rng):
    """quant/apply._augment_weight (QTensor carrier) and
    ops.quantize_weight_interleaved (Pallas) emit identical codes/scales —
    one canonical layout, two producers."""
    from repro.quant.apply import _augment_weight
    k, s, n = 128, 32, 16
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    order = jnp.asarray(rng.permutation(k).astype(np.int32))
    qt = _augment_weight(w, order, s, "nvfp4").to_packed()
    codes_kernel, scales_kernel = ops.quantize_weight_interleaved(
        w, order, s, interpret=True)
    from repro.core import formats as F
    # decoded values, not raw codes: the two encoders differ only in the
    # sign bit of zeros (carrier drops it, the kernel keeps -0), which is
    # numerically irrelevant everywhere downstream
    np.testing.assert_array_equal(
        np.asarray(F.decode_e2m1(F.unpack_e2m1(qt.elements))),
        np.asarray(F.decode_e2m1(codes_kernel)))
    np.testing.assert_allclose(np.asarray(qt.scale_values()),
                               np.asarray(scales_kernel), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_kernel_input_dtypes(dtype, rng):
    """The fused kernel upcasts internally; bf16 inputs match the oracle."""
    m, k, s = 16, 64, 16
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32), dtype)
    gamma = jnp.asarray(1 + 0.1 * rng.normal(size=(k,)).astype(np.float32),
                        dtype)
    order = jnp.asarray(rng.permutation(k).astype(np.int32))
    ts = jnp.asarray([0.02, 0.002], jnp.float32)
    c1, s1 = arc_fused_quantize(x, gamma, order, ts, s, interpret=True)
    c2, s2 = ref.ref_arc_fused(x, gamma, order, ts, s)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
