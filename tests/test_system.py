"""End-to-end system behaviour: train -> calibrate -> quantize -> evaluate.

These tests exercise the full pipeline the way the paper uses it: a model
with real (trained) activation structure is post-training quantized with
each strategy and the quality ordering of Table 2 is checked at proxy
scale (ARC <= RTN in loss; ARC < RTN in layer-output MSE).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import (capture_stats, forward, init_params,
                          next_token_loss)
from repro.optim import adamw_init
from repro.quant import make_plan_bundle


@pytest.fixture(scope="module")
def trained():
    """A tiny LM trained enough to develop activation structure."""
    cfg = ARCHS["llama31-8b"].reduced(layers=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, base_lr=3e-3, warmup=5, total=60,
                                   remat=False), donate_argnums=(0, 1))
    data = SyntheticLM(cfg.vocab_size, 0)
    it = data.train_stream().batches(4, 64)
    losses = []
    for i in range(60):
        toks = next(it)
        pos = np.broadcast_to(np.arange(64), (4, 64)).astype(np.int32)
        params, opt, m = step(params, opt, {"tokens": jnp.asarray(toks),
                                            "positions": jnp.asarray(pos)})
        losses.append(float(m["loss"]))
    eval_toks = jnp.asarray(data.eval_batches(4, 64, 2)[0])
    return cfg, params, eval_toks, losses


def test_training_reduces_loss(trained):
    cfg, params, eval_toks, losses = trained
    assert losses[-1] < losses[0] - 0.5


def test_quantization_ordering(trained):
    """Table 2 at proxy scale: ARC is the best W4A4 method."""
    cfg, params, eval_toks, _ = trained
    stats = capture_stats(params, cfg, tokens=eval_toks)
    results = {}
    for method in ["none", "rtn", "arc"]:
        q = QuantConfig(method=method)
        plans = make_plan_bundle(stats, cfg, q, params)
        loss, _ = next_token_loss(params, cfg, eval_toks, quant=q, plans=plans)
        results[method] = float(loss)
    assert results["none"] <= results["arc"] + 0.02
    assert results["arc"] <= results["rtn"] + 1e-6


def test_layerwise_mse_ordering(trained):
    """Fig. 3 analogue: ARC suppresses per-layer output MSE vs RTN."""
    cfg, params, eval_toks, _ = trained
    stats = capture_stats(params, cfg, tokens=eval_toks)
    ref, _, _ = forward(params, cfg, tokens=eval_toks)
    mses = {}
    for method in ["rtn", "arc"]:
        q = QuantConfig(method=method)
        plans = make_plan_bundle(stats, cfg, q, params)
        lg, _, _ = forward(params, cfg, tokens=eval_toks, quant=q,
                           plans=plans)
        mses[method] = float(jnp.mean((lg - ref) ** 2))
    assert mses["arc"] < mses["rtn"]


def test_w4a8_reference_bracket(trained):
    """ARC (W4A4) should land near the W4A8 reference (paper's headline)."""
    cfg, params, eval_toks, _ = trained
    stats = capture_stats(params, cfg, tokens=eval_toks)
    losses = {}
    for name, q in {
        "rtn4": QuantConfig(method="rtn", fmt="nvfp4"),
        "arc": QuantConfig(method="arc", fmt="nvfp4"),
        "w4a8": QuantConfig(method="rtn", fmt="mxfp4", act_fmt="mxfp8"),
    }.items():
        plans = make_plan_bundle(stats, cfg, q, params)
        loss, _ = next_token_loss(params, cfg, eval_toks, quant=q,
                                  plans=plans)
        losses[name] = float(loss)
    assert losses["arc"] <= losses["rtn4"] + 1e-6
    assert losses["arc"] <= losses["w4a8"] + 0.1
