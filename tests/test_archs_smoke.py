"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import forward, init_cache, init_params, next_token_loss
from repro.optim import adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = {"tokens": toks}
    if cfg.frontend != "text":
        kw["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                         jnp.float32) * 0.1
    return toks, kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    toks, kw = _inputs(cfg)
    logits, _, aux = forward(params, cfg, **kw)
    from repro.models.lm import padded_vocab
    assert logits.shape == (2, 16, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all())
    if cfg.num_experts:
        assert float(aux["moe_loss"]) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY, jnp.float32)
    opt = adamw_init(params)
    toks, kw = _inputs(cfg)

    def loss_fn(p):
        return next_token_loss(p, cfg, toks, embeds=kw.get("embeds"))

    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    new_params, opt = adamw_update(params, grads, opt, 1e-3)
    (loss2, _), _ = jax.value_and_grad(loss_fn, has_aux=True)(new_params)
    assert np.isfinite(float(loss2))


# NOTE: qwen3-moe is excluded — top-k routing flips on 1-ulp program-level
# noise between the cached and uncached programs, which is a property of
# MoE numerics, not of the cache (jamba covers the MoE decode path).
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-3b", "jamba-v0.1-52b",
                                  "gemma3-12b"])
def test_decode_consistency(arch):
    """Prefill+decode through the cache == full forward (per family)."""
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    B, S = 2, 20
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    ref, _, _ = forward(params, cfg, tokens=toks)
    # NOTE: the prefill reference runs on exactly the first S tokens —
    # capacity-based MoE dispatch is sequence-length dependent (a later
    # token can displace an earlier one from an expert's capacity buffer),
    # so full(S+1)[:, :S] is not bitwise comparable for MoE archs.
    ref_p, _, _ = forward(params, cfg, tokens=toks[:, :S])
    cache = init_cache(cfg, B, S + 1)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    lg_p, cache, _ = forward(params, cfg, tokens=toks[:, :S], positions=pos,
                             cache=cache)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(ref_p),
                               atol=2e-2, rtol=1e-3)
    lg_d, _, _ = forward(params, cfg, tokens=toks[:, S:S + 1],
                         positions=jnp.full((B, 1), S), cache=cache)
    np.testing.assert_allclose(np.asarray(lg_d[:, 0]), np.asarray(ref[:, S]),
                               atol=5e-2, rtol=1e-2)


def test_mrope_text_equals_rope():
    """Qwen2-VL M-RoPE with equal position streams == standard RoPE path."""
    from repro.models.layers import apply_rope
    x = jax.random.normal(KEY, (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    a = apply_rope(x, pos, 1e4, mrope=False)
    b = apply_rope(x, pos, 1e4, mrope=True)
    # sections reorder frequencies; rotation magnitudes preserved
    np.testing.assert_allclose(np.linalg.norm(np.asarray(a)),
                               np.linalg.norm(np.asarray(b)), rtol=1e-5)


def test_gemma3_ring_cache_window():
    """Local-attention layers allocate window-sized (ring) caches."""
    cfg = ARCHS["gemma3-12b"].reduced()
    cache = init_cache(cfg, 2, 4 * cfg.sliding_window)
    # first 5 positions of the period are local -> ring of window size
    assert cache[0]["k"].shape[2] == cfg.sliding_window
    # global layer keeps the full length
    assert cache[5]["k"].shape[2] == 4 * cfg.sliding_window


def test_param_count_sanity():
    """Full-size param counts are in the right ballpark (N for roofline)."""
    assert 1.4e9 < ARCHS["qwen2-1.5b"].param_count() < 2.1e9
    assert 25e9 < ARCHS["qwen3-32b"].param_count() < 40e9
    moe = ARCHS["qwen3-moe-235b-a22b"]
    assert 180e9 < moe.param_count() < 300e9
    assert 15e9 < moe.active_param_count() < 40e9
