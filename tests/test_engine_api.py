"""Step-driven serving API: EngineCore add_request/step semantics,
streaming deltas vs the run() compatibility wrapper, chunked prefill
parity (one-shot vs chunked, including preemption + resume on the paged
backend), and slot-invariant temperature sampling.

The fast tests drive an unquantized (method="none") reduced model so the
core API is covered in the fast CI job; the arc-quantized architecture
matrix (dense/MoE/SSM) runs under the `slow` marker with the other
end-to-end serving suites.
"""
import copy

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import capture_stats, init_params
from repro.quant import make_plan_bundle, quantize_weights_for_serving
from repro.serving import (GenerationRequest, PagedServingEngine, Request,
                           SamplingParams, ServingEngine)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    """Unquantized reduced dense model — fast enough for the fast job."""
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=2)
    params = init_params(cfg, KEY)
    quant = QuantConfig(method="none")
    return cfg, params, quant


@pytest.fixture(scope="module")
def slot_engine(tiny):
    cfg, params, quant = tiny
    return ServingEngine(params, cfg, quant, None, batch_size=2, max_len=48)


@pytest.fixture(scope="module")
def paged_engine(tiny):
    cfg, params, quant = tiny
    return PagedServingEngine(params, cfg, quant, None, batch_size=2,
                              max_len=48)


def _workload(cfg, n=4, seed=42):
    rng = np.random.default_rng(seed)
    return [Request(
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(3, 15))).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 9))) for _ in range(n)]


def _stream_tokens(engine, reqs):
    """Drive stream() and concatenate each request's per-tick deltas."""
    toks, finished = {}, {}
    for ro in engine.stream(copy.deepcopy(reqs)):
        toks.setdefault(ro.request_id, []).extend(ro.new_tokens)
        if ro.finished:
            finished[ro.request_id] = ro.finish_reason
        assert ro.num_generated == len(toks[ro.request_id])
    return toks, finished


# ---------------------------------------------------------------------------
# Streaming vs run() (fast, slot + paged backends)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("which", ["slot", "paged"])
def test_stream_deltas_concatenate_to_run_tokens(which, tiny, slot_engine,
                                                 paged_engine):
    cfg = tiny[0]
    eng = slot_engine if which == "slot" else paged_engine
    reqs = _workload(cfg, n=4)
    run_out = eng.run(copy.deepcopy(reqs))
    toks, finished = _stream_tokens(eng, reqs)
    assert toks == {i: r.out_tokens for i, r in enumerate(run_out)}
    # every request finished exactly once, with a reason
    assert sorted(finished) == list(range(len(reqs)))
    assert all(reason in ("length", "eos") for reason in finished.values())


def test_run_reconstitutes_legacy_shape(tiny, slot_engine):
    """run() returns the same Request objects, results and metrics
    filled — the pre-redesign contract."""
    cfg = tiny[0]
    reqs = _workload(cfg, n=3)
    out = slot_engine.run(reqs)
    assert out is reqs
    for r in out:
        assert r.done and r.finish_reason is not None
        assert len(r.out_tokens) >= 1
        assert r.latency_steps is not None and r.latency_steps >= 0


# ---------------------------------------------------------------------------
# Step-driven core: mid-flight submission
# ---------------------------------------------------------------------------


def test_add_request_mid_flight_is_admitted_and_finishes(tiny, slot_engine):
    cfg = tiny[0]
    rng = np.random.default_rng(1)
    core = slot_engine.make_core()
    first = core.add_request(GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=10)))
    for _ in range(4):
        assert core.step().outputs     # first request emits every tick
    late = core.add_request(GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=3)))
    while core.has_unfinished():
        core.step()
    st = core.states[late]
    assert st.submit_step == 4 and st.admit_step >= 4
    assert st.done and len(st.out_tokens) == 3
    assert core.states[first].done
    assert len(core.states[first].out_tokens) == 10


def test_step_on_empty_core_is_harmless(slot_engine):
    core = slot_engine.make_core()
    assert not core.has_unfinished()
    out = core.step()
    assert out.outputs == [] and not out


def test_idle_step_launches_nothing(tiny, slot_engine):
    """An idle tick is free: no jitted entry point runs, and the engine
    clock does not advance — the server pump can poll ``step()`` cheaply
    between requests without burning device launches."""
    cfg = tiny[0]
    core = slot_engine.make_core()
    calls = []
    real_fns = core.fns

    class _Counting:
        def __getattr__(self, name):
            fn = getattr(real_fns, name)

            def wrapped(*a, **kw):
                calls.append(name)
                return fn(*a, **kw)
            return wrapped

    core.fns = _Counting()
    tick = core.sched.step
    for _ in range(5):
        out = core.step()
        assert out.outputs == []
    assert calls == []                      # zero device launches
    assert core.sched.step == tick          # clock did not advance
    # a real request still runs through the counting shims...
    rng = np.random.default_rng(9)
    rid = core.add_request(GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=2)))
    while core.has_unfinished():
        core.step()
    assert core.states[rid].done and calls  # launches happened for work
    # ...and once drained, idle ticks go back to zero launches
    n = len(calls)
    core.step()
    assert len(calls) == n


def test_pop_request_evicts_finished_state(tiny, slot_engine):
    """Long-lived cores drop finished states explicitly so the state map
    does not grow without bound."""
    cfg = tiny[0]
    rng = np.random.default_rng(8)
    core = slot_engine.make_core()
    rid = core.add_request(GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=2)))
    with pytest.raises(ValueError):
        core.pop_request(rid)               # still in flight
    while core.has_unfinished():
        core.step()
    st = core.pop_request(rid)
    assert st.done and len(st.out_tokens) == 2
    assert rid not in core.states


def test_duplicate_request_id_rejected(tiny, slot_engine):
    cfg = tiny[0]
    rng = np.random.default_rng(2)
    core = slot_engine.make_core()
    gr = GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
        request_id=5)
    core.add_request(gr)
    with pytest.raises(ValueError):
        core.add_request(gr)


# ---------------------------------------------------------------------------
# Chunked prefill (fast: prompt 30, chunk 8)
# ---------------------------------------------------------------------------


def _long_prompt_reqs(cfg, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, 30)
                    .astype(np.int32), max_new_tokens=5),
            Request(prompt=rng.integers(0, cfg.vocab_size, 5)
                    .astype(np.int32), max_new_tokens=8)]


def _core_tokens(engine, reqs, prefill_chunk=None):
    core = engine.make_core(prefill_chunk=prefill_chunk)
    rids = [core.add_request(r.to_generation_request()) for r in reqs]
    while core.has_unfinished():
        core.step()
    return [core.states[rid].out_tokens for rid in rids], core.stats


@pytest.mark.parametrize("which", ["slot", "paged"])
def test_chunked_prefill_token_identical(which, tiny, slot_engine,
                                         paged_engine):
    """prefill_chunk=8 over a 30-token prompt: greedy tokens must match
    one-shot prefill exactly, while the admission stall (prefill tokens
    one tick computes) drops to the chunk size."""
    cfg = tiny[0]
    eng = slot_engine if which == "slot" else paged_engine
    reqs = _long_prompt_reqs(cfg)
    ref, ref_stats = _core_tokens(eng, reqs)
    chunked, stats = _core_tokens(eng, reqs, prefill_chunk=8)
    assert chunked == ref
    # the stall bound no longer scales with prompt length: at worst every
    # slot contributes one chunk (or a shorter one-shot prompt) per tick
    assert stats.max_prefill_tokens_per_step <= 2 * 8
    assert ref_stats.max_prefill_tokens_per_step >= 30


def test_chunked_prefill_with_preemption_paged(tiny):
    """A pool too small for both requests preempts mid-flight; chunked
    prefill (including the resume re-prefill) must not change tokens."""
    cfg, params, quant = tiny
    reqs = _workload(cfg, n=4, seed=9)
    ref = ServingEngine(params, cfg, quant, None, batch_size=2,
                        max_len=48).run(copy.deepcopy(reqs))
    tiny_pool = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                                   max_len=48, num_pages=3, block_size=16,
                                   prefill_chunk=8)
    out = tiny_pool.run(copy.deepcopy(reqs))
    assert [r.out_tokens for r in out] == [r.out_tokens for r in ref]
    assert tiny_pool.last_stats.preemptions > 0


def test_chunked_prefill_interleaves_decode(tiny, slot_engine):
    """While a long prompt chunks in, an in-flight request keeps emitting
    one token per tick — the stall chunking exists to remove."""
    cfg = tiny[0]
    rng = np.random.default_rng(4)
    core = slot_engine.make_core(prefill_chunk=8)
    short = core.add_request(GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=12)))
    core.step()
    long = core.add_request(GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 30).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=4)))
    emitted_during_chunks = 0
    while core.has_unfinished():
        out = core.step()
        mine = [ro for ro in out.outputs if ro.request_id == short]
        still_chunking = (core.states[long].admit_step >= 0
                          and not core.states[long].out_tokens)
        if still_chunking and mine:
            emitted_during_chunks += len(mine[0].new_tokens)
    # the 30-token prompt needs 4 chunk ticks; the short request must
    # have kept decoding through them
    assert emitted_during_chunks >= 3
    assert core.states[long].done and core.states[short].done


# ---------------------------------------------------------------------------
# Slot-invariant sampling (temperature > 0)
# ---------------------------------------------------------------------------


def test_sampling_is_slot_invariant(tiny):
    """A request's sampled tokens depend only on (engine seed, request
    id, token index) — not on which slot it lands in or who shares the
    batch."""
    cfg, params, quant = tiny
    eng = ServingEngine(params, cfg, quant, None, batch_size=3, max_len=48,
                        seed=11)
    rng = np.random.default_rng(5)
    probe = GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=6, temperature=1.5),
        request_id=99)

    def serve(companions):
        core = eng.make_core()
        for i in range(companions):
            core.add_request(GenerationRequest(
                prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                sampling=SamplingParams(max_new_tokens=7, temperature=0.8),
                request_id=i))
        core.add_request(probe)
        while core.has_unfinished():
            core.step()
        return list(core.states[99].out_tokens)

    alone = serve(0)                    # slot 0, empty batch
    crowded = serve(2)                  # slot 2, sampled company
    assert alone == crowded
    assert len(set(alone)) > 1          # actually sampling, not a constant


def test_sampling_stream_matches_run(tiny, slot_engine):
    """Temperature>0 parity between stream() and run(): the per-request
    PRNG stream makes them identical, not just same-distribution."""
    cfg = tiny[0]
    rng = np.random.default_rng(6)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 6)
                    .astype(np.int32), max_new_tokens=5, temperature=2.0)
            for _ in range(3)]
    run_out = slot_engine.run(copy.deepcopy(reqs))
    toks, _ = _stream_tokens(slot_engine, reqs)
    assert toks == {i: r.out_tokens for i, r in enumerate(run_out)}


def test_sampling_reproducible_across_preemption(tiny):
    """Preemption + resume replays no RNG: the sampled trace equals the
    no-preemption trace because keys derive from (rid, token index)."""
    cfg, params, quant = tiny
    reqs = _workload(cfg, n=4, seed=9)
    for r in reqs:
        r.temperature = 1.2
    ref = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                             max_len=48, seed=3).run(copy.deepcopy(reqs))
    tiny_pool = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                                   max_len=48, seed=3, num_pages=3,
                                   block_size=16)
    out = tiny_pool.run(copy.deepcopy(reqs))
    assert tiny_pool.last_stats.preemptions > 0
    assert [r.out_tokens for r in out] == [r.out_tokens for r in ref]


# ---------------------------------------------------------------------------
# Arc-quantized architecture matrix (slow: with the e2e serving suites)
# ---------------------------------------------------------------------------

PARITY_ARCHS = ["qwen2-1.5b", "qwen3-moe-235b-a22b", "rwkv6-3b"]


def _build(arch):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    stats = capture_stats(params, cfg, tokens=toks)
    quant = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, quant, params)
    qparams = quantize_weights_for_serving(params, cfg, quant, plans,
                                           pack=True)
    return cfg, quant, plans, qparams


@pytest.mark.slow
@pytest.mark.parametrize("arch", PARITY_ARCHS)
@pytest.mark.parametrize("which", ["slot", "paged"])
def test_stream_matches_run_quantized_matrix(arch, which):
    """Streamed per-tick deltas concatenate to exactly run()'s out_tokens
    on dense / MoE / SSM configs, slot and paged backends."""
    cfg, quant, plans, qparams = _build(arch)
    cls = ServingEngine if which == "slot" else PagedServingEngine
    eng = cls(qparams, cfg, quant, plans, batch_size=2, max_len=48)
    reqs = _workload(cfg, n=3, seed=13)
    run_out = eng.run(copy.deepcopy(reqs))
    toks, _ = _stream_tokens(eng, reqs)
    assert toks == {i: r.out_tokens for i, r in enumerate(run_out)}, arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-3b"])
def test_chunked_prefill_token_identical_quantized(arch):
    """Chunked prefill greedy parity on the quantized path, including a
    recurrent-state (SSM) config whose prefill chunks thread state."""
    cfg, quant, plans, qparams = _build(arch)
    eng = ServingEngine(qparams, cfg, quant, plans, batch_size=2, max_len=48)
    reqs = _long_prompt_reqs(cfg, seed=17)
    ref, _ = _core_tokens(eng, reqs)
    chunked, stats = _core_tokens(eng, reqs, prefill_chunk=8)
    assert chunked == ref, arch
    assert stats.max_prefill_tokens_per_step <= 2 * 8
