"""Pallas paged-attention decode kernel: interpret-mode parity vs the
gather-path oracle (dense/GQA, ragged active counts, null-page tables,
single-resident and full-pool shapes), the block-table overflow
regression, and engine-level kernel==gather==slot greedy parity."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.kernels.ops import paged_attention
from repro.models import init_params
from repro.models import layers as L
from repro.serving import PagedServingEngine, Request, ServingEngine

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Kernel vs gather-path oracle (interpret mode)
# ---------------------------------------------------------------------------


def _oracle(q, kp, vp, posp, tables, qpos, window=None):
    """The jnp path the kernel replaces: gather the logical view, then
    chunked_attention — bit-for-bit the attention_layer fallback."""
    B = q.shape[0]
    nblocks, bs = tables.shape[1], kp.shape[1]
    hkv, hd = kp.shape[2], kp.shape[3]
    k_all = kp[tables].reshape(B, nblocks * bs, hkv, hd)
    v_all = vp[tables].reshape(B, nblocks * bs, hkv, hd)
    kv_pos = posp[tables].reshape(B, nblocks * bs)
    out = L.chunked_attention(jnp.asarray(q)[:, None], jnp.asarray(k_all),
                              jnp.asarray(v_all), jnp.asarray(qpos)[:, None],
                              jnp.asarray(kv_pos), window=window, q_chunk=1)
    return np.asarray(out[:, 0])


def _rand_pool(rng, B, num_pages, bs, hkv, hd, nblocks, lengths):
    """Pool with page 0 = null; per-row contiguous allocations of
    ``lengths[i]`` tokens (length 0 -> inactive row: qpos -1, null
    table). Unused pages keep stale random K/V bytes with posp = -1
    (recycled-page semantics: masking must hide them)."""
    kp = rng.normal(size=(num_pages, bs, hkv, hd)).astype(np.float32)
    vp = rng.normal(size=(num_pages, bs, hkv, hd)).astype(np.float32)
    posp = np.full((num_pages, bs), -1, np.int32)
    tables = np.zeros((B, nblocks), np.int32)
    qpos = np.full((B,), -1, np.int32)
    nxt = 1
    for i, n in enumerate(lengths):
        if n == 0:
            continue
        qpos[i] = n - 1
        for b in range((n + bs - 1) // bs):
            page = nxt
            nxt += 1
            assert page < num_pages, "pool too small for this workload"
            tables[i, b] = page
            wrote = min(bs, n - b * bs)
            posp[page, :wrote] = np.arange(b * bs, b * bs + wrote)
    return kp, vp, posp, tables, qpos


def _run_kernel(q, kp, vp, posp, tables, qpos, active=None, window=None):
    return np.asarray(paged_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(posp),
        jnp.asarray(tables), jnp.asarray(qpos), active, window=window,
        interpret=True))


def _assert_live_rows_match(out, ref, qpos):
    live = qpos >= 0
    np.testing.assert_allclose(out[live], ref[live], rtol=2e-5, atol=2e-6)
    # dead rows (qpos < 0) emit exact zeros from the kernel; the oracle's
    # softmax leaks uniform weights there (phantom exp(0) rows), but those
    # rows never survive the engine's scatter-back
    assert (out[~live] == 0).all()


@pytest.mark.parametrize("hkv,rep", [(4, 1), (2, 4)])  # dense MHA / GQA
def test_kernel_matches_gather(hkv, rep):
    rng = np.random.default_rng(0)
    bs, hd, nblocks = 8, 16, 3
    lengths = [20, 1, 24, 0]                 # partial tail / single / full
    kp, vp, posp, tables, qpos = _rand_pool(rng, 4, 12, bs, hkv, hd,
                                            nblocks, lengths)
    q = rng.normal(size=(4, hkv * rep, hd)).astype(np.float32)
    out = _run_kernel(q, kp, vp, posp, tables, qpos)
    ref = _oracle(q, kp, vp, posp, tables, qpos)
    _assert_live_rows_match(out, ref, qpos)


def test_kernel_single_resident():
    rng = np.random.default_rng(1)
    kp, vp, posp, tables, qpos = _rand_pool(rng, 1, 3, 4, 2, 8, 2, [5])
    q = rng.normal(size=(1, 4, 8)).astype(np.float32)
    out = _run_kernel(q, kp, vp, posp, tables, qpos)
    _assert_live_rows_match(out, _oracle(q, kp, vp, posp, tables, qpos),
                            qpos)


def test_kernel_full_pool():
    """Every usable page allocated, every table entry live."""
    rng = np.random.default_rng(2)
    B, bs, nblocks = 3, 4, 2
    kp, vp, posp, tables, qpos = _rand_pool(
        rng, B, B * nblocks + 1, bs, 2, 8, nblocks, [8, 8, 8])
    q = rng.normal(size=(B, 4, 8)).astype(np.float32)
    out = _run_kernel(q, kp, vp, posp, tables, qpos)
    _assert_live_rows_match(out, _oracle(q, kp, vp, posp, tables, qpos),
                            qpos)


def test_kernel_window_masking():
    rng = np.random.default_rng(3)
    kp, vp, posp, tables, qpos = _rand_pool(rng, 2, 8, 8, 2, 16, 3,
                                            [20, 24])
    q = rng.normal(size=(2, 4, 16)).astype(np.float32)
    out = _run_kernel(q, kp, vp, posp, tables, qpos, window=8)
    ref = _oracle(q, kp, vp, posp, tables, qpos, window=8)
    _assert_live_rows_match(out, ref, qpos)


def test_ragged_active_counts_share_one_trace():
    """Rows past the traced ``active`` scalar emit zeros, live rows are
    untouched, and every count reuses a single trace (the dynamic
    valid-row masking that replaces pow2 bucket retraces)."""
    rng = np.random.default_rng(4)
    B = 4
    kp, vp, posp, tables, qpos = _rand_pool(rng, B, 14, 8, 2, 16, 3,
                                            [20, 1, 24, 9])
    q = rng.normal(size=(B, 4, 16)).astype(np.float32)
    full = _run_kernel(q, kp, vp, posp, tables, qpos)
    traces = [0]

    def impl(active):
        traces[0] += 1
        return paged_attention(jnp.asarray(q), jnp.asarray(kp),
                               jnp.asarray(vp), jnp.asarray(posp),
                               jnp.asarray(tables), jnp.asarray(qpos),
                               active, interpret=True)

    f = jax.jit(impl)
    for n in (1, 3, 4, 2):
        out = np.asarray(f(jnp.int32(n)))
        np.testing.assert_allclose(out[:n], full[:n], rtol=1e-6, atol=1e-7)
        assert (out[n:] == 0).all()
    assert traces[0] == 1, "active-count change retraced the kernel"


def test_null_page_tables_contribute_nothing():
    """Unallocated table tails point at the null page (positions -1);
    padding its table out to max_blocks must not perturb a row."""
    rng = np.random.default_rng(5)
    kp, vp, posp, tables, qpos = _rand_pool(rng, 1, 4, 4, 2, 8, 6, [6])
    q = rng.normal(size=(1, 4, 8)).astype(np.float32)
    wide = _run_kernel(q, kp, vp, posp, tables, qpos)
    narrow = _run_kernel(q, kp, vp, posp, tables[:, :2], qpos)
    np.testing.assert_allclose(wide, narrow, rtol=1e-6, atol=1e-7)


_SHAPES = st.tuples(
    st.integers(1, 4),                       # batch rows
    st.sampled_from([(1, 1), (2, 1), (2, 2), (2, 3)]),   # (hkv, rep)
    st.sampled_from([4, 8]),                 # block size
    st.integers(1, 3),                       # max_blocks
    st.integers(0, 6),                       # content seed
)


@settings(max_examples=15)
@given(_SHAPES)
def test_randomized_kernel_oracle_parity(shape):
    """Random pool layouts (ragged lengths including inactive rows, GQA
    groupings, partial tails) stay bit-close to the gather oracle."""
    B, (hkv, rep), bs, nblocks, salt = shape
    rng = np.random.default_rng(1000 + salt)
    lengths = [int(rng.integers(0, nblocks * bs + 1)) for _ in range(B)]
    num_pages = 1 + sum((n + bs - 1) // bs for n in lengths) + 1
    kp, vp, posp, tables, qpos = _rand_pool(rng, B, num_pages, bs, hkv,
                                            8, nblocks, lengths)
    q = rng.normal(size=(B, hkv * rep, 8)).astype(np.float32)
    out = _run_kernel(q, kp, vp, posp, tables, qpos)
    ref = _oracle(q, kp, vp, posp, tables, qpos)
    _assert_live_rows_match(out, ref, qpos)


# ---------------------------------------------------------------------------
# Regression: block-table overflow must drop the write, not corrupt the
# last allocated block
# ---------------------------------------------------------------------------


def _paged_attn_setup(block_size=4, nblocks=2, num_pages=6):
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=1)
    ctx = L.LayerCtx(cfg)
    params = L.init_attention(KEY, cfg)
    pool = L.init_attention_page_pool(cfg, num_pages, block_size)
    return cfg, ctx, params, pool


def test_overflow_write_past_short_table_is_dropped():
    """Decoding one token past a deliberately short block table: the old
    ``clip(p // bs, 0, nblocks - 1)`` silently redirected the write into
    the *last allocated block*, overwriting another token's K/V; it must
    be dropped like the p < 0 padding writes."""
    bs, nblocks = 4, 2
    cfg, ctx, params, pool = _paged_attn_setup(bs, nblocks)
    table = np.array([[1, 2]], np.int32)     # capacity: nblocks*bs = 8
    # fill the table's capacity
    posp = np.asarray(pool["posp"]).copy()
    posp[1] = np.arange(0, bs)
    posp[2] = np.arange(bs, 2 * bs)
    pool = dict(pool, posp=jnp.asarray(posp))
    x = jax.random.normal(KEY, (1, 1, cfg.d_model), jnp.float32)
    # decode position 8: one past the table — blk = 2 is out of range
    overflow_pos = jnp.full((1, 1), nblocks * bs, jnp.int32)
    _, nc = L.attention_layer(ctx, "attn", params, x, overflow_pos, pool,
                              block_table=jnp.asarray(table))
    new_posp = np.asarray(nc["posp"])
    # the write vanished: no slot anywhere took position 8, and the last
    # allocated block's positions are intact (old clip behavior wrote
    # posp[2, 0] = 8)
    assert (new_posp == posp).all()
    assert not (new_posp == nblocks * bs).any()


def test_inactive_row_write_still_dropped():
    """The p < 0 padding-row semantics the overflow fix shares."""
    cfg, ctx, params, pool = _paged_attn_setup()
    x = jax.random.normal(KEY, (1, 1, cfg.d_model), jnp.float32)
    neg = jnp.full((1, 1), -1, jnp.int32)
    _, nc = L.attention_layer(ctx, "attn", params, x, neg,
                              pool, block_table=jnp.zeros((1, 2), jnp.int32))
    assert (np.asarray(nc["posp"]) == np.asarray(pool["posp"])).all()


# ---------------------------------------------------------------------------
# Engine-level greedy parity: slot == gather == kernel
# ---------------------------------------------------------------------------


def _engine_tokens(engine, reqs):
    served = engine.run(copy.deepcopy(reqs))
    assert all(r.done for r in served)
    return [r.out_tokens for r in served]


def test_engine_kernel_matches_gather_and_slot():
    """PagedServingEngine default (kernel) == attn_kernel=False (gather)
    == ServingEngine (slot pool), token-identical greedy traces."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = init_params(cfg, KEY)
    quant = QuantConfig(method="none")
    rng = np.random.default_rng(7)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=6) for n in (5, 11, 3)]
    kw = dict(batch_size=2, max_len=48)
    slot = _engine_tokens(ServingEngine(params, cfg, quant, None, **kw), reqs)
    gather = _engine_tokens(
        PagedServingEngine(params, cfg, quant, None, attn_kernel=False,
                           **kw), reqs)
    kernel = _engine_tokens(
        PagedServingEngine(params, cfg, quant, None, **kw), reqs)
    assert slot == gather == kernel
