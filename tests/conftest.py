import numpy as np
import pytest
from hypothesis import settings

# keep hypothesis fast on the single-core CI box
settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
