import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS

# compiled-artifact rule fixtures (hlo_lint / trace_guard /
# assert_no_findings) — see src/repro/analysis/pytest_plugin.py
pytest_plugins = ("repro.analysis.pytest_plugin",)

if HAVE_HYPOTHESIS:
    # keep hypothesis fast on the single-core CI box; registered only when
    # the real library is installed (the fallback shim has its own budget)
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
