"""Unit coverage for ``launch.hlo_analysis.analyze_hlo`` on synthetic
HLO: the dtype byte table (fp8 scale codes / packed 4-bit nibbles on the
deployed NVFP4 path), dot-FLOP accounting on ROOT lines, and
trip-count-aware multiplication through (nested) while loops — plus the
fp8 wire-byte path of the canonical collective parser.

The synthetic modules follow the post-optimization text format the
regex parser expects: computation headers like
``ENTRY %main (p0: ...) -> ... {``, ``%``-prefixed instruction names,
and while lines carrying ``body=``/``condition=`` plus a
``known_trip_count`` backend_config.
"""
from repro.analysis.collectives import parse_collectives
from repro.launch.hlo_analysis import analyze_hlo

FP8_MODULE = """\
HloModule fp8_bytes

ENTRY %main (p0: f32[128,4]) -> s4[64,64] {
  %p0 = f32[128,4] parameter(0)
  %q = f8e4m3fn[128,4] convert(%p0)
  ROOT %pk = s4[64,64] copy(%q)
}
"""

DOT_MODULE = """\
HloModule root_dot

ENTRY %main (p0: f32[8,16], p1: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16] parameter(0)
  %p1 = f32[16,4] parameter(1)
  ROOT %d = f32[8,4] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def _while_module(inner_trip='backend_config={"known_trip_count":{"n":"3"}}',
                  outer_trip='backend_config={"known_trip_count":{"n":"4"}}'):
    """Nested whiles: the inner body's 64-byte copy must be charged
    inner_trip * outer_trip times."""
    return f"""\
HloModule nested_while

%inner_body (x: f32[16]) -> f32[16] {{
  %x = f32[16] parameter(0)
  ROOT %cp = f32[16] copy(%x)
}}

%inner_cond (xc: f32[16]) -> pred[] {{
  ROOT %t0 = pred[] constant(false)
}}

%outer_body (y: f32[16]) -> f32[16] {{
  %y = f32[16] parameter(0)
  ROOT %w_in = f32[16] while(%y), condition=%inner_cond, body=%inner_body, {inner_trip}
}}

%outer_cond (yc: f32[16]) -> pred[] {{
  ROOT %t1 = pred[] constant(false)
}}

ENTRY %main (p0: f32[16]) -> f32[16] {{
  %p0 = f32[16] parameter(0)
  ROOT %w_out = f32[16] while(%p0), condition=%outer_cond, body=%outer_body, {outer_trip}
}}
"""


DOT_IN_LOOP_MODULE = """\
HloModule scanned_dot

%body (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,4] broadcast(%a), dimensions={}
  ROOT %d = f32[8,4] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%cond (c: f32[8,16]) -> pred[] {
  ROOT %t = pred[] constant(false)
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  ROOT %w = f32[8,16] while(%p0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
}
"""


# ---------------------------------------------------------------------------
# dtype byte table: fp8 scale codes and packed nibbles must count
# ---------------------------------------------------------------------------


def test_fp8_and_packed_nibble_bytes_counted():
    acc = analyze_hlo(FP8_MODULE)
    # convert -> f8e4m3fn[128,4] = 512 B at 1 B/elem; copy -> s4[64,64]
    # = 2048 B at 0.5 B/elem. Before the table carried these dtypes the
    # deployed NVFP4 path's HBM bytes silently read as zero.
    assert acc["bytes"] == 128 * 4 * 1 + 64 * 64 * 0.5


def test_f8e5m2_scale_codes_counted():
    hlo = FP8_MODULE.replace("f8e4m3fn", "f8e5m2")
    assert analyze_hlo(hlo)["bytes"] == 128 * 4 * 1 + 64 * 64 * 0.5


# ---------------------------------------------------------------------------
# dot FLOPs (including on ROOT lines) and trip-count accounting
# ---------------------------------------------------------------------------


def test_root_dot_flops_from_contracting_dims():
    acc = analyze_hlo(DOT_MODULE)
    # 2 * out_elems * K = 2 * (8*4) * 16; the ROOT prefix must not hide
    # the instruction from the def regex
    assert acc["flops"] == 2 * 8 * 4 * 16


def test_nested_while_trip_counts_multiply():
    acc = analyze_hlo(_while_module())
    # the 64-byte inner copy runs inner(3) * outer(4) = 12 times
    assert acc["bytes"] == 16 * 4 * 3 * 4


def test_unknown_trip_count_is_conservative():
    # strip the backend_config: an unknown trip count multiplies by 1
    hlo = _while_module(inner_trip="metadata={}", outer_trip="metadata={}")
    assert analyze_hlo(hlo)["bytes"] == 16 * 4


def test_dot_inside_while_scales_flops():
    acc = analyze_hlo(DOT_IN_LOOP_MODULE)
    assert acc["flops"] == 2 * 8 * 4 * 16 * 4      # base dot x trip 4


# ---------------------------------------------------------------------------
# collective parser: fp8 wire bytes (ring model)
# ---------------------------------------------------------------------------


def test_parse_collectives_counts_fp8_wire_bytes():
    hlo = ("  %ag = f8e4m3fn[1024] all-gather(%x), dimensions={0}, "
           "replica_groups={{0,1,2,3}}\n")
    coll = parse_collectives(hlo)
    assert coll["count"] == 1
    # ring all-gather: (n-1)/n * result bytes, 1 B/elem at fp8
    assert coll["all-gather"] == (4 - 1) / 4 * 1024


def test_parse_collectives_iota_replica_groups():
    hlo = "  %ar = f32[256] all-reduce(%x), replica_groups=[2,4], to_apply=%add\n"
    coll = parse_collectives(hlo)
    assert coll["all-reduce"] == 2.0 * (4 - 1) / 4 * 256 * 4
