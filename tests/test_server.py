"""OpenAI-compatible HTTP front end: wire-format and lifecycle contract.

Raw ``asyncio.open_connection`` clients against a real ``ServerApp`` on
an ephemeral port — no HTTP library on either side, so the bytes on the
wire are exactly what is asserted. The load-bearing contracts:

  * streaming SSE deltas concatenate *bit-identical* to
    ``engine.stream()`` for the same pinned request id and seed (the
    per-token byte tokenizer makes text deltas exact, and slot-invariant
    sampling makes temperature>0 reproducible);
  * a client that disconnects mid-stream gets its request aborted:
    ``EngineStats.aborted`` increments and the paged pool releases every
    page (invariants checked);
  * a full bounded admission queue maps to HTTP 429 + ``Retry-After``
    — made deterministic by pinning the engine mid-tick with
    ``FaultInjector.hold_at``;
  * watchdog expiries surface as ``finish_reason: "timeout"`` with
    structured ``finish_details``, capacity misfits as HTTP 400;
  * ``/metrics`` exposes the robustness counters and TTFT/latency
    percentiles in Prometheus text format.

Event-loop use: each test drives its own ``asyncio.run`` (no
pytest-asyncio dependency); the app is started and torn down inside the
coroutine so the pump task lives on that loop.
"""
import asyncio
import copy
import json

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import init_params
from repro.serving import (GenerationRequest, PagedServingEngine,
                           SamplingParams, ServingEngine)
from repro.serving.faults import FaultInjector
from repro.server import ServerApp, ServerDefaults
from repro.server.chat import ByteTokenizer, render_chat
from repro.server.sse import DONE_PAYLOAD, SSEParser

KEY = jax.random.PRNGKey(0)
POLL_S = 0.02
POLLS = 500                         # 10s liveness bound on every wait loop


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=2)
    params = init_params(cfg, KEY)
    quant = QuantConfig(method="none")
    return cfg, params, quant


@pytest.fixture(scope="module")
def slot_engine(tiny):
    cfg, params, quant = tiny
    return ServingEngine(params, cfg, quant, None, batch_size=2, max_len=48)


@pytest.fixture(scope="module")
def paged_engine(tiny):
    cfg, params, quant = tiny
    return PagedServingEngine(params, cfg, quant, None, batch_size=2,
                              max_len=48, block_size=4, prefix_cache=True)


@pytest.fixture(scope="module")
def chat_engine(tiny):
    """Chat prompts run ~90 template tokens; give them room."""
    cfg, params, quant = tiny
    return ServingEngine(params, cfg, quant, None, batch_size=2, max_len=128)


@pytest.fixture(scope="module")
def bounded_engine(tiny):
    """One queue slot: the second concurrent submission must 429."""
    cfg, params, quant = tiny
    return ServingEngine(params, cfg, quant, None, batch_size=2, max_len=48,
                         max_queue=1)


# -- raw-socket HTTP client --------------------------------------------------


def _parse_response(raw: bytes):
    head, _, body = raw.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split(" ")[1])
    headers = {}
    for line in lines[1:]:
        k, _, v = line.partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers, body


async def _connect(port: int, method: str, path: str, obj=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(obj).encode("utf-8") if obj is not None else b""
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: test\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode("ascii")
                 + body)
    await writer.drain()
    return reader, writer


async def _request(port: int, method: str, path: str, obj=None):
    """One request/response round trip (server closes the connection)."""
    reader, writer = await _connect(port, method, path, obj)
    raw = await reader.read()
    writer.close()
    await writer.wait_closed()
    return _parse_response(raw)


async def _read_sse(reader) -> list:
    """Read status+headers then SSE events until [DONE]; returns the
    decoded JSON payloads (without the DONE sentinel)."""
    head = await reader.readuntil(b"\r\n\r\n")
    assert b" 200 " in head.split(b"\r\n")[0]
    assert b"text/event-stream" in head
    parser, events = SSEParser(), []
    while True:
        chunk = await reader.read(64)       # small reads exercise reassembly
        assert chunk, "stream ended before [DONE]"
        for payload in parser.feed(chunk):
            if payload == DONE_PAYLOAD:
                return events
            events.append(json.loads(payload))


class _App:
    """Start/stop a ServerApp around a test body."""

    def __init__(self, engine, faults=None, defaults=None, **core_kw):
        self.core = engine.make_core(faults=faults, **core_kw)
        self.app = ServerApp(self.core, model_id="tiny-proxy",
                             defaults=defaults
                             or ServerDefaults(max_new_tokens=8))

    async def __aenter__(self):
        await self.app.start()
        return self

    async def __aexit__(self, *exc):
        await self.app.stop()

    @property
    def port(self):
        return self.app.port


async def _poll(cond, msg: str):
    for _ in range(POLLS):
        if cond():
            return
        await asyncio.sleep(POLL_S)
    raise AssertionError(f"timed out waiting for {msg}")


# -- plumbing endpoints ------------------------------------------------------


def test_health_models_and_routing(slot_engine):
    async def body():
        async with _App(slot_engine) as h:
            status, _, payload = await _request(h.port, "GET", "/health")
            assert status == 200 and json.loads(payload)["status"] == "ok"
            status, _, payload = await _request(h.port, "GET", "/v1/models")
            data = json.loads(payload)["data"]
            assert status == 200 and data[0]["id"] == "tiny-proxy"
            status, _, _ = await _request(h.port, "GET", "/nope")
            assert status == 404
            status, headers, _ = await _request(h.port, "POST", "/health")
            assert status == 405 and headers["allow"] == "GET"
    asyncio.run(body())


def test_malformed_requests_get_400(slot_engine):
    async def body():
        async with _App(slot_engine) as h:
            for bad in [{"prompt": ""},                      # empty
                        {"prompt": 7},                       # wrong type
                        {"prompt": [0, 99999]},              # id out of range
                        {"prompt": [1, 2], "n": 2},          # n unsupported
                        {"prompt": [1, 2], "max_tokens": 0}]:
                status, _, payload = await _request(
                    h.port, "POST", "/v1/completions", bad)
                assert status == 400, (bad, payload)
                assert "error" in json.loads(payload)
            # invalid JSON body
            reader, writer = await asyncio.open_connection("127.0.0.1",
                                                           h.port)
            writer.write(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                         b"Content-Length: 4\r\n\r\n{{{{")
            await writer.drain()
            status, _, _ = _parse_response(await reader.read())
            writer.close()
            assert status == 400
            # chat role validation
            status, _, _ = await _request(
                h.port, "POST", "/v1/chat/completions",
                {"messages": [{"role": "robot", "content": "x"}]})
            assert status == 400
    asyncio.run(body())


# -- generation: parity with the engine API ----------------------------------


def test_completion_matches_engine_stream(tiny, slot_engine):
    """Non-stream completion over raw token ids is token-exact against
    engine.stream() with the same pinned request id."""
    cfg = tiny[0]
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    ref = []
    for ro in slot_engine.stream([GenerationRequest(
            prompt=prompt, request_id=0,
            sampling=SamplingParams(max_new_tokens=6))]):
        ref.extend(ro.new_tokens)

    async def body():
        async with _App(slot_engine) as h:
            status, _, payload = await _request(
                h.port, "POST", "/v1/completions",
                {"prompt": [int(t) for t in prompt], "max_tokens": 6,
                 "request_id": 0})
            assert status == 200
            out = json.loads(payload)
            choice = out["choices"][0]
            assert choice["token_ids"] == [int(t) for t in ref]
            assert choice["finish_reason"] == "length"
            tok = ByteTokenizer(cfg.vocab_size)
            assert choice["text"] == tok.decode(ref)
            assert out["usage"]["completion_tokens"] == len(ref)
            assert out["id"] == "cmpl-0"
    asyncio.run(body())


def test_sse_stream_bit_identical_to_engine(tiny, slot_engine):
    """Streaming deltas (temperature>0, pinned rid) concatenate to the
    exact engine.stream() token/text sequence — the SSE framing adds and
    loses nothing."""
    cfg = tiny[0]
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    sampling = SamplingParams(max_new_tokens=7, temperature=0.8)
    ref = []
    for ro in slot_engine.stream([GenerationRequest(
            prompt=prompt, request_id=11, sampling=sampling)]):
        ref.extend(ro.new_tokens)

    async def body():
        async with _App(slot_engine) as h:
            reader, writer = await _connect(
                h.port, "POST", "/v1/completions",
                {"prompt": [int(t) for t in prompt], "max_tokens": 7,
                 "temperature": 0.8, "request_id": 11, "stream": True})
            events = await _read_sse(reader)
            writer.close()
            await writer.wait_closed()
            toks, text = [], ""
            for ev in events:
                assert ev["id"] == "cmpl-11"
                choice = ev["choices"][0]
                toks.extend(choice["token_ids"])
                text += choice["text"]
            assert toks == [int(t) for t in ref]
            tok = ByteTokenizer(cfg.vocab_size)
            assert text == tok.decode(ref)              # bit-identical
            assert events[-1]["choices"][0]["finish_reason"] == "length"
    asyncio.run(body())


def test_chat_stream_roundtrip(tiny, chat_engine):
    cfg = tiny[0]
    messages = [{"role": "system", "content": "terse"},
                {"role": "user", "content": "hi"}]

    async def body():
        async with _App(chat_engine) as h:
            reader, writer = await _connect(
                h.port, "POST", "/v1/chat/completions",
                {"messages": messages, "max_tokens": 5, "stream": True,
                 "request_id": 2})
            events = await _read_sse(reader)
            writer.close()
            assert events[0]["object"] == "chat.completion.chunk"
            assert events[0]["choices"][0]["delta"]["role"] == "assistant"
            toks, text = [], ""
            for ev in events:
                choice = ev["choices"][0]
                toks.extend(choice["token_ids"])
                text += choice["delta"].get("content", "")
            assert len(toks) == 5
            assert text == ByteTokenizer(cfg.vocab_size).decode(toks)
            # same conversation, non-stream: identical tokens (greedy)
            status, _, payload = await _request(
                h.port, "POST", "/v1/chat/completions",
                {"messages": messages, "max_tokens": 5, "request_id": 3})
            assert status == 200
            out = json.loads(payload)["choices"][0]
            assert out["token_ids"] == toks
            assert out["message"]["content"] == text
    asyncio.run(body())

    # chat prompt == tokenized template render (prefix-cache determinism)
    assert render_chat(messages) == render_chat(list(messages))


# -- lifecycle: disconnect, backpressure, watchdogs --------------------------


def test_disconnect_aborts_and_releases_pages(paged_engine):
    """Kill the socket mid-stream: the request aborts within a tick, the
    paged pool releases every page, and the pool invariants hold."""
    async def body():
        async with _App(paged_engine) as h:
            core = h.core
            reader, writer = await _connect(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2, 3, 4], "max_tokens": 40,
                 "stream": True})
            # prove the stream is live before cutting it
            head = await reader.readuntil(b"\r\n\r\n")
            assert b"text/event-stream" in head
            first = await reader.readuntil(b"\n\n")
            assert first.startswith(b"data: ")
            writer.close()                  # mid-stream disconnect
            await writer.wait_closed()
            await _poll(lambda: core.stats.aborted == 1, "abort counted")
            await _poll(lambda: core.pool.pages_in_use == 0,
                        "pages released")
            core.pool.check_invariants()
            assert not core.has_unfinished()
            assert core.states == {}        # popped: state map stays bounded
    asyncio.run(body())


def test_nonstream_disconnect_aborts_and_releases_pages(paged_engine):
    """The non-streaming path has the same disconnect contract as SSE: a
    client that vanishes mid-generation aborts within a tick and holds no
    pages until its (unreadable) response would have completed."""
    async def body():
        async with _App(paged_engine) as h:
            core = h.core
            reader, writer = await _connect(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2, 3, 4], "max_tokens": 40})
            await _poll(core.has_unfinished, "request admitted")
            writer.close()                  # vanish before the response
            await writer.wait_closed()
            await _poll(lambda: core.stats.aborted == 1, "abort counted")
            await _poll(lambda: core.pool.pages_in_use == 0,
                        "pages released")
            core.pool.check_invariants()
            assert core.states == {}
    asyncio.run(body())


def test_stale_abort_after_finish_keeps_pump_alive(slot_engine):
    """A disconnect that races completion enqueues an abort for a rid the
    fanout already popped — the pump must treat it as a no-op, not die
    with KeyError and stop ticking for everyone."""
    async def body():
        async with _App(slot_engine) as h:
            status, _, _ = await _request(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2], "max_tokens": 2, "request_id": 21})
            assert status == 200            # rid 21 finished and popped
            h.app.pump.abort(21)            # stale: raced completion
            status, _, _ = await asyncio.wait_for(_request(
                h.port, "POST", "/v1/completions",
                {"prompt": [3, 4], "max_tokens": 2}), 10)
            assert status == 200            # pump survived the stale abort
            assert h.core.stats.aborted == 0
    asyncio.run(body())


def test_stray_client_bytes_are_not_a_disconnect(slot_engine):
    """Bytes arriving after the request body (trailing newline, pipelined
    junk) must not trip the socket-EOF watch: the stream runs to [DONE]
    and nothing is aborted."""
    async def body():
        async with _App(slot_engine) as h:
            reader, writer = await _connect(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2, 3], "max_tokens": 5, "stream": True})
            head = await reader.readuntil(b"\r\n\r\n")
            assert b"text/event-stream" in head
            writer.write(b"\r\nGET /health HTTP/1.1\r\n\r\n")   # stray bytes
            await writer.drain()
            parser, events = SSEParser(), []
            while True:
                chunk = await asyncio.wait_for(reader.read(64), 10)
                assert chunk, "stream ended before [DONE]"
                done = False
                for payload in parser.feed(chunk):
                    if payload == DONE_PAYLOAD:
                        done = True
                        break
                    events.append(json.loads(payload))
                if done:
                    break
            writer.close()
            assert sum(len(e["choices"][0]["token_ids"])
                       for e in events) == 5
            assert h.core.stats.aborted == 0
    asyncio.run(body())


def test_failed_step_sweeps_finished_requests(slot_engine):
    """If step() raises after marking a request finished, the pump must
    synthesize the lost final delta and sentinel — handlers unwind with
    the request's real finish reason instead of awaiting forever — and
    keep serving."""
    async def body():
        async with _App(slot_engine) as h:
            core = h.core
            orig, tripped = core.step, []

            def flaky_step():
                out = orig()
                if any(ro.finished for ro in out.outputs) and not tripped:
                    tripped.append(True)
                    raise RuntimeError("injected post-finish step failure")
                return out

            core.step = flaky_step
            try:
                status, _, payload = await asyncio.wait_for(_request(
                    h.port, "POST", "/v1/completions",
                    {"prompt": [1, 2, 3], "max_tokens": 3}), 10)
                assert status == 200
                assert tripped              # the failure actually fired
                choice = json.loads(payload)["choices"][0]
                assert choice["finish_reason"] == "length"
                # pump survived: the next request completes normally
                status, _, _ = await asyncio.wait_for(_request(
                    h.port, "POST", "/v1/completions",
                    {"prompt": [4, 5], "max_tokens": 2}), 10)
                assert status == 200
                assert core.states == {}    # swept finishes were popped
            finally:
                core.step = orig
    asyncio.run(body())


def test_pump_trims_histograms(slot_engine):
    """A long-lived pump bounds the stats histograms so /metrics scrape
    cost stays O(keep), not O(total requests served)."""
    async def body():
        async with _App(slot_engine) as h:
            h.app.pump.trim_every = 1       # trim every tick
            h.app.pump.hist_keep = 2
            for _ in range(3):
                status, _, _ = await _request(
                    h.port, "POST", "/v1/completions",
                    {"prompt": [1, 2, 3], "max_tokens": 2})
                assert status == 200
            assert len(h.core.stats.latency_hist) <= 2
            assert len(h.core.stats.ttft_hist) <= 2
    asyncio.run(body())


def test_queue_full_maps_to_429(bounded_engine):
    """Bounded admission queue -> deterministic HTTP 429: the engine is
    pinned mid-tick by an injected hold, so the queued request cannot be
    admitted while the second submission arrives."""
    faults = FaultInjector().hold_at(0)

    async def body():
        async with _App(bounded_engine, faults=faults) as h:
            first = asyncio.ensure_future(_request(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2, 3], "max_tokens": 3}))
            # the hold is logged once tick 0 is pinned inside step()
            await _poll(lambda: any(e["kind"] == "hold"
                                    for e in faults.log), "tick 0 held")
            status, headers, payload = await _request(
                h.port, "POST", "/v1/completions",
                {"prompt": [4, 5, 6], "max_tokens": 3})
            assert status == 429
            assert headers["retry-after"] == "1"
            assert json.loads(payload)["error"]["code"] == "queue_full"
            assert h.core.stats.rejected == 1
            faults.release()
            status, _, payload = await first
            assert status == 200
            assert json.loads(payload)["choices"][0]["finish_reason"] \
                == "length"
    asyncio.run(body())


def test_capacity_misfit_maps_to_400(slot_engine):
    async def body():
        async with _App(slot_engine) as h:
            status, _, payload = await _request(
                h.port, "POST", "/v1/completions",
                {"prompt": list(range(1, 47)), "max_tokens": 50})
            assert status == 400
            assert json.loads(payload)["error"]["code"] == "capacity"
            assert h.core.stats.rejected == 1
    asyncio.run(body())


def test_deadline_expiry_is_structured_timeout(slot_engine):
    async def body():
        async with _App(slot_engine) as h:
            status, _, payload = await _request(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2, 3], "max_tokens": 40,
                 "deadline_steps": 3})
            assert status == 200
            choice = json.loads(payload)["choices"][0]
            assert choice["finish_reason"] == "timeout"
            assert choice["finish_details"] == {"type": "timeout",
                                                "reason": "deadline"}
            assert h.core.stats.expired == 1
    asyncio.run(body())


def test_duplicate_request_id_maps_to_400(slot_engine):
    async def body():
        async with _App(slot_engine) as h:
            reader, writer = await _connect(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2], "max_tokens": 30, "request_id": 5,
                 "stream": True})
            await reader.readuntil(b"\r\n\r\n")     # in flight
            status, _, payload = await _request(
                h.port, "POST", "/v1/completions",
                {"prompt": [3, 4], "request_id": 5})
            assert status == 400
            assert "duplicate" in json.loads(payload)["error"]["message"]
            writer.close()
    asyncio.run(body())


# -- /metrics ----------------------------------------------------------------


def test_metrics_exposition(paged_engine):
    """After real traffic (one finish, one disconnect-abort), /metrics
    carries the robustness counters, pool gauges, and tick-latency
    percentiles in Prometheus text format."""
    async def body():
        async with _App(paged_engine) as h:
            status, _, _ = await _request(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2, 3, 4], "max_tokens": 4})
            assert status == 200
            reader, writer = await _connect(
                h.port, "POST", "/v1/completions",
                {"prompt": [1, 2, 3, 4], "max_tokens": 40,
                 "stream": True})
            await reader.readuntil(b"\n\n")
            writer.close()
            await _poll(lambda: h.core.stats.aborted == 1, "abort counted")
            status, headers, payload = await _request(h.port, "GET",
                                                      "/metrics")
            assert status == 200
            assert headers["content-type"].startswith("text/plain")
            text = payload.decode("utf-8")
            for needle in [
                    "repro_requests_aborted_total 1",
                    "repro_requests_expired_total 0",
                    "repro_requests_rejected_total 0",
                    "repro_requests_nan_isolated_total 0",
                    "repro_step_failures_total 0",
                    'repro_ttft_steps{quantile="0.5"}',
                    'repro_ttft_steps{quantile="0.95"}',
                    'repro_request_latency_steps{quantile="0.5"}',
                    "repro_pages_in_use 0",
                    "repro_prefix_hit_ratio",
                    "# TYPE repro_ttft_steps summary",
                    "# TYPE repro_requests_aborted_total counter",
            ]:
                assert needle in text, f"missing {needle!r} in:\n{text}"
            # histograms observed both finishes (length + abort)
            assert "repro_request_latency_steps_count 2" in text
    asyncio.run(body())


# -- e2e smoke on the quantized proxy (slow job) -----------------------------


@pytest.mark.slow
def test_server_e2e_quantized_smoke(tiny):
    """End-to-end: ARC-quantized tiny proxy behind the full stack — one
    streaming chat completion, a /metrics scrape, and a clean shutdown
    with zero pages leaked."""
    from repro.launch.cli import calibrate_and_quantize
    cfg, params, _ = tiny
    qparams, quant, plans = calibrate_and_quantize(params, cfg, "arc",
                                                   n_calib=2, seq=32)
    engine = PagedServingEngine(qparams, cfg, quant, plans, batch_size=2,
                                max_len=96, block_size=4, prefix_cache=True)

    async def body():
        async with _App(engine) as h:
            reader, writer = await _connect(
                h.port, "POST", "/v1/chat/completions",
                {"messages": [{"role": "user", "content": "ping"}],
                 "max_tokens": 4, "stream": True})
            events = await _read_sse(reader)
            writer.close()
            assert sum(len(e["choices"][0]["token_ids"])
                       for e in events) == 4
            assert events[-1]["choices"][0]["finish_reason"] == "length"
            status, _, payload = await _request(h.port, "GET", "/metrics")
            assert status == 200
            assert "repro_engine_generated_tokens_total 4" \
                in payload.decode()
            return h.core
    core = asyncio.run(body())
    core.pool.check_invariants()
    assert core.pool.pages_in_use == 0
    assert not core.has_unfinished()
