"""Compiled-artifact rule engine (``repro.analysis``): per-rule unit
tests on synthetic HLO, parser coverage for the alias header / tuple
shapes / custom-call targets, and the two seeded engine-level
regressions the PR's acceptance criteria name — the jnp gather fallback
tripping R2 and a lost ``donate_argnums`` tripping R3. The slow matrix
asserts the full suite is clean on current main for slot/paged x
dense/MoE arc-quantized engines (the same cells as the CI ``lint-hlo``
gate).
"""
import dataclasses

import jax
import pytest

from repro.analysis import build_artifact, max_severity, parse_hlo, run_rules
from repro.analysis.rules import RuleContext
from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import init_params
from repro.serving import PagedServingEngine, ServingEngine

KEY = jax.random.PRNGKey(0)

PLAIN_HDR = "HloModule jit_step\n"
# three donated cache leaves aliased through to outputs 0..2
ALIAS3_HDR = ("HloModule jit_step, input_output_alias={ "
              "{0}: (1, {}, may-alias), {1}: (2, {}, may-alias), "
              "{2}: (3, {}, may-alias) }\n")
ALIAS1_HDR = ("HloModule jit_step, input_output_alias={ "
              "{0}: (1, {}, may-alias) }\n")


def _mod(header, *body_lines):
    return header + "\n".join(
        ["", "ENTRY %main (p0: f32[4]) -> f32[4] {",
         *(f"  {line}" for line in body_lines), "}", ""])


# ---------------------------------------------------------------------------
# parse_hlo
# ---------------------------------------------------------------------------


def test_parse_hlo_alias_header_and_shapes():
    hlo = _mod(ALIAS3_HDR,
               "%p0 = f32[4] parameter(0)",
               "%t = (f32[4]{0}, s32[]) tuple(%p0, %p0)",
               '%cc = f32[4] custom-call(%p0), custom_call_target="__cublas$gemm"',
               "ROOT %r = f32[4] copy(%p0)")
    mod = parse_hlo(hlo)
    assert mod.input_output_alias == [((0,), 1), ((1,), 2), ((2,), 3)]
    by_name = {i.name: i for i in mod.instructions()}
    assert by_name["t"].opcode == "tuple"
    assert by_name["t"].shapes == [("f32", (4,)), ("s32", ())]
    assert by_name["cc"].custom_call_target == "__cublas$gemm"
    assert by_name["r"].is_root
    assert mod.entry is not None and mod.entry.name == "main"
    assert [i.name for i in mod.find_shape((4,), ("s32",))] == []


def test_parse_hlo_no_alias_header():
    assert parse_hlo(_mod(PLAIN_HDR, "%p0 = f32[4] parameter(0)")
                     ).input_output_alias == []


# ---------------------------------------------------------------------------
# R1: no dequantized full-weight materialization
# ---------------------------------------------------------------------------

R1_META = {"deployed": True,
           "forbidden_weight_shapes": {(64, 160): "b0.mlp.up",
                                       (160, 64): "b0.mlp.up"}}


def test_r1_fires_on_wide_full_weight_tensor():
    hlo = _mod(PLAIN_HDR,
               "%p0 = f32[4] parameter(0)",
               "%w = bf16[64,160] convert(%p0)",
               "%pk = u8[64,80] copy(%p0)")     # packed bytes: legal
    f = run_rules(RuleContext(entry="decode", hlo_text=hlo, meta=R1_META),
                  only=["R1"])
    assert [x.severity for x in f] == ["error"]
    assert f[0].op == "w" and "b0.mlp.up" in f[0].message


def test_r1_transposed_materialization_also_fires():
    hlo = _mod(PLAIN_HDR, "%wt = f32[160,64] transpose(%p0)")
    f = run_rules(RuleContext(entry="decode", hlo_text=hlo, meta=R1_META),
                  only=["R1"])
    assert len(f) == 1 and f[0].severity == "error"


def test_r1_silent_off_the_deployed_path():
    hlo = _mod(PLAIN_HDR, "%w = bf16[64,160] convert(%p0)")
    meta = dict(R1_META, deployed=False)
    assert not run_rules(RuleContext(entry="decode", hlo_text=hlo,
                                     meta=meta), only=["R1"])


# ---------------------------------------------------------------------------
# R2: no gathered logical K/V view
# ---------------------------------------------------------------------------

R2_META = {"gathered_view_shapes": {(2, 64, 2, 32): "paged K/V view"}}


def test_r2_fires_on_view_shape_any_dtype():
    hlo = _mod(PLAIN_HDR, "%g = bf16[2,64,2,32] transpose(%p0)")
    f = run_rules(RuleContext(entry="decode_paged", hlo_text=hlo,
                              meta=R2_META), only=["R2"])
    assert [x.severity for x in f] == ["error"] and f[0].rule == "R2"


def test_r2_clean_without_view_shape():
    hlo = _mod(PLAIN_HDR, "%g = bf16[2,16,2,32] transpose(%p0)")
    assert not run_rules(RuleContext(entry="decode_paged", hlo_text=hlo,
                                     meta=R2_META), only=["R2"])


# ---------------------------------------------------------------------------
# R3: donation / aliasing
# ---------------------------------------------------------------------------

POOL = {"expect_aliased": 3, "pool_leaf_shapes": {(2, 1, 48, 2, 32)}}
POOL_COPY = "%cp = bf16[2,1,48,2,32] copy(%p0)"


def test_r3_no_alias_is_error_with_pool_copy_site():
    f = run_rules(RuleContext(entry="decode",
                              hlo_text=_mod(PLAIN_HDR, POOL_COPY),
                              meta=POOL), only=["R3"])
    assert [x.severity for x in f] == ["error", "warning"]
    assert "donate_argnums" in f[0].message
    assert f[1].op == "cp"                      # corroborating copy site


def test_r3_partial_alias_is_warning():
    f = run_rules(RuleContext(entry="decode",
                              hlo_text=_mod(ALIAS1_HDR, POOL_COPY),
                              meta=POOL), only=["R3"])
    assert max_severity(f) == "warning"
    assert any("only 1 of 3" in x.message for x in f)


def test_r3_fully_aliased_module_tolerates_pool_shaped_copies():
    # XLA legitimately keeps pool-shaped copies feeding fused in-place
    # updates; with full aliasing the copy scan must not fire
    assert not run_rules(RuleContext(entry="decode",
                                     hlo_text=_mod(ALIAS3_HDR, POOL_COPY),
                                     meta=POOL), only=["R3"])


# ---------------------------------------------------------------------------
# R4: no host transfer / Python callback in the step loop
# ---------------------------------------------------------------------------


def test_r4_flags_callback_custom_call_and_host_ops():
    hlo = _mod(PLAIN_HDR,
               '%cb = f32[4] custom-call(%p0), custom_call_target="xla_python_cpu_callback"',
               "%of = token[] outfeed(%p0)",
               '%ok = f32[4] custom-call(%p0), custom_call_target="__cublas$gemm"')
    f = run_rules(RuleContext(entry="decode", hlo_text=hlo,
                              meta={"step_loop": True}), only=["R4"])
    assert len(f) == 2 and all(x.severity == "error" for x in f)
    assert {x.op for x in f} == {"cb", "of"}


def test_r4_flags_jaxpr_callback_primitive():
    ctx = RuleContext(entry="decode",
                      jaxpr_text="a:f32[4] = pure_callback[callback=...] b",
                      meta={"step_loop": True})
    f = run_rules(ctx, only=["R4"])
    assert len(f) == 1 and "pure_callback" in f[0].message


def test_r4_only_binds_to_step_loop_entries():
    hlo = _mod(PLAIN_HDR, "%of = token[] outfeed(%p0)")
    assert not run_rules(RuleContext(entry="offline", hlo_text=hlo,
                                     meta={"step_loop": False}),
                         only=["R4"])


# ---------------------------------------------------------------------------
# R6: Pallas VMEM budget
# ---------------------------------------------------------------------------


def _vmem_meta(used):
    return {"vmem_limit": 16 * 2**20,
            "vmem_reports": [{"kernel": "nvfp4_gemm", "site": "decode",
                              "grid": (1, 1), "blocks": {},
                              "vmem_bytes": used}]}


def test_r6_over_budget_is_error():
    f = run_rules(RuleContext(entry="decode", meta=_vmem_meta(20 * 2**20)),
                  only=["R6"])
    assert [x.severity for x in f] == ["error"]
    assert "nvfp4_gemm" in f[0].message


def test_r6_under_budget_is_clean():
    assert not run_rules(RuleContext(entry="decode",
                                     meta=_vmem_meta(8 * 2**20)),
                         only=["R6"])


# ---------------------------------------------------------------------------
# R7: collective lint
# ---------------------------------------------------------------------------

COLL_LINE = ("%ar = f32[256] all-reduce(%p0), replica_groups={{0,1}}, "
             "to_apply=%add")


def test_r7_collective_on_single_device_is_error():
    f = run_rules(RuleContext(entry="decode",
                              hlo_text=_mod(PLAIN_HDR, COLL_LINE),
                              meta={"num_devices": 1}), only=["R7"])
    assert [x.severity for x in f] == ["error"]


def test_r7_multi_device_reports_wire_bytes():
    f = run_rules(RuleContext(entry="decode",
                              hlo_text=_mod(PLAIN_HDR, COLL_LINE),
                              meta={"num_devices": 2}), only=["R7"])
    assert [x.severity for x in f] == ["info"]
    assert "all-reduce" in f[0].message


# ---------------------------------------------------------------------------
# Seeded engine-level regressions (fast: unquantized reduced model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=2)
    return cfg, init_params(cfg, KEY), QuantConfig(method="none")


def test_r2_catches_gather_fallback_and_passes_kernel(tiny):
    """The benchmark's old inline regex, now as rule R2: the jnp gather
    fallback materializes the logical K/V view; the Pallas kernel path
    must not."""
    cfg, params, quant = tiny
    gather = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                                max_len=48, attn_kernel=False)
    f = run_rules(build_artifact(gather, "decode_paged",
                                 include_jaxpr=False).context(),
                  only=["R2"])
    assert f and all(x.rule == "R2" and x.severity == "error" for x in f)
    kernel = PagedServingEngine(params, cfg, quant, None, batch_size=2,
                                max_len=48)
    assert not run_rules(build_artifact(kernel, "decode_paged",
                                        include_jaxpr=False).context(),
                         only=["R2"])


def test_r3_catches_lost_donation(tiny):
    """Re-jitting decode without ``donate_argnums`` empties the compiled
    module's alias map — R3 must turn that into an error."""
    cfg, params, quant = tiny
    eng = ServingEngine(params, cfg, quant, None, batch_size=2, max_len=48)
    healthy = build_artifact(eng, "decode", include_jaxpr=False)
    assert not run_rules(healthy.context(), only=["R3"])
    eng.fns = dataclasses.replace(
        eng.fns, decode=jax.jit(eng.fns.decode.__wrapped__))
    f = run_rules(build_artifact(eng, "decode",
                                 include_jaxpr=False).context(),
                  only=["R3"])
    assert any(x.severity == "error" and "donate_argnums" in x.message
               for x in f)


# ---------------------------------------------------------------------------
# Full suite clean on main (slow: the CI lint-hlo matrix as a test)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("paged", [False, True], ids=["slot", "paged"])
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen3-moe-235b-a22b"],
                         ids=["dense", "moe"])
def test_rule_suite_clean_on_main(arch, paged, hlo_lint, assert_no_findings):
    from repro.launch.analyze import build_engine
    engine = build_engine(arch, paged, prefill_chunk=4)
    _, findings = hlo_lint(engine)
    assert_no_findings(findings, max_severity="warning")
