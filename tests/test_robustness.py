"""Request-lifecycle robustness: abort in every phase, deadlines and
queue timeouts, admission backpressure, poisoned-request isolation,
step-failure containment, and the seeded fault-injection sweep.

Structure mirrors the hardening layer in ``serving/core.py``:

  * abort_request at QUEUED / chunked-PREFILL / DECODE / PREEMPTED, on
    the slot, paged, and paged+prefix backends, each followed by the
    pool invariant check and a bit-identical-survivors assertion;
  * the step watchdog (deadline_steps, queue_timeout_steps, preemption
    budget) and its distinct finish reasons;
  * bounded-queue QueueFullError and CapacityError fail-fast;
  * the per-row non-finite-logit guard (real NaN weights through the
    in-jit guard, plus injected row poisons for surgical isolation) and
    whole-step failure containment;
  * ``FaultInjector.random`` crash-consistency sweeps asserting every
    request reaches a terminal state and the page pool stays coherent
    after every tick.

Fast tests drive the unquantized reduced model (as in
``test_engine_api.py``); the heavier randomized sweep runs under the
``slow`` marker.
"""
import copy

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.models import init_params
from repro.serving import (CapacityError, EngineCore, FaultInjector,
                           FinishReason, GenerationRequest, PagedServingEngine,
                           QueueFullError, Request, SamplingParams,
                           ServingEngine)

KEY = jax.random.PRNGKey(0)
MAX_TICKS = 200                     # liveness guard for every drain loop


@pytest.fixture(scope="module")
def tiny():
    cfg = ARCHS["qwen2-1.5b"].reduced(layers=2)
    params = init_params(cfg, KEY)
    quant = QuantConfig(method="none")
    return cfg, params, quant


@pytest.fixture(scope="module")
def slot_engine(tiny):
    cfg, params, quant = tiny
    return ServingEngine(params, cfg, quant, None, batch_size=2, max_len=48)


@pytest.fixture(scope="module")
def paged_engine(tiny):
    cfg, params, quant = tiny
    return PagedServingEngine(params, cfg, quant, None, batch_size=2,
                              max_len=48, block_size=4)


@pytest.fixture(scope="module")
def prefix_engine(tiny):
    cfg, params, quant = tiny
    return PagedServingEngine(params, cfg, quant, None, batch_size=2,
                              max_len=48, block_size=4, prefix_cache=True)


ENGINES = ["slot", "paged", "prefix"]


def _engine(which, slot_engine, paged_engine, prefix_engine):
    return {"slot": slot_engine, "paged": paged_engine,
            "prefix": prefix_engine}[which]


def _req(cfg, seed=0, plen=6, new=6, **sampling):
    rng = np.random.default_rng(seed)
    return GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=new, **sampling))


def _drain(core, max_ticks=MAX_TICKS):
    """Step to completion; returns {rid: [tokens]} and {rid: reason}."""
    toks, reasons = {}, {}
    for _ in range(max_ticks):
        if not core.has_unfinished():
            break
        for ro in core.step().outputs:
            toks.setdefault(ro.request_id, []).extend(ro.new_tokens)
            if ro.finished:
                reasons[ro.request_id] = ro.finish_reason
    assert not core.has_unfinished(), "drain did not terminate"
    return toks, reasons


def _check_pool(core):
    if hasattr(core.pool, "check_invariants"):
        core.pool.check_invariants()
        assert core.pool.pages_in_use == 0   # everything released


# ---------------------------------------------------------------------------
# abort_request: every phase x every backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("which", ENGINES)
def test_abort_queued_request(which, tiny, slot_engine, paged_engine,
                              prefix_engine):
    cfg = tiny[0]
    core = _engine(which, slot_engine, paged_engine, prefix_engine).make_core()
    rids = [core.add_request(_req(cfg, seed=i)) for i in range(3)]
    assert core.abort_request(rids[2]) is True      # still queued (2 slots)
    _, reasons = _drain(core)
    assert reasons[rids[2]] == FinishReason.ABORTED
    assert core.states[rids[2]].out_tokens == []
    assert {reasons[r] for r in rids[:2]} == {FinishReason.LENGTH}
    assert core.stats.aborted == 1
    _check_pool(core)


@pytest.mark.parametrize("which", ENGINES)
def test_abort_mid_chunked_prefill(which, tiny, slot_engine, paged_engine,
                                   prefix_engine):
    cfg = tiny[0]
    eng = _engine(which, slot_engine, paged_engine, prefix_engine)
    core = eng.make_core(prefill_chunk=4)
    victim = core.add_request(_req(cfg, seed=0, plen=14, new=4))
    other = core.add_request(_req(cfg, seed=1, plen=4, new=6))
    core.step()                     # victim is mid chunked prefill
    vslot = core.sched.slot_of(victim)
    assert vslot is not None and vslot.state == "PREFILL"
    assert core.abort_request(victim) is True
    _, reasons = _drain(core)
    assert reasons[victim] == FinishReason.ABORTED
    assert reasons[other] == FinishReason.LENGTH
    _check_pool(core)


@pytest.mark.parametrize("which", ENGINES)
def test_abort_mid_decode_survivors_bit_identical(which, tiny, slot_engine,
                                                  paged_engine, prefix_engine):
    """Aborting one decoding request never perturbs its batch company."""
    cfg = tiny[0]
    eng = _engine(which, slot_engine, paged_engine, prefix_engine)
    reqs = [_req(cfg, seed=i, new=8, temperature=0.7) for i in range(2)]

    base = eng.make_core()
    for i, r in enumerate(reqs):
        base.add_request(copy.deepcopy(r))
    base_toks, _ = _drain(base)

    core = eng.make_core()
    rids = [core.add_request(copy.deepcopy(r)) for r in reqs]
    toks = {}
    for ro in core.step().outputs:  # both prefilled + first decode
        toks.setdefault(ro.request_id, []).extend(ro.new_tokens)
    assert core.sched.slot_of(rids[0]).state == "DECODE"
    assert core.abort_request(rids[0]) is True
    more, reasons = _drain(core)
    for rid, t in more.items():
        toks.setdefault(rid, []).extend(t)
    assert reasons[rids[0]] == FinishReason.ABORTED
    # the survivor's full trace matches the abort-free run exactly
    assert toks[rids[1]] == base_toks[rids[1]]
    # the aborted request keeps the tokens it produced before the abort
    assert core.states[rids[0]].out_tokens == \
        base_toks[rids[0]][: len(core.states[rids[0]].out_tokens)]
    _check_pool(core)


def test_abort_preempted_request(tiny, paged_engine):
    """Abort a request that sits requeued after a mid-flight eviction."""
    cfg = tiny[0]
    inj = FaultInjector().alloc_fault_at(2)
    core = paged_engine.make_core(faults=inj)
    rids = [core.add_request(_req(cfg, seed=i, new=10)) for i in range(2)]
    for _ in range(MAX_TICKS):      # run until the injected eviction lands
        core.step()
        if any(core.states[r].preemptions for r in rids):
            break
    evicted = next(r for r in rids if core.states[r].preemptions)
    assert core.states[evicted] in core.sched.queue
    assert core.abort_request(evicted) is True
    _, reasons = _drain(core)
    assert reasons[evicted] == FinishReason.ABORTED
    assert core.states[evicted].finish_reason == FinishReason.ABORTED
    _check_pool(core)


def test_abort_unknown_and_finished(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    rid = core.add_request(_req(cfg, new=2))
    with pytest.raises(KeyError):
        core.abort_request(rid + 999)
    _drain(core)
    assert core.abort_request(rid) is False         # already finished: no-op
    assert core.states[rid].finish_reason == FinishReason.LENGTH


def test_abort_shared_prefix_keeps_sharers_pages(tiny, prefix_engine):
    """Aborting one sharer of a cached prefix must not free pages the
    other sharer still reads (ref counting, not ownership)."""
    cfg = tiny[0]
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    core = prefix_engine.make_core()
    rids = [core.add_request(GenerationRequest(
        prompt=np.concatenate([shared, rng.integers(
            0, cfg.vocab_size, 3).astype(np.int32)]),
        sampling=SamplingParams(max_new_tokens=8))) for _ in range(2)]
    core.step()
    assert core.stats.cached_prefix_tokens > 0      # the share happened
    assert core.abort_request(rids[0]) is True
    core.pool.check_invariants()
    _, reasons = _drain(core)
    assert reasons[rids[1]] == FinishReason.LENGTH
    assert len(core.states[rids[1]].out_tokens) == 8
    _check_pool(core)


# ---------------------------------------------------------------------------
# deadlines / queue timeout / preemption budget (the step watchdog)
# ---------------------------------------------------------------------------


def test_deadline_expires_resident_request(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    rid = core.add_request(_req(cfg, new=30, deadline_steps=3))
    ok = core.add_request(_req(cfg, seed=1, new=5))
    _, reasons = _drain(core)
    assert reasons[rid] == FinishReason.DEADLINE
    st = core.states[rid]
    assert 0 < len(st.out_tokens) < 30              # partial output kept
    assert st.latency_steps <= 4
    assert reasons[ok] == FinishReason.LENGTH
    assert core.stats.expired == 1


def test_queue_timeout_never_admitted(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    # both slots busy for many ticks; the third request cannot wait
    blockers = [core.add_request(_req(cfg, seed=i, new=20)) for i in range(2)]
    core.step()
    late = core.add_request(_req(cfg, seed=5, new=4, queue_timeout_steps=2))
    _, reasons = _drain(core)
    assert reasons[late] == FinishReason.QUEUE_TIMEOUT
    assert core.states[late].out_tokens == []
    assert core.states[late].admit_step < 0         # truly never admitted
    assert all(reasons[b] == FinishReason.LENGTH for b in blockers)


def test_deadline_expires_queued_request(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    for i in range(2):
        core.add_request(_req(cfg, seed=i, new=20))
    core.step()
    late = core.add_request(_req(cfg, seed=5, new=4, deadline_steps=3))
    _, reasons = _drain(core)
    assert reasons[late] == FinishReason.DEADLINE


def test_preemption_budget_breaks_livelock(tiny, paged_engine):
    """After the retry budget, a thrashing request fails CAPACITY fast."""
    cfg = tiny[0]
    inj = FaultInjector().alloc_fault_at(2)
    eng = paged_engine
    core = EngineCore(eng.fns, eng.qparams, eng.cfg,
                      cache_backend=eng.cache_backend, num_slots=2,
                      max_len=48, max_preemptions=0, faults=inj)
    rids = [core.add_request(_req(cfg, seed=i, new=10)) for i in range(2)]
    _, reasons = _drain(core)
    capped = [r for r in rids if reasons[r] == FinishReason.CAPACITY]
    assert len(capped) == 1                         # the evicted one
    assert "budget" in core.states[capped[0]].error
    survivor = next(r for r in rids if r not in capped)
    assert reasons[survivor] == FinishReason.LENGTH
    assert core.stats.expired == 1
    _check_pool(core)


# ---------------------------------------------------------------------------
# admission backpressure
# ---------------------------------------------------------------------------


def test_bounded_queue_rejects_with_queue_full(tiny, slot_engine):
    cfg = tiny[0]
    eng = slot_engine
    core = EngineCore(eng.fns, eng.qparams, eng.cfg,
                      cache_backend=eng.cache_backend, num_slots=2,
                      max_len=48, max_queue=2)
    for i in range(2):
        core.add_request(_req(cfg, seed=i))
    with pytest.raises(QueueFullError):
        core.add_request(_req(cfg, seed=9))
    assert core.stats.rejected == 1
    assert len(core.states) == 2                    # nothing half-enqueued
    _, reasons = _drain(core)
    assert all(r == FinishReason.LENGTH for r in reasons.values())


def test_capacity_fail_fast_slot_and_paged(tiny, slot_engine, paged_engine):
    cfg = tiny[0]
    for eng in (slot_engine, paged_engine):
        core = eng.make_core()
        with pytest.raises(CapacityError):
            core.add_request(_req(cfg, plen=40, new=40))    # > max_len 48
        assert core.stats.rejected == 1
        assert not core.has_unfinished()            # nothing enqueued
    # CapacityError subclasses ValueError: legacy handlers keep working
    assert issubclass(CapacityError, ValueError)


def test_duplicate_request_id_rejected(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    core.add_request(GenerationRequest(prompt=np.arange(4, dtype=np.int32),
                                       request_id=7))
    with pytest.raises(ValueError, match="duplicate"):
        core.add_request(GenerationRequest(
            prompt=np.arange(4, dtype=np.int32), request_id=7))


# ---------------------------------------------------------------------------
# poisoned-request isolation
# ---------------------------------------------------------------------------


def test_real_nan_weights_finish_error_not_crash(tiny):
    """NaN model weights drive the *in-jit* guard: requests finish with
    ERROR instead of silently emitting garbage or crashing the engine."""
    cfg, params, quant = tiny
    bad = jax.tree_util.tree_map(lambda x: np.full_like(x, np.nan), params)
    eng = ServingEngine(bad, cfg, quant, None, batch_size=2, max_len=48)
    core = eng.make_core()
    rids = [core.add_request(_req(cfg, seed=i, new=4)) for i in range(2)]
    _, reasons = _drain(core)
    assert all(reasons[r] == FinishReason.ERROR for r in rids)
    assert all("non-finite" in core.states[r].error for r in rids)
    assert core.stats.nan_isolated == 2


def test_nan_guard_off_skips_detection(tiny):
    cfg, params, quant = tiny
    bad = jax.tree_util.tree_map(lambda x: np.full_like(x, np.nan), params)
    eng = ServingEngine(bad, cfg, quant, None, batch_size=2, max_len=48,
                        nan_guard=False)
    core = eng.make_core()
    rid = core.add_request(_req(cfg, new=3))
    _, reasons = _drain(core)
    assert reasons[rid] == FinishReason.LENGTH      # garbage, but unflagged
    assert core.stats.nan_isolated == 0


@pytest.mark.parametrize("which", ENGINES)
def test_injected_decode_poison_isolates_one_row(which, tiny, slot_engine,
                                                 paged_engine, prefix_engine):
    """Only the poisoned row finishes ERROR; the other row of the same
    decode launch keeps its token, bit-identical to a fault-free run."""
    cfg = tiny[0]
    eng = _engine(which, slot_engine, paged_engine, prefix_engine)
    reqs = [_req(cfg, seed=i, new=8) for i in range(2)]

    base = eng.make_core()
    for r in reqs:
        base.add_request(copy.deepcopy(r))
    base_toks, _ = _drain(base)

    inj = FaultInjector().nan_at(2, 0)
    core = eng.make_core(faults=inj)
    rids = [core.add_request(copy.deepcopy(r)) for r in reqs]
    toks, reasons = _drain(core)
    assert reasons[rids[0]] == FinishReason.ERROR
    assert core.states[rids[0]].error == "non-finite logits at decode"
    assert reasons[rids[1]] == FinishReason.LENGTH
    assert toks[rids[1]] == base_toks[rids[1]]      # survivor untouched
    assert toks[rids[0]] == base_toks[rids[0]][: len(toks[rids[0]])]
    assert core.stats.nan_isolated == 1
    assert inj.log and inj.log[0]["kind"] == "nan"
    _check_pool(core)


def test_injected_prefill_poison(tiny, paged_engine):
    cfg = tiny[0]
    inj = FaultInjector().nan_at(0, 0)
    core = paged_engine.make_core(faults=inj)
    rid = core.add_request(_req(cfg, new=6))
    _, reasons = _drain(core)
    assert reasons[rid] == FinishReason.ERROR
    assert core.states[rid].error == "non-finite logits at prefill"
    assert core.states[rid].out_tokens == []
    _check_pool(core)


# ---------------------------------------------------------------------------
# step-failure containment
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("which", ["slot", "paged"])
def test_step_error_contained_to_batch(which, tiny, slot_engine,
                                       paged_engine, prefix_engine):
    cfg = tiny[0]
    eng = _engine(which, slot_engine, paged_engine, prefix_engine)
    inj = FaultInjector().step_error_at(2)
    core = eng.make_core(faults=inj)
    doomed = [core.add_request(_req(cfg, seed=i, new=10)) for i in range(2)]
    queued = core.add_request(_req(cfg, seed=9, new=3))
    _, reasons = _drain(core)
    for r in doomed:
        assert reasons[r] == FinishReason.ERROR
        assert "decode step failed" in core.states[r].error
        assert "injected backend step failure" in core.states[r].error
    # the engine survives: the queued request runs to completion after
    assert reasons[queued] == FinishReason.LENGTH
    assert core.stats.step_failures == 1
    _check_pool(core)


def test_alloc_fault_parity_with_fault_free_run(tiny, paged_engine):
    """Injected page-allocation failures drive real preemption + exact
    recompute: final greedy tokens match the fault-free run."""
    cfg = tiny[0]
    reqs = [_req(cfg, seed=i, new=8) for i in range(3)]
    base = paged_engine.make_core()
    for r in reqs:
        base.add_request(copy.deepcopy(r))
    base_toks, _ = _drain(base)

    inj = FaultInjector().alloc_fault_at(2).alloc_fault_at(4)
    core = paged_engine.make_core(faults=inj)
    rids = [core.add_request(copy.deepcopy(r)) for r in reqs]
    toks, reasons = _drain(core)
    assert core.stats.preemptions > 0               # the faults really bit
    assert all(reasons[r] == FinishReason.LENGTH for r in rids)
    assert toks == base_toks                        # exact-recompute resume
    assert core.stats.preemption_retries > 0
    _check_pool(core)


# ---------------------------------------------------------------------------
# hardened bookkeeping APIs
# ---------------------------------------------------------------------------


def test_pop_request_guards(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    rid = core.add_request(_req(cfg, new=2))
    with pytest.raises(KeyError, match="unknown request id"):
        core.pop_request(rid + 1)
    with pytest.raises(ValueError, match="in flight"):
        core.pop_request(rid)
    _drain(core)
    st = core.pop_request(rid)
    assert st.done and st.rid == rid
    with pytest.raises(KeyError):                   # second pop
        core.pop_request(rid)


def test_scheduler_free_and_remove_guards(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    rid = core.add_request(_req(cfg, new=4))
    core.step()
    slot = core.sched.slot_of(rid)
    with pytest.raises(RuntimeError, match="only\\s+DONE"):
        core.sched.free(slot)                       # in-flight: refuse
    with pytest.raises(KeyError):
        core.sched.remove_queued(core.states[rid])  # resident, not queued
    _drain(core)


def test_stats_summary_exports_robustness_counters(tiny, slot_engine):
    cfg = tiny[0]
    core = slot_engine.make_core()
    core.add_request(_req(cfg, new=2))
    _drain(core)
    s = core.stats.summary()
    for k in ("aborted", "expired", "rejected", "nan_isolated",
              "preemption_retries", "step_failures"):
        assert s[k] == 0


def test_finish_reason_strings_stay_compatible():
    assert FinishReason.EOS == "eos"
    assert FinishReason.LENGTH in ("length", "eos")
    assert str(FinishReason.ABORTED) == "aborted"
    assert FinishReason("deadline") is FinishReason.DEADLINE


def test_run_absorbs_error_and_reason(tiny, slot_engine):
    """The legacy run() wrapper surfaces the new fields on Request."""
    cfg = tiny[0]
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=30, deadline_steps=3),
            Request(prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=4)]
    out = slot_engine.run(reqs)
    assert out[0].finish_reason == FinishReason.DEADLINE
    assert out[0].done and 0 < len(out[0].out_tokens) < 30
    assert out[1].finish_reason == FinishReason.LENGTH
    assert out[1].error is None


# ---------------------------------------------------------------------------
# seeded randomized crash-consistency sweep
# ---------------------------------------------------------------------------

TERMINAL = {FinishReason.LENGTH, FinishReason.EOS, FinishReason.ERROR,
            FinishReason.CAPACITY, FinishReason.DEADLINE,
            FinishReason.QUEUE_TIMEOUT, FinishReason.ABORTED}


def _sweep(eng, cfg, seed, n_requests=5, ticks=30, deadline=60):
    inj = FaultInjector.random(seed, ticks=ticks,
                               rids=list(range(n_requests)),
                               p_alloc=0.15, p_nan=0.06, p_step_error=0.04)
    core = eng.make_core(faults=inj)
    rng = np.random.default_rng(seed)
    rids = [core.add_request(GenerationRequest(
        prompt=rng.integers(0, cfg.vocab_size,
                            int(rng.integers(3, 12))).astype(np.int32),
        sampling=SamplingParams(max_new_tokens=int(rng.integers(2, 9)),
                                deadline_steps=deadline)))
        for _ in range(n_requests)]
    for _ in range(MAX_TICKS):
        if not core.has_unfinished():
            break
        core.step()
        if hasattr(core.pool, "check_invariants"):
            core.pool.check_invariants()            # coherent after EVERY tick
    assert not core.has_unfinished()
    for r in rids:
        st = core.states[r]
        assert st.done and st.finish_reason in TERMINAL, \
            f"seed {seed} rid {r}: {st.finish_reason}"
    _check_pool(core)
    return core


@pytest.mark.parametrize("seed", range(3))
def test_crash_consistency_sweep_fast(seed, tiny, prefix_engine):
    """Randomized faults against the prefix-sharing paged pool — the
    most invariant-rich configuration — must leave every request
    terminal and the pool partition-coherent at every tick."""
    cfg = tiny[0]
    core = _sweep(prefix_engine, cfg, seed)
    assert sum(v["tick"] >= 0 for v in core.faults.log) == len(core.faults.log)


@pytest.mark.slow
@pytest.mark.parametrize("which", ENGINES)
@pytest.mark.parametrize("seed", range(6))
def test_crash_consistency_sweep_heavy(which, seed, tiny, slot_engine,
                                       paged_engine, prefix_engine):
    cfg = tiny[0]
    eng = _engine(which, slot_engine, paged_engine, prefix_engine)
    _sweep(eng, cfg, 100 + seed, n_requests=8, ticks=50)


def test_sweep_is_deterministic(tiny, prefix_engine):
    """Same seed, same workload -> bit-identical outputs and fault log."""
    cfg = tiny[0]
    a = _sweep(prefix_engine, cfg, 1234)
    b = _sweep(prefix_engine, cfg, 1234)
    assert a.faults.log == b.faults.log
    assert {r: s.out_tokens for r, s in a.states.items()} == \
        {r: s.out_tokens for r, s in b.states.items()}
    assert {r: s.finish_reason for r, s in a.states.items()} == \
        {r: s.finish_reason for r, s in b.states.items()}
