"""Fault tolerance: injected failure -> rollback+resume; NaN quarantine;
data-stream cursor restoration (distributed/fault_tolerance.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.distributed import FaultTolerantRunner, Preemption, RunnerConfig


def toy_step(params, opt_state, batch):
    """Deterministic toy train step: params counts batches seen."""
    s = float(np.asarray(batch["tokens"]).sum())
    params = {"w": params["w"] + 1}
    opt_state = {"n": opt_state["n"] + 1}
    return params, opt_state, {"loss": 1.0 / (1 + float(params["w"]))}


def make(tmp_path, max_steps=12, interval=4):
    mgr = CheckpointManager(tmp_path, interval=interval)
    runner = FaultTolerantRunner(mgr, RunnerConfig(
        max_steps=max_steps, checkpoint_interval=interval))
    stream = TokenStream(vocab_size=100, seed=0)
    it = stream.batches(2, 8)

    def batch_fn(stream):
        return {"tokens": next(it)}

    return mgr, runner, stream, batch_fn


def test_runs_to_completion(tmp_path):
    mgr, runner, stream, batch_fn = make(tmp_path)
    out = runner.run(toy_step, {"w": 0}, {"n": 0}, stream, batch_fn)
    assert out["final_step"] == 12
    assert len(out["losses"]) == 12


def test_injected_failure_recovers(tmp_path):
    mgr, runner, stream, batch_fn = make(tmp_path)
    out = runner.run(toy_step, {"w": 0}, {"n": 0}, stream, batch_fn,
                     inject_failure_at=6)
    assert out["final_step"] == 12
    kinds = [e["kind"] for e in out["events"]]
    assert "failure" in kinds
    # rolled back to step 4 checkpoint and re-ran 4..12
    assert int(out["params"]["w"]) == 12


def test_failure_before_any_checkpoint_raises(tmp_path):
    mgr, runner, stream, batch_fn = make(tmp_path)
    with pytest.raises(RuntimeError):
        runner.run(toy_step, {"w": 0}, {"n": 0}, stream, batch_fn,
                   inject_failure_at=1)


def test_nan_rollback_and_skip(tmp_path):
    mgr, runner, stream, batch_fn = make(tmp_path)
    calls = {"n": 0}

    def nan_step(params, opt_state, batch):
        calls["n"] += 1
        params, opt_state, m = toy_step(params, opt_state, batch)
        if calls["n"] == 6:
            m = {"loss": float("nan")}
        return params, opt_state, m

    out = runner.run(nan_step, {"w": 0}, {"n": 0}, stream, batch_fn)
    assert out["final_step"] == 12
    assert "nan" in [e["kind"] for e in out["events"]]


def test_resume_from_checkpoint(tmp_path):
    """Simulates a process restart: second runner picks up at the last step."""
    mgr, runner, stream, batch_fn = make(tmp_path, max_steps=8)
    runner.run(toy_step, {"w": 0}, {"n": 0}, stream, batch_fn)
    # "restart": fresh runner, same dir, more steps
    mgr2 = CheckpointManager(tmp_path, interval=4)
    runner2 = FaultTolerantRunner(mgr2, RunnerConfig(max_steps=12,
                                                     checkpoint_interval=4))
    stream2 = TokenStream(vocab_size=100, seed=0)
    it2 = stream2.batches(2, 8)
    out = runner2.run(toy_step, {"w": 0}, {"n": 0}, stream2,
                      lambda s: {"tokens": next(it2)})
    assert out["events"][0] == {"kind": "resume", "step": 8}
    assert out["final_step"] == 12
    assert int(out["params"]["w"]) == 12


def test_preemption_saves_and_raises(tmp_path):
    mgr, runner, stream, batch_fn = make(tmp_path, max_steps=100)
    orig = toy_step

    def step(params, opt_state, batch):
        p, o, m = orig(params, opt_state, batch)
        if int(p["w"]) == 5:
            runner.preempted = True     # simulate SIGTERM arrival
        return p, o, m

    with pytest.raises(Preemption):
        runner.run(step, {"w": 0}, {"n": 0}, stream, batch_fn)
    assert mgr.latest_step() == 5       # out-of-cadence preemption save


def test_straggler_watchdog():
    mgr = CheckpointManager("/tmp/unused_watchdog", interval=1000)
    runner = FaultTolerantRunner(mgr, RunnerConfig())
    for _ in range(10):
        runner.record_step_time(0.1)
    warn = runner.record_step_time(1.0)
    assert warn is not None and "straggler" in warn
