"""Lower + compile one (arch x shape) cell on the production mesh and print
its roofline terms — a single-cell version of the multi-pod dry-run.

    PYTHONPATH=src python examples/multi_arch_dryrun.py \
        --arch rwkv6-3b --shape decode_32k --multipod
"""
import argparse
import json
import tempfile
from pathlib import Path

# NOTE: repro.launch.dryrun sets XLA_FLAGS for 512 host devices on import —
# it must be imported before anything touches jax.
from repro.launch import dryrun


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()

    out = Path(tempfile.mkdtemp())
    res = dryrun.run_cell(args.arch, args.shape, args.multipod, out)
    print(json.dumps({k: v for k, v in res.items() if k != "trace"},
                     indent=1))
    if res["status"] == "ok":
        from benchmarks.roofline import analyze_cell
        r = analyze_cell(out / f"{res['cell']}.json")
        print(f"\nroofline: compute={r['t_compute']:.4f}s "
              f"memory={r['t_memory']:.4f}s collective={r['t_collective']:.4f}s"
              f"\ndominant={r['dominant']}  MFU={r['roofline_fraction']:.1%}"
              f"\n-> {r['recommendation']}")


if __name__ == "__main__":
    main()
