"""Quickstart: ARCQuant on a single linear layer in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's core mechanism end to end:
  1. calibrate channel stats, pick outliers (tau = 2^-3 * M rule)
  2. augment weights offline (reorder + quantize + duplicate outlier cols)
  3. one unified NVFP4 GEMM over K+S computes main product + compensation
"""
import jax.numpy as jnp
import numpy as np

from repro.core import arc, baselines, quant

rng = np.random.default_rng(0)

# activations with outlier channels (the LLM regime, paper Fig. 2)
X = rng.normal(size=(128, 1024)).astype(np.float32)
X[:, rng.choice(1024, 8, replace=False)] *= 40.0
W = rng.normal(size=(512, 1024)).astype(np.float32)
Y_ref = X @ W.T

# 1. offline: calibration -> plan
plan = arc.select_outliers(np.abs(X).max(axis=0), fmt="nvfp4")
print(f"layer max M={plan.layer_max:.1f}, tau=M/8, S={plan.s} augmented channels")

# 2. offline: weight augmentation  Q_W_aug = [Q_W | Q_W_o]
W_aug = arc.augment_weights(jnp.asarray(W), plan)
print(f"weight: (512, 1024) -> augmented {W_aug.shape}, "
      f"{W_aug.bits_per_value():.1f} bits/value")

# 3. online: one GEMM over the extended reduction dimension (paper Eq. 2)
Y_arc = np.asarray(arc.arc_matmul(jnp.asarray(X), W_aug, plan))
Y_rtn = np.asarray(baselines.rtn_matmul(jnp.asarray(X), jnp.asarray(W)))
Y_w4a8 = np.asarray(baselines.w4a8_matmul(jnp.asarray(X), jnp.asarray(W)))

for name, Y in [("NVFP4 RTN (W4A4)", Y_rtn), ("ARCQuant (W4A4)", Y_arc),
                ("MXFP8 act (W4A8)", Y_w4a8)]:
    mse = np.mean((Y - Y_ref) ** 2)
    print(f"{name:20s} MSE vs FP32: {mse:10.4f}")

print("\nARCQuant reaches W4A8-level error within strict W4A4 — the paper's claim.")
