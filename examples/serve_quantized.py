"""Serve a small model with batched requests through the ARCQuant engine.

    PYTHONPATH=src python examples/serve_quantized.py --arch qwen2-1.5b

Pipeline (paper Fig. 5): calibrate -> offline weight quantization (packed
NVFP4, ARC-augmented along K) -> batched prefill -> decode loop where every
linear runs online activation quantization + the unified K+S GEMM.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.launch.serve import calibrate_and_quantize
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--method", default="arc")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, quant, plans = calibrate_and_quantize(params, cfg, args.method)

    import jax.numpy as jnp
    orig = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    packed = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(qparams))
    print(f"weights: {orig/1e6:.1f}MB fp32 -> {packed/1e6:.1f}MB packed NVFP4 "
          f"({orig/packed:.1f}x)")

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    engine = ServingEngine(qparams, cfg, quant, plans, batch_size=2,
                           max_len=12 + args.new_tokens + 1)
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    n = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests / {n} tokens in {dt:.1f}s")
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: prompt[:4]={r.prompt[:4].tolist()} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
