"""Serve a small model through the step-driven ARCQuant serving core.

    PYTHONPATH=src python examples/serve_quantized.py --arch qwen2-1.5b
    PYTHONPATH=src python examples/serve_quantized.py --backend pallas

Pipeline (paper Fig. 5): calibrate -> offline weight quantization (packed
NVFP4, ARC-augmented along K, interleaved channel layout) -> per-request
prefill into a free cache slot -> batched decode loop where every linear
runs online activation quantization + the unified K+S GEMM.

This example drives the step-driven ``EngineCore`` directly to show the
serving API end to end:

  * tokens print per tick as the core emits them (streaming deltas);
  * a new request is submitted *mid-flight* (``add_request`` between
    ticks) and picks up a freed slot without waiting for the batch;
  * ``--prefill-chunk`` feeds long prompts in fixed-size slices across
    ticks so their prefill never stalls in-flight decodes;
  * ``--prefix-cache`` (implies ``--paged``) serves a shared-system-
    prompt workload through the content-addressed page pool: every
    request after the first finds the system prompt's pages in the
    prefix cache and skips their prefill entirely (per-request
    ``cached_prefix_tokens`` shows the hit; the mid-flight request hits
    it too).

``--backend pallas`` serves through the fused kernel pipeline: each
deployed linear is one ``arc_fused_quantize`` launch (RMSNorm + reorder +
primary + residual quantization over every active slot at once) feeding
one ``nvfp4_gemm`` over the packed 4-bit weights — the paper's deployment
dataflow. On this CPU example it runs in interpret mode (bit-faithful,
slow); on a TPU drop ``interpret`` for the compiled kernels. Greedy
outputs are identical to ``--backend reference``.
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCHS
from repro.launch.serve import calibrate_and_quantize
from repro.models import init_params
from repro.serving import (GenerationRequest, PagedServingEngine,
                           SamplingParams, ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--method", default="arc")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV cache pool (block "
                         "tables + on-demand page allocation) instead of "
                         "per-slot max_len rows")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed paged pool (implies --paged): "
                         "requests sharing a prompt prefix reuse its pages")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunked prefill slice size (0 = one-shot)")
    args = ap.parse_args()
    if args.prefix_cache:
        args.paged = True
    if args.new_tokens < 1:
        ap.error("--new-tokens must be >= 1 (prefill samples the first token)")

    cfg = ARCHS[args.arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, quant, plans = calibrate_and_quantize(params, cfg, args.method)

    orig = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    packed = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(qparams))
    print(f"weights: {orig/1e6:.1f}MB fp32 -> {packed/1e6:.1f}MB packed NVFP4 "
          f"({orig/packed:.1f}x)")

    # mixed-length workload, salted with one long prompt so chunked
    # prefill has a stall to remove; with --prefix-cache every prompt
    # additionally starts with one shared system prompt whose pages the
    # content-addressed pool serves from cache after the first request
    rng = np.random.default_rng(0)
    lo = min(2, args.new_tokens)
    sys_prompt = (rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
                  if args.prefix_cache else np.zeros((0,), np.int32))

    def make_request(plen):
        return GenerationRequest(
            prompt=np.concatenate([
                sys_prompt,
                rng.integers(0, cfg.vocab_size, plen).astype(np.int32)]),
            sampling=SamplingParams(
                max_new_tokens=int(rng.integers(lo, args.new_tokens + 1)),
                temperature=args.temperature))

    long_prompt = 24
    cls = PagedServingEngine if args.paged else ServingEngine
    kw = {"prefix_cache": True} if args.prefix_cache else {}
    engine = cls(qparams, cfg, quant, plans, batch_size=2,
                 max_len=len(sys_prompt) + long_prompt + args.new_tokens + 1,
                 backend=args.backend,
                 interpret=(args.backend == "pallas"
                            and jax.default_backend() == "cpu"),
                 prefill_chunk=args.prefill_chunk or None, **kw)

    core = engine.make_core()
    for _ in range(args.requests - 2):
        core.add_request(make_request(int(rng.integers(4, 13))))
    core.add_request(make_request(long_prompt))     # exercises chunking

    late_id = None
    while core.has_unfinished():
        out = core.step()
        for ro in out.outputs:
            tag = f" [{ro.finish_reason}]" if ro.finished else ""
            late = " (mid-flight)" if ro.request_id == late_id else ""
            print(f"tick {out.step:3d}  req{ro.request_id}{late}: "
                  f"+{ro.new_tokens} ({ro.num_generated} total){tag}")
        if late_id is None and (out.step >= 2 or not core.has_unfinished()):
            # a request arriving while others are mid-generation: it
            # queues now and takes over the first slot that frees up
            # (submitted no later than the drain, so it always runs)
            late_id = core.add_request(make_request(6))
            print(f"tick {out.step:3d}  >>> add_request(req{late_id}) "
                  f"submitted mid-flight")

    s = core.stats
    print(f"\nbackend={args.backend}: "
          f"served {len(core.states)} requests / {s.generated_tokens} tokens "
          f"in {s.wall_seconds:.1f}s across {s.decode_steps} decode steps "
          f"(padding waste {100 * s.padding_waste:.1f}%, worst-tick prefill "
          f"{s.max_prefill_tokens_per_step} tokens)")
    if args.paged:
        print(f"  page pool: {s.num_pages} pages, peak {s.peak_pages}, "
              f"mean utilization {100 * s.page_utilization:.1f}%, "
              f"{s.preemptions} preemptions")
    if args.prefix_cache:
        print(f"  prefix cache: {s.cached_prefix_tokens} prefill tokens "
              f"served from shared pages, {s.prefill_tokens} computed")
    for rid, st in sorted(core.states.items())[:4]:
        cached = (f" cached={st.cached_prefix_tokens}"
                  if args.prefix_cache else "")
        print(f"  req{rid}: prompt_len={st.prompt_len}{cached} "
              f"admitted@{st.admit_step} ttft={st.ttft_steps} "
              f"-> {st.out_tokens}")


if __name__ == "__main__":
    main()
