"""Serve a small model through the continuous-batching ARCQuant engine.

    PYTHONPATH=src python examples/serve_quantized.py --arch qwen2-1.5b
    PYTHONPATH=src python examples/serve_quantized.py --backend pallas

Pipeline (paper Fig. 5): calibrate -> offline weight quantization (packed
NVFP4, ARC-augmented along K, interleaved channel layout) -> per-request
prefill into a free cache slot -> batched decode loop where every linear
runs online activation quantization + the unified K+S GEMM. Finished
requests free their slot between decode steps and the scheduler admits
the next queued request into the row, so mixed-length workloads don't pay
padding waste.

``--backend pallas`` serves through the fused kernel pipeline: each
deployed linear is one ``arc_fused_quantize`` launch (RMSNorm + reorder +
primary + residual quantization over every active slot at once) feeding
one ``nvfp4_gemm`` over the packed 4-bit weights — the paper's deployment
dataflow. On this CPU example it runs in interpret mode (bit-faithful,
slow); on a TPU drop ``interpret`` for the compiled kernels. Greedy
outputs are identical to ``--backend reference``.
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCHS
from repro.launch.serve import calibrate_and_quantize
from repro.models import init_params
from repro.serving import PagedServingEngine, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--method", default="arc")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV cache pool (block "
                         "tables + on-demand page allocation) instead of "
                         "per-slot max_len rows")
    args = ap.parse_args()
    if args.new_tokens < 1:
        ap.error("--new-tokens must be >= 1 (prefill samples the first token)")

    cfg = ARCHS[args.arch].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    qparams, quant, plans = calibrate_and_quantize(params, cfg, args.method)

    orig = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    packed = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(qparams))
    print(f"weights: {orig/1e6:.1f}MB fp32 -> {packed/1e6:.1f}MB packed NVFP4 "
          f"({orig/packed:.1f}x)")

    # mixed-length workload: this is where continuous batching pays off
    rng = np.random.default_rng(0)
    lo = min(2, args.new_tokens)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 13))).astype(np.int32),
                    max_new_tokens=int(rng.integers(lo, args.new_tokens + 1)),
                    temperature=args.temperature)
            for _ in range(args.requests)]
    cls = PagedServingEngine if args.paged else ServingEngine
    engine = cls(qparams, cfg, quant, plans, batch_size=2,
                 max_len=12 + args.new_tokens + 1,
                 backend=args.backend,
                 interpret=(args.backend == "pallas"
                            and jax.default_backend() == "cpu"))
    engine.run(reqs)
    s = engine.last_stats
    print(f"backend={args.backend}: "
          f"served {len(reqs)} requests / {s.generated_tokens} tokens in "
          f"{s.wall_seconds:.1f}s across {s.decode_steps} decode steps "
          f"(padding waste {100 * s.padding_waste:.1f}%)")
    if args.paged:
        print(f"  page pool: {s.num_pages} pages, peak {s.peak_pages}, "
              f"mean utilization {100 * s.page_utilization:.1f}%, "
              f"{s.preemptions} preemptions")
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: prompt[:4]={r.prompt[:4].tolist()} "
              f"admitted@{r.admit_step} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
