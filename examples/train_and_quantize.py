"""End-to-end driver: train a ~100M-param LM for a few hundred steps, then
post-training-quantize it with every strategy and compare perplexity
(the paper's Table 1/2 workflow at laptop scale).

    PYTHONPATH=src python examples/train_and_quantize.py \
        --steps 200 --d-model 512 --layers 4

Defaults are sized for CI (much smaller); pass the flags above for the
full ~100M run.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS
from repro.configs.base import QuantConfig
from repro.data import SyntheticLM, make_calibration_set
from repro.distributed import FaultTolerantRunner, RunnerConfig
from repro.launch.steps import make_train_step
from repro.models import capture_stats, init_params, next_token_loss
from repro.optim import adamw_init
from repro.quant import make_plan_bundle, plan_summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/arcquant_example")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        ARCHS["llama31-8b"].reduced(layers=args.layers),
        d_model=args.d_model, d_ff=args.d_model * 3,
        num_heads=max(4, args.d_model // 64), num_kv_heads=2,
        head_dim=64, vocab_size=4096)
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"~{cfg.param_count()/1e6:.1f}M params")

    # --- train (fault-tolerant loop: checkpoints + resume) ---------------
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, base_lr=3e-3, warmup=10,
                                   total=args.steps, remat=False),
                   donate_argnums=(0, 1))
    data = SyntheticLM(cfg.vocab_size, 0)
    stream = data.train_stream()
    it = stream.batches(args.batch, args.seq)

    def batch_fn(stream):
        toks = next(it)
        pos = np.broadcast_to(np.arange(args.seq),
                              (args.batch, args.seq)).astype(np.int32)
        return {"tokens": jnp.asarray(toks), "positions": jnp.asarray(pos)}

    runner = FaultTolerantRunner(
        CheckpointManager(args.ckpt_dir, interval=max(args.steps // 4, 10)),
        RunnerConfig(max_steps=args.steps))
    t0 = time.time()
    out = runner.run(lambda p, o, b: step(p, o, b), params, opt, stream,
                     batch_fn)
    params = out["params"]
    print(f"trained {out['final_step']} steps in {time.time()-t0:.0f}s; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

    # --- calibrate (paper App. B: WikiText2-style segments) --------------
    calib = make_calibration_set(cfg.vocab_size, 8, args.seq)
    stats = None
    for toks in calib.batches:
        s = capture_stats(params, cfg, tokens=jnp.asarray(toks))
        stats = ({k: np.array(v) for k, v in s.items()} if stats is None
                 else {k: np.maximum(stats[k], np.asarray(v)) for k, v in s.items()})

    # --- PTQ comparison (Table 2) ----------------------------------------
    eval_toks = jnp.asarray(data.eval_batches(args.batch, args.seq, 2)[0])
    print(f"\n{'method':12s} {'PPL':>9s}")
    for method in ["none", "rtn", "smooth", "quarot", "atom", "arc"]:
        q = QuantConfig(method=method, fmt="nvfp4")
        plans = make_plan_bundle(stats, cfg, q, params)
        _, aux = next_token_loss(params, cfg, eval_toks, quant=q, plans=plans)
        print(f"{method:12s} {np.exp(float(aux['nll'])):9.3f}")

    q = QuantConfig(method="arc")
    plans = make_plan_bundle(stats, cfg, q, params)
    ss = [v["S"] for v in plan_summary(plans).values()]
    print(f"\nARC augmented channels per layer: mean={np.mean(ss):.0f} "
          f"max={max(ss)} (paper Fig. 7)")


if __name__ == "__main__":
    main()
